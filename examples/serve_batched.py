"""Batched serving example: prefill a batch of prompts, stream greedy decode,
and show the sliding-window ring-buffer cache in action (gemma3-style).

  PYTHONPATH=src python examples/serve_batched.py [--arch gemma3-1b]
"""
import argparse
import time

import jax

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.sparse import registry as REG


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.ALL_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch)
    if not cfg.causal:
        raise SystemExit("encoder-only arch has no decode path")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"] if reg else {}

    cache = M.init_cache(cfg, args.batch, max_len=args.prompt_len + args.gen)
    total = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    print(f"[serve] cache bytes: {total/1e6:.2f} MB "
          f"(ring buffers cap local-attention layers at window="
          f"{cfg.sliding_window or 'n/a'})")

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(cfg, params, masks, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.batch} streams x {args.gen} tokens in {dt:.2f}s")
    for b in range(min(args.batch, 2)):
        print(f"  stream {b}: ...{out[b, -args.gen:].tolist()}")


if __name__ == "__main__":
    main()
