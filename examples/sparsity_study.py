"""The paper's core experiment in miniature (Tables 1-2 / Fig. 3):

train the same LM with {dense, SRigL, SRigL w/o ablation, RigL, SET} at a
sweep of sparsities and report final loss + learned width. Expected shape:
SRigL ~ RigL << SET, and SRigL-without-ablation degrades at very high
sparsity while ablation recovers it.

  PYTHONPATH=src python examples/sparsity_study.py [--steps 80]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.schedule import DSTSchedule
from repro.data.pipeline import SyntheticLM
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def run_one(method, sparsity, ablation, steps):
    cfg = configs.get_smoke_config("qwen3-1.7b").replace(d_ff=256)
    cfg = cfg.replace(sparsity=dataclasses.replace(
        cfg.sparsity, method=method, sparsity=sparsity, ablation=ablation,
        delta_t=10, gamma_sal=0.4))
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg)) if reg else None
    sched = DSTSchedule(delta_t=10)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, batch_size=8, seed=1)
    losses = []
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        state, m = step(state, b)
        if dst is not None and bool(sched.is_update_step(i + 1)):
            state = dst(state, b)
        losses.append(float(m["loss"]))
    width = 1.0
    if reg and method == "srigl":
        width = min(float(jnp.mean(a.astype(jnp.float32)))
                    for a in jax.tree.leaves(state.neuron_active))
    return sum(losses[-10:]) / 10, width


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args(argv)

    print(f"{'config':32s} {'final loss':>10s} {'min width':>10s}")
    loss, _ = run_one("dense", 0.0, True, args.steps)
    print(f"{'dense':32s} {loss:10.4f} {'100%':>10s}")
    for s in (0.8, 0.95):
        for label, method, abl in [
            ("srigl w/ ablation", "srigl", True),
            ("srigl w/o ablation", "srigl", False),
            ("rigl", "rigl", True),
            ("set", "set", True),
        ]:
            loss, width = run_one(method, s, abl, args.steps)
            print(f"{label + f' @ {s:.0%}':32s} {loss:10.4f} {width:10.2%}")


if __name__ == "__main__":
    main()
