"""Quickstart: train a small LM with SRigL, inspect the learned structure,
export the condensed representation, and verify serving equivalence.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import topology
from repro.core.schedule import DSTSchedule
from repro.data.pipeline import SyntheticLM
from repro.kernels import ops
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def main():
    # 1. a reduced qwen3-style config at 90% sparsity, SRigL with ablation
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, delta_t=10))
    registry = REG.build_registry(cfg)
    print(f"sparse stacks: {[s.name for s in registry]}")
    print(f"ERK densities: {[f'{s.density:.3f}' for s in registry]}")

    # 2. train with periodic topology updates
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, registry, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, registry))
    sched = DSTSchedule(delta_t=10)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, batch_size=8, seed=0)
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, metrics = step(state, batch)
        if bool(sched.is_update_step(i + 1)):
            state = dst(state, batch)
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(metrics['loss']):.4f} "
                  f"drop_frac {float(metrics['drop_fraction']):.3f}")

    # 3. learned structure: constant fan-in + neuron ablation
    summary = REG.sparsity_summary(registry, {"masks": state.masks,
                                              "neuron_active": state.neuron_active})
    for name, row in summary.items():
        print(f"{name:20s} density={row['density']:.3f} "
              f"active_neurons={row['active_neurons']:.2%}")

    # 4. condensed export: same weights, two representations (paper Sec. 4.4)
    s0 = registry[0]
    w = np.array(REG.get_path(state.params, s0.path))[0]
    m = np.array(REG.get_path(state.masks, s0.path))[0]
    k = int(m.sum(0).max())
    vals, idx = topology.dense_to_condensed(jnp.asarray(w * m), jnp.asarray(m), k)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, w.shape[0]))
    err = float(jnp.max(jnp.abs(ops.condensed_linear(x, vals, idx) - x @ (w * m))))
    print(f"condensed-vs-masked max err: {err:.2e}  (fan-in k={k}, "
          f"{vals.size}/{w.size} weights stored = {vals.size/w.size:.1%})")

    # 5. serve the trained model through the programmatic ENGINE (paper
    #    Sec. 4.4): ServingEngine.submit/step/retire admits requests, groups
    #    them by PLAN KEY — the request's batch bucket (shared with the
    #    kernel-autotune cache keys) crossed with the per-stack FORMAT the
    #    cost model picks at that bucket (repro.sparse.formats: MaskedDense /
    #    Condensed / StructuredFanIn / CondensedOverActive, the four Fig. 4
    #    points) — and decodes each group with one jitted scan program.
    #    Greedy decode is token-identical to masked-dense for every exact
    #    format the plan can choose, and fusing requests into a group slab
    #    never changes a stream's tokens (greedy argmax is batch-independent).
    #    (CLI equivalent:
    #       PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
    #           --smoke --path auto)
    from repro.launch import serve
    from repro.launch.engine import ServingEngine
    engine = ServingEngine(cfg, state.params, state.masks, registry,
                           path="auto", mask_versions=state.mask_versions)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab_size)
    rid_a = engine.submit(prompts, gen_len=8)            # batch-2 request
    rid_b = engine.submit(prompts[:1], gen_len=8)        # batch-1 request
    groups = engine.pending_groups()
    print(f"serve: {len(groups)} plan-key group(s): "
          f"{[k.describe() for k in groups]}")
    print(engine.plan_for(engine.plan_key(2)).describe())
    engine.step()
    [res_a] = engine.retire(rid_a)
    [res_b] = engine.retire(rid_b)
    out_masked = serve.generate(cfg, state.params, state.masks, prompts, 8)
    same = bool(jnp.all(out_masked == res_a.tokens))
    print(f"serve: engine decode tokens == masked decode tokens: {same} "
          f"(batch-1 group: {res_b.tok_s:.1f} tok/s)")
    print(f"serve: first stream: {res_a.tokens[0, 8:].tolist()}")

    # 6. incremental export: keep training, then refresh the engine — only
    #    stacks whose mask-version counter moved are re-condensed (per cached
    #    plan), so a live training job can serve without a full re-export
    #    every delta_t steps. The refresh runs as jitted device programs with
    #    the old format buffers DONATED (formats.Condensed.donate_refresh):
    #    new arrays are written into the old storage whenever shapes match,
    #    so serving weight memory never doubles during a refresh (and no
    #    weight data touches the host).
    for i in range(60, 70):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, _ = step(state, batch)
        if bool(sched.is_update_step(i + 1)):
            state = dst(state, batch)
    changed = engine.refresh(state.params, state.masks, state.mask_versions)
    for key, names in changed.items():
        plan = engine.plan_for(key)
        print(f"serve: refresh[{key.describe()}] re-condensed "
              f"{len(names)}/{len(registry)} stacks: {names}; values-only "
              f"regathers (topology unchanged, weights trained on): "
              f"{plan.value_refreshes}")

    # 7. calibration: replace the cost model's built-in v5e-like constants
    #    with rates measured on THIS machine (HBM stream, matmul, and the
    #    gather at TWO batch points — the activation-traffic cache cliff
    #    makes one scalar gather rate mispredict large-batch crossovers;
    #    cached per backend in the autotune cache file), and let the timed
    #    block-shape search pick the Pallas kernel tiles for every condensed
    #    dispatch shape (engine.autotune derives the cache keys from the
    #    formats' tuning_key — exactly what the kernel wrappers look up).
    #    `--path auto --profile measured` / `--autotune` on the serve CLI do
    #    the same; benchmarks/kernel_autotune.py validates that the
    #    calibrated model's predicted masked/condensed crossover batch lands
    #    in the measured bucket.
    from repro.sparse import autotune, plan as PLAN
    prof = PLAN.HardwareProfile.measure()
    print(f"calibrated {prof.name}: hbm {prof.hbm_bytes_per_s / 1e9:.1f} GB/s "
          f"matmul {prof.mxu_flops_per_s / 1e9:.1f} GFLOP/s "
          f"gather {prof.gather_flops_per_s / 1e9:.1f}->"
          f"{(prof.gather_flops_per_s_large or 0) / 1e9:.1f} GFLOP/s "
          f"(b={prof.gather_small_batch}->{prof.gather_large_batch}; "
          f"cache: {autotune.cache_path()})")
    engine_m = ServingEngine(cfg, state.params, state.masks, registry,
                             path="auto", profile=prof)
    print(engine_m.plan_for(engine_m.plan_key(2)).describe())
    tuned = engine_m.autotune(2)
    for name, res in tuned.items():
        print(f"autotuned {name} @ b=2: best "
              f"{res.block_b or 'decode'}x{res.block_n} "
              f"({res.us:.0f} us vs 128x128 default {res.default_us:.0f} us)")

    # 8. ablation-aware kernels (Fig. 4 "structured" / combined points): the
    #    structured path now executes a column-GATHERED Pallas matmul — only
    #    the surviving columns' weight bytes stream per decode step and the
    #    fused one-hot epilogue writes exact zeros for ablated neurons
    #    in-kernel (no standalone scatter dispatch; same epilogue fuses the
    #    condensed-over-active scatter). On an ablation-ONLY stack (active
    #    columns fully dense) the cost model therefore lets structured WIN
    #    auto selection outright at decode shapes, and the kernel's measured
    #    step time scales with the active fraction (interpret-mode timings
    #    on this container — rankings transfer, absolute numbers do not).
    import types

    from repro.kernels import structured_matmul as SM
    from repro.sparse import formats as F
    d_in, d_out, b = 512, 512, 8
    key8 = jax.random.PRNGKey(8)
    w8 = jax.random.normal(key8, (d_in, d_out))
    x8 = jax.random.normal(jax.random.fold_in(key8, 1), (b, d_in))
    base = None
    for frac in (1.0, 0.5, 0.25):
        a = int(d_out * frac)
        ai = jnp.sort(jax.random.permutation(
            jax.random.fold_in(key8, a), d_out)[:a]).astype(jnp.int32)
        a_pad = SM.padded_active_count(a, d_out)
        ai = jnp.pad(ai, (0, a_pad - a), constant_values=d_out)
        t = autotune._time_us(
            lambda x, w, ai: SM.structured_matmul(x, w, ai), x8, w8, ai,
            reps=3)
        base = base or t
        print(f"structured kernel active={frac:.2f}: {t:8.1f} us "
              f"({t / base:.2f}x of dense-width, interpret mode)")
    stack = types.SimpleNamespace(name="mlp@abl50", d_in=3072, d_out=1024,
                                  n_replicas=1)
    stats = F.ExportStats(k=3072, max_active=512, active_fraction=0.5,
                          min_fan_in=3072)  # ablation-only: survivors dense
    for bb in (1, 256):
        dec = PLAN.select_representation(stack, batch_size=bb, itemsize=4,
                                         stats=stats, profile=prof)
        est = {r: f"{v * 1e6:.1f}us" for r, v in dec.est_s.items()}
        print(f"auto @ b={bb} (ablation-only stack) -> {dec.representation} "
              f"{est}")

    # 9. continuous batching: the engine is a request SCHEDULER, not a slab
    #    fuser. Every dispatch is padded to the plan key's batch bucket and
    #    prompts to a power-of-two length bucket, so ONE compiled prefill and
    #    ONE compiled decode program serve every request mix in the bucket
    #    (no recompile per arriving shape). KV state lives in a PAGED pool —
    #    per-stream block tables over shared pages, page 0 reserved as the
    #    garbage page padded rows point at — and decode runs in chunked
    #    jitted scans, so requests ADMIT at chunk boundaries mid-generation
    #    and finished streams free their pages without waiting for the slab.
    #    Exact-zero masking keeps every stream's greedy tokens bitwise equal
    #    to its standalone run. (CLI: repro.launch.serve, --no-paged opts
    #    out; SLA numbers: benchmarks/serve_paths.py --smoke.)
    import time
    eng9 = ServingEngine(cfg, state.params, state.masks, registry,
                         path="masked", gen_chunk=4)
    key9 = jax.random.PRNGKey(9)
    arrivals = [(jax.random.randint(jax.random.fold_in(key9, i),
                                    (2, (4, 6, 8)[i % 3]), 0, cfg.vocab_size),
                 (8, 12)[i % 2]) for i in range(6)]
    start, lat, outstanding, steps = {}, [], set(), 0
    first = None
    while arrivals or outstanding:
        if arrivals:                 # one request per chunk boundary: it
            p, g = arrivals.pop(0)   # joins the slab mid-generation of the
            rid = eng9.submit(p, g)  # earlier ones (paged pool grows, no
            first = first or (p, g, rid)     # recompile, tokens unchanged)
            start[rid] = time.perf_counter()
            outstanding.add(rid)
        eng9.step(max_chunks=1)
        steps += 1
        for res in eng9.retire():    # early finishers free pages mid-slab
            outstanding.discard(res.id)
            lat.append((time.perf_counter() - start[res.id]) * 1e3)
            if res.id == first[2]:
                ref = serve.generate(cfg, state.params, state.masks,
                                     first[0], first[1])
                print(f"serve: first request retired after {steps} chunk(s); "
                      f"tokens == standalone masked decode: "
                      f"{bool(jnp.all(res.tokens == ref))}")
    print(f"serve: continuous batching drained {len(lat)} mixed-shape "
          f"requests in {steps} chunk steps (one bucket-8 program pair): "
          f"p50 {np.percentile(lat, 50):.1f} ms  "
          f"p99 {np.percentile(lat, 99):.1f} ms")

    # 10. quantized decode: the condensed path is HBM-bytes-bound at decode,
    #     so shrinking stored values from f32 to int8 (per-output-neuron
    #     symmetric scales, dequant fused into the Pallas kernel AFTER the
    #     k-reduction) is a direct lever on the hot path. values_dtype is an
    #     ENGINE-level choice: every plan it builds exports quantized leaves,
    #     prices the real byte width, and tunes kernels under wint8 cache
    #     keys. Below: an int8 engine against the f32 engine from the same
    #     trained state — the weight-bytes ratio is computed from the
    #     EXPORTED arrays (values+scales nbytes, the hardware-transferable
    #     quantity), and greedy token agreement is measured, not assumed.
    #     (CLI equivalent:
    #        PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
    #            --smoke --path condensed --values-dtype int8)
    eng_f32 = ServingEngine(cfg, state.params, state.masks, registry,
                            path="condensed", paged=False)
    eng_i8 = ServingEngine(cfg, state.params, state.masks, registry,
                           path="condensed", paged=False, values_dtype="int8")
    p10 = jax.random.randint(jax.random.PRNGKey(10), (1, 8), 0,
                             cfg.vocab_size)
    toks = {}
    for name, eng in (("f32", eng_f32), ("int8", eng_i8)):
        rid = eng.submit(p10, gen_len=16)
        eng.step()
        [res] = eng.retire(rid)
        toks[name] = np.asarray(res.tokens[:, 8:])
    vb = {"f32": 0, "int8": 0}
    for name, eng in (("f32", eng_f32), ("int8", eng_i8)):
        tree = eng.plan_for(eng.plan_key(1)).serving_tree
        for s in registry:
            leaf = REG.get_path(tree, s.path)
            vb[name] += leaf.values.nbytes
            if leaf.scales is not None:
                vb[name] += leaf.scales.nbytes
    agree = float(np.mean(toks["f32"] == toks["int8"]))
    print(f"quantized decode: int8 values stream "
          f"{vb['int8']}/{vb['f32']} bytes = {vb['int8'] / vb['f32']:.3f}x "
          f"of f32 (exported values+scales; ->(k+4)/(4k) at large fan-in); "
          f"greedy token agreement vs f32: {agree:.2%}")
    print(f"quantized decode: int8 stream: {toks['int8'][0].tolist()}")

    # 11. tensor-parallel serving: constant fan-in means the condensed
    #     neuron axis partitions EXACTLY over a 'model' mesh axis — each
    #     shard holds n/tp neuron rows with locally rebased indices, the
    #     gather is shard-local (x stays replicated), and GSPMD inserts
    #     exactly ONE all-gather per sparse layer to rebuild the output.
    #     Whether that collective is worth paying is a COST-MODEL decision,
    #     not a flag: stack_costs(tp=...) adds collective-priced
    #     "<rep>@tpN" candidates (profile.ici_bytes_per_s prices the
    #     all-gather) and --path auto picks per stack. Below: the priced
    #     decision surface in-process, then the serve_tp DRYRUN as a
    #     subprocess (it forces 512 simulated host devices via XLA_FLAGS
    #     before importing jax, which this process — already running jax on
    #     the real device set — must not do): it lowers sharded prefill +
    #     paged decode on a simulated 4-way model mesh and ASSERTS the SPMD
    #     invariants from the lowered HLO (per-stack isolated apply: 1
    #     all-gather, 0 stray collectives, shard-local (n/tp, k) gathers),
    #     printing per-shard condensed bytes and full-program collective
    #     counts. (CLI, real multi-device host: repro.launch.serve --tp N.)
    stack11 = types.SimpleNamespace(name="mlp@tp", d_in=2048, d_out=2048,
                                    n_replicas=1)
    stats11 = F.ExportStats(k=205, max_active=2048, active_fraction=1.0,
                            min_fan_in=205)
    for bb in (1, 512):
        dec = PLAN.select_representation(stack11, batch_size=bb, itemsize=4,
                                         stats=stats11, tp=4)
        print(f"tp auto @ b={bb}: -> {dec.cost_key} "
              f"(sharded gather vs per-layer all-gather, priced at "
              f"{PLAN.DEFAULT_PROFILE.ici_bytes_per_s / 1e9:.0f} GB/s ICI)")
    cross = PLAN.tp_crossover_batch(stack11, itemsize=4, stats=stats11, tp=4)
    print(f"tp auto: predicted shard->replicate crossover batch: {cross} "
          f"(benchmarks/serve_paths.py records this per arch, schema v6)")
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-1.7b",
         "--shapes", "decode_32k", "--program", "serve_tp", "--tp", "4",
         "--smoke"],
        capture_output=True, text=True)
    for line in proc.stdout.splitlines():
        if "[serve_tp]" in line or "cells compiled" in line:
            print(f"dryrun| {line}")
    if proc.returncode:
        print(proc.stdout[-2000:], proc.stderr[-2000:])
        raise SystemExit("serve_tp dryrun failed")

    # 12. live train->serve sync (repro.sync): the model keeps TRAINING
    #     while replicas SERVE it. The trainer publishes versioned records
    #     to a sync directory — generation 1 is a full Snapshot (bootstrap),
    #     then one Delta per publish: stacks whose mask_versions moved ship
    #     their condensed indices+values (a "topology" record — the
    #     condensed format IS the wire format), unchanged stacks ship
    #     values-only, and the dense non-stack params ride along. Subscriber
    #     replicas tail the directory and apply each generation
    #     all-or-nothing at paged-chunk boundaries through the DONATED
    #     adoption path (no weight-memory doubling, no decode recompiles);
    #     stale/duplicate records drop, a gap triggers a full-snapshot
    #     resync via the request-file back-channel. Below: publish in THIS
    #     process while `serve.py --sync-dir` subscribes as a second
    #     process — the production topology, two processes sharing only a
    #     directory.
    import tempfile
    from repro.sync import DirChannel, Publisher
    sync_dir = tempfile.mkdtemp(prefix="repro-sync-")
    pub = Publisher(cfg, registry, DirChannel(sync_dir), path="condensed",
                    batch_size=2, arch="qwen3-1.7b")
    info = pub.publish(state)
    print(f"sync: gen {info['generation']} {info['kind']} "
          f"{info['bytes']} B -> {sync_dir}")
    # a few more training steps: values-only deltas between DST updates,
    # a topology delta when the schedule rewires
    for i in range(60, 75):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, metrics = step(state, batch)
        if bool(sched.is_update_step(i + 1)):
            state = dst(state, batch)
        info = pub.publish(state)
        if info["topology"] or i % 5 == 0:
            print(f"sync: gen {info['generation']:2d} "
                  f"{'topology ' + str(info['topology']) if info['topology'] else 'values-only'}"
                  f" ({info['bytes']} B: topo {info['topology_bytes']} + "
                  f"values {info['values_bytes']} + dense "
                  f"{info['dense_bytes']})")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-1.7b",
         "--smoke", "--path", "condensed", "--batch", "2", "--prompt-len",
         "8", "--gen", "8", "--sync-dir", sync_dir],
        capture_output=True, text=True)
    for line in proc.stdout.splitlines():
        if "[serve" in line:
            print(f"subscriber| {line}")
    if proc.returncode:
        print(proc.stdout[-2000:], proc.stderr[-2000:])
        raise SystemExit("serve --sync-dir failed")

    # 13. self-draft speculative decoding: SRigL's neuron ablation means the
    #     served model already CONTAINS its own draft model — the SAME
    #     trained weights at a higher ablation fraction. The engine derives
    #     a per-stack draft plan from the live mask (plan.derive_draft_tree;
    #     every value buffer shared BY IDENTITY with the target plan — zero
    #     extra weight residency, asserted), runs gamma cheap draft steps,
    #     then ONE batched full-network verify over the gamma+1 positions;
    #     the agreed prefix commits, the first mismatch rewinds the paged KV
    #     (overshoot pages back to the pool). Greedy acceptance keeps the
    #     token stream BITWISE identical to plain greedy decode — the knobs
    #     trade full-network dispatches per token, never correctness.
    #     Whether the draft is worth running is PRICED, not assumed
    #     (plan.price_speculation: sentinel drafts save nothing under the
    #     current kernels, column subsets do; --path auto can decline, fixed
    #     paths force). Below: acceptance and dispatches/token measured
    #     across (gamma, draft_ablation); ablation 0.0 pins the protocol
    #     ceiling — the draft IS the target, acceptance 1.0, exactly
    #     1/(gamma+1) dispatches per token.
    from repro.launch.speculative import SpecConfig
    p13 = jax.random.randint(jax.random.PRNGKey(13), (2, 8), 0,
                             cfg.vocab_size)
    eng_ref = ServingEngine(cfg, state.params, state.masks, registry,
                            path="condensed")
    rid = eng_ref.submit(p13, gen_len=16)
    eng_ref.step()
    [ref13] = eng_ref.retire(rid)
    for gamma, frac in ((3, 0.0), (3, 0.5), (2, 0.5)):
        eng13 = ServingEngine(
            cfg, state.params, state.masks, registry, path="condensed",
            speculative=SpecConfig(gamma=gamma, draft_ablation=frac,
                                   force=True))
        rid = eng13.submit(p13, gen_len=16)
        eng13.step()
        [res13] = eng13.retire(rid)
        s = res13.spec
        print(f"spec g={gamma} abl={frac}: acceptance "
              f"{s['acceptance_rate']:.2f}, full-network dispatches/token "
              f"{s['full_dispatches_per_token']:.3f}, bitwise == plain: "
              f"{bool(jnp.all(res13.tokens == ref13.tokens))}")
    est13 = eng13.spec_estimate_for(eng13.plan_key(2))
    print(f"spec pricing @ smoke dims: draft {est13.draft_step_s * 1e6:.0f}us"
          f" vs target {est13.target_step_s * 1e6:.0f}us per step -> "
          f"auto would {'run' if est13.worthwhile else 'decline'} "
          f"(lane padding hides tiny-dim savings; realistic d_out wins)")
    # the CLI drives the same thing: --speculative --gamma G
    # --draft-ablation F (a fixed --path forces; --path auto prices)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-1.7b",
         "--smoke", "--path", "condensed", "--batch", "2", "--prompt-len",
         "8", "--gen", "16", "--speculative", "--gamma", "3",
         "--draft-ablation", "0.5"],
        capture_output=True, text=True)
    for line in proc.stdout.splitlines():
        if "[serve:spec]" in line or "tok/s" in line:
            print(f"spec-cli| {line}")
    if proc.returncode:
        print(proc.stdout[-2000:], proc.stderr[-2000:])
        raise SystemExit("serve --speculative failed")


if __name__ == "__main__":
    main()
