"""Quickstart: train a small LM with SRigL, inspect the learned structure,
export the condensed representation, and verify serving equivalence.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import topology
from repro.core.schedule import DSTSchedule
from repro.data.pipeline import SyntheticLM
from repro.kernels import ops
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def main():
    # 1. a reduced qwen3-style config at 90% sparsity, SRigL with ablation
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, delta_t=10))
    registry = REG.build_registry(cfg)
    print(f"sparse stacks: {[s.name for s in registry]}")
    print(f"ERK densities: {[f'{s.density:.3f}' for s in registry]}")

    # 2. train with periodic topology updates
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, registry, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, registry))
    sched = DSTSchedule(delta_t=10)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, batch_size=8, seed=0)
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, metrics = step(state, batch)
        if bool(sched.is_update_step(i + 1)):
            state = dst(state, batch)
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(metrics['loss']):.4f} "
                  f"drop_frac {float(metrics['drop_fraction']):.3f}")

    # 3. learned structure: constant fan-in + neuron ablation
    summary = REG.sparsity_summary(registry, {"masks": state.masks,
                                              "neuron_active": state.neuron_active})
    for name, row in summary.items():
        print(f"{name:20s} density={row['density']:.3f} "
              f"active_neurons={row['active_neurons']:.2%}")

    # 4. condensed export: same weights, two representations (paper Sec. 4.4)
    s0 = registry[0]
    w = np.array(REG.get_path(state.params, s0.path))[0]
    m = np.array(REG.get_path(state.masks, s0.path))[0]
    k = int(m.sum(0).max())
    vals, idx = topology.dense_to_condensed(jnp.asarray(w * m), jnp.asarray(m), k)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, w.shape[0]))
    err = float(jnp.max(jnp.abs(ops.condensed_linear(x, vals, idx) - x @ (w * m))))
    print(f"condensed-vs-masked max err: {err:.2e}  (fan-in k={k}, "
          f"{vals.size}/{w.size} weights stored = {vals.size/w.size:.1%})")

    # 5. serve the trained model through an execution PLAN (paper Sec. 4.4):
    #    repro.sparse.plan picks a representation PER STACK from a bytes/FLOPs
    #    cost model over the request batch — condensed gather at decode (B=1),
    #    masked-dense MXU at large batch, and the composed condensed-over-
    #    active once training has ablated neurons (the combined Fig. 4 point).
    #    Greedy decode is token-identical to masked-dense for every exact
    #    representation the plan can choose.
    #    (CLI equivalent:
    #       PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
    #           --smoke --path auto)
    from repro.launch import serve
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab_size)
    plan = serve.build_plan(cfg, registry, state.params, state.masks, "auto",
                            batch_size=2, mask_versions=state.mask_versions)
    print(plan.describe())
    out_masked = serve.generate(cfg, state.params, state.masks, prompts, 8)
    out_plan = serve.generate(cfg, state.params, plan.serving_tree, prompts, 8)
    same = bool(jnp.all(out_masked == out_plan))
    print(f"serve: planned decode tokens == masked decode tokens: {same}")
    print(f"serve: first stream: {out_plan[0, 8:].tolist()}")

    # 6. incremental export: keep training, then refresh the plan — only
    #    stacks whose mask-version counter moved are re-condensed, so a live
    #    training job can serve without a full re-export every delta_t steps.
    #    The refresh runs as jitted device programs with the plan's OLD
    #    {values, indices} buffers donated: new arrays are written into the
    #    old storage whenever shapes match, so serving weight memory never
    #    doubles during a refresh (and no weight data touches the host).
    for i in range(60, 70):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, _ = step(state, batch)
        if bool(sched.is_update_step(i + 1)):
            state = dst(state, batch)
    changed = plan.refresh(state.params, state.masks, state.mask_versions)
    print(f"serve: plan.refresh re-condensed {len(changed)}/{len(registry)} "
          f"stacks: {changed}; values-only regathers (topology unchanged, "
          f"weights trained on): {plan.value_refreshes}")

    # 7. calibration: replace the cost model's built-in v5e-like constants
    #    with rates measured on THIS machine (HBM stream, matmul, gather —
    #    cached per backend in the autotune cache file), and let the timed
    #    block-shape search pick the Pallas kernel tiles for the decode
    #    shape. `--path auto --profile measured` / `--autotune` on the serve
    #    CLI do the same; benchmarks/kernel_autotune.py validates that the
    #    calibrated model's predicted masked/condensed crossover batch lands
    #    in the measured bucket.
    from repro.sparse import autotune, plan as PLAN
    prof = PLAN.HardwareProfile.measure()
    print(f"calibrated {prof.name}: hbm {prof.hbm_bytes_per_s / 1e9:.1f} GB/s "
          f"matmul {prof.mxu_flops_per_s / 1e9:.1f} GFLOP/s "
          f"gather {prof.gather_flops_per_s / 1e9:.1f} GFLOP/s "
          f"(cache: {autotune.cache_path()})")
    plan_m = serve.build_plan(cfg, registry, state.params, state.masks,
                              "auto", batch_size=2, profile=prof)
    print(plan_m.describe())
    res = autotune.autotune_blocks(2, s0.d_in, s0.d_out, k)
    print(f"autotuned {s0.name} @ b=2: best "
          f"{res.block_b or 'decode'}x{res.block_n} "
          f"({res.us:.0f} us vs 128x128 default {res.default_us:.0f} us)")


if __name__ == "__main__":
    main()
