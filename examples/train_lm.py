"""End-to-end driver: train a ~100M-parameter sparse LM for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py                  # ~20M (CPU-sized)
  PYTHONPATH=src python examples/train_lm.py --full           # ~110M params
  PYTHONPATH=src python examples/train_lm.py --steps 300 --ckpt /tmp/ck

Uses the production Trainer (prefetch, checkpoints, straggler watchdog) with
SRigL at 90% sparsity and the ERK distribution — the paper's recipe end to end.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.optim.schedules import warmup_cosine
from repro.sparse import registry as REG
from repro.train.trainer import Trainer


def lm_100m() -> "configs.ArchConfig":
    """~110M-parameter qwen3-style dense transformer, SRigL @ 90%."""
    return configs.get_config("qwen3-1.7b").replace(
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32_000, dtype="float32",
        attn_q_chunk=128, attn_kv_chunk=128, ce_chunk=128,
        sparsity=dataclasses.replace(
            configs.get_config("qwen3-1.7b").sparsity, delta_t=25))


def lm_20m() -> "configs.ArchConfig":
    return lm_100m().replace(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                             head_dim=32, d_ff=1024, vocab_size=8_000)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~110M params")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = lm_100m() if args.full else lm_20m()
    reg = REG.build_registry(cfg)
    n_params = sum(
        s.d_in * s.d_out * s.n_replicas for s in reg) + cfg.vocab_padded * cfg.d_model
    print(f"[train_lm] ~{n_params/1e6:.0f}M params in sparse stacks + embeddings, "
          f"sparsity {cfg.sparsity.sparsity:.0%} ({cfg.sparsity.method})")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=0)
    batches = Prefetcher((jax.tree.map(jnp.asarray, b) for b in data.iterate()),
                         depth=2)
    trainer = Trainer(cfg=cfg,
                      lr_fn=warmup_cosine(3e-3, args.steps // 10, args.steps),
                      ckpt_dir=args.ckpt or None, ckpt_every=50, log_every=10)
    state = trainer.init_or_restore(jax.random.PRNGKey(0))
    state = trainer.fit(state, batches, args.steps)
    batches.close()

    summary = REG.sparsity_summary(trainer.registry,
                                   {"masks": state.masks,
                                    "neuron_active": state.neuron_active})
    print("[train_lm] learned structure:")
    for name, row in summary.items():
        print(f"  {name:20s} density={row['density']:.3f} "
              f"active={row['active_neurons']:.2%}")


if __name__ == "__main__":
    main()
