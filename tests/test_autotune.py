"""Kernel block-shape selection + timed autotune cache (repro.sparse.autotune,
kernels.condensed_matmul block logic).

The satellite contracts made executable:

* every candidate / chosen (block_b, block_n) respects the documented VMEM
  budget formula and 8x128 alignment;
* padded shapes stay exact for non-multiple (b, n_out) under auto block
  selection (both the general and the decode-specialized path);
* the decode-specialized small-batch variant is BIT-identical to the general
  kernel (same f32 accumulation per row, batch padding/tiling independent);
* the timed search's winner is never slower than the legacy 128x128 default
  on its own measured table, persists across a cache reload, and is consumed
  by kernels.ops.condensed_linear at trace time.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import condensed_matmul as cm
from repro.kernels import ops, ref
from repro.sparse import autotune as AT


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    AT.reset_cache_state()
    yield tmp_path / "autotune.json"
    AT.reset_cache_state()


# ---------------------------------------------------------------------------
# block candidates: alignment + VMEM budget
# ---------------------------------------------------------------------------

SHAPE_GRID = [
    (1, 64, 32, 8),
    (8, 3072, 768, 307),
    (130, 300, 257, 5),
    (256, 3072, 768, 307),
    (1024, 16384, 4096, 64),   # budget-constrained: 128*16384 words > cap
    (4096, 65536, 8192, 32),   # extreme d_in: only minimum blocks survive
]


@pytest.mark.parametrize("b,d_in,n_out,k", SHAPE_GRID)
def test_block_candidates_respect_budget_and_alignment(b, d_in, n_out, k):
    budget = cm.vmem_budget_bytes()
    cands = cm.block_candidates(b, d_in, n_out, k)
    assert cands
    for bb, bn in cands:
        assert bb % cm.SUBLANE == 0 and bn % cm.LANE == 0
        if (bb, bn) != (cm.SUBLANE, cm.LANE):  # minimum kept unconditionally
            assert cm.fwd_vmem_words(bb, bn, d_in, k) * 4 <= budget
    for bb, bn in cm.dw_block_candidates(b, d_in, n_out, k):
        assert bb % cm.SUBLANE == 0 and bn % cm.LANE == 0
        if (bb, bn) != (cm.SUBLANE, cm.LANE):
            assert cm.dw_vmem_words(bb, bn, d_in, k) * 4 <= budget


@pytest.mark.parametrize("b,d_in,n_out,k", SHAPE_GRID)
def test_default_blocks_are_valid_candidates(b, d_in, n_out, k):
    assert cm.default_blocks(b, d_in, n_out, k) in \
        cm.block_candidates(b, d_in, n_out, k)
    assert cm.default_dw_blocks(b, d_in, n_out, k) in \
        cm.dw_block_candidates(b, d_in, n_out, k)


def test_default_blocks_keep_legacy_shape_when_it_fits():
    """The paper-benchmark layer at training batch still gets the legacy
    128x128 default (the autotuner refines it, the default must not regress)."""
    assert cm.default_blocks(256, 3072, 768, 307) == (128, 128)


def test_block_candidates_shrink_batch_dim_first():
    """When B_blk * d_in blows the budget, the batch tile shrinks before the
    neuron tile (d_in is structurally unblocked)."""
    bb, bn = cm.default_blocks(1024, 262144, 4096, 32)
    assert bb == cm.SUBLANE
    assert (bb, bn) == (8, 128)


def test_batch_bucket_monotonic_and_covering():
    assert AT.batch_bucket(1) == 1
    assert AT.batch_bucket(2) == 8
    assert AT.batch_bucket(8) == 8
    assert AT.batch_bucket(9) == 32
    # above the table the geometric x4 progression continues: the bucket
    # must COVER the batch (a plan calibrated below the dispatch batch was
    # the slab-overflow bug), never silently clamp down
    assert AT.batch_bucket(2049) == 8192
    assert AT.batch_bucket(10**9) >= 10**9
    prev = 0
    for b in list(range(1, 3000)) + [10**6, 10**9]:
        cur = AT.batch_bucket(b)
        assert cur >= b
        assert cur >= prev
        prev = cur


# ---------------------------------------------------------------------------
# exactness under auto block selection (padding paths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,d_in,n_out,k", [
    (1, 40, 257, 5),      # decode variant, non-multiple n_out
    (3, 64, 129, 3),      # decode variant, batch not a sublane multiple
    (8, 33, 128, 4),      # decode threshold boundary
    (9, 33, 130, 4),      # just past the threshold: general kernel
    (130, 300, 257, 5),   # general kernel, both dims non-multiple
])
def test_auto_blocks_padding_stays_exact(b, d_in, n_out, k):
    key = jax.random.PRNGKey(b * 31 + k)
    x = jax.random.normal(key, (b, d_in))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    y = cm.condensed_matmul(x, w, idx)  # block_b=None -> auto dispatch
    np.testing.assert_allclose(np.array(y),
                               np.array(ref.condensed_matmul_ref(x, w, idx)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", [1, 2, 5, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_variant_bit_identical_to_general_kernel(b, dtype):
    """Same f32 accumulation per output row -> the decode-specialized variant
    must match the general tiled kernel BIT for bit, not just approximately."""
    d_in, n_out, k = 96, 257, 7
    key = jax.random.PRNGKey(b)
    x = jax.random.normal(key, (b, d_in), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k),
                          jnp.float32).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    y_dec = cm.condensed_matmul_decode(x, w, idx, block_n=128, interpret=True)
    y_gen = cm.condensed_matmul(x, w, idx, block_b=128, block_n=128,
                                interpret=True)
    assert y_dec.dtype == y_gen.dtype
    np.testing.assert_array_equal(np.array(y_dec), np.array(y_gen))


def test_dw_batch_tiling_matches_untiled():
    """Blocked-over-batch dw accumulates tile partials in f32: equal to the
    whole-batch staging within f32 roundoff, and to the oracle."""
    b, d_in, n_out, k = 130, 48, 129, 5
    key = jax.random.PRNGKey(0)
    dy = jax.random.normal(key, (b, n_out))
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d_in))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    dw_tiled = cm.condensed_matmul_dw(dy, x, idx, block_b=32, block_n=128,
                                      interpret=True)
    dw_whole = cm.condensed_matmul_dw(dy, x, idx, block_b=136, block_n=128,
                                      interpret=True)
    dw_ref = ref.condensed_matmul_dw_ref(dy, x, idx)
    np.testing.assert_allclose(np.array(dw_tiled), np.array(dw_whole),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.array(dw_tiled), np.array(dw_ref),
                               rtol=1e-5, atol=1e-5)


def test_dw_auto_blocks_stay_exact_and_grads_flow():
    """Auto-picked dw blocks (block_b=None) on a non-aligned training shape,
    reached through the custom-VJP backward pass."""
    b, d_in, n_out, k = 67, 40, 33, 6
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (b, d_in))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    f = lambda x, w: jnp.sum(jnp.tanh(ops.condensed_linear(x, w, idx)))
    g = lambda x, w: jnp.sum(jnp.tanh(ref.condensed_matmul_ref(x, w, idx)))
    gx1, gw1 = jax.grad(f, (0, 1))(x, w)
    gx2, gw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), atol=1e-5)
    np.testing.assert_allclose(np.array(gw1), np.array(gw2), atol=1e-5)


def test_interpret_default_resolves_from_backend(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert cm.default_interpret("cpu") is True
    assert cm.default_interpret("tpu") is False
    assert cm.default_interpret("gpu") is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert cm.default_interpret("cpu") is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert cm.default_interpret("tpu") is True


# ---------------------------------------------------------------------------
# timed search + persistent cache
# ---------------------------------------------------------------------------

TUNE_SHAPE = dict(batch=1, d_in=48, n_out=96, k=4)


def test_autotune_winner_beats_default_on_its_table(tmp_cache):
    res = AT.autotune_blocks(reps=2, **TUNE_SHAPE)
    assert "128x128" in res.table            # legacy default always measured
    assert res.us == min(res.table.values())
    assert res.us <= res.default_us          # winner is argmin of the table
    assert res.speedup_vs_default >= 1.0
    if res.block_b is not None:              # winner respects the contracts
        assert (res.block_b, res.block_n) in cm.block_candidates(
            AT.batch_bucket(TUNE_SHAPE["batch"]), TUNE_SHAPE["d_in"],
            TUNE_SHAPE["n_out"], TUNE_SHAPE["k"]) + [(128, 128)]
    else:
        assert res.block_n % cm.LANE == 0


def test_autotune_cache_survives_reload(tmp_cache):
    res = AT.autotune_blocks(reps=2, **TUNE_SHAPE)
    AT.reset_cache_state()                   # force a re-read from disk
    got = AT.lookup_blocks(TUNE_SHAPE["batch"], TUNE_SHAPE["d_in"],
                           TUNE_SHAPE["n_out"], TUNE_SHAPE["k"])
    assert got == {"block_b": res.block_b, "block_n": res.block_n}
    assert tmp_cache.exists()
    # same bucket, different batch -> same entry; other bucket -> miss
    assert AT.lookup_blocks(1, **{k: v for k, v in TUNE_SHAPE.items()
                                  if k != "batch"}) == got
    assert AT.lookup_blocks(256, TUNE_SHAPE["d_in"], TUNE_SHAPE["n_out"],
                            TUNE_SHAPE["k"]) is None


def test_ops_consume_tuned_blocks(tmp_cache, monkeypatch):
    """condensed_linear resolves its block shape from the autotune cache at
    trace time (the tuned winner reaches the Pallas wrapper's kwargs)."""
    res = AT.autotune_blocks(reps=2, **TUNE_SHAPE)
    seen = {}

    orig_general, orig_decode = cm.condensed_matmul, cm.condensed_matmul_decode

    def spy_general(x, v, i, **kw):
        seen.update(kw)
        return orig_general(x, v, i, **kw)

    def spy_decode(x, v, i, **kw):
        seen.update(kw, decode=True)
        return orig_decode(x, v, i, **kw)

    monkeypatch.setattr(cm, "condensed_matmul", spy_general)
    monkeypatch.setattr(cm, "condensed_matmul_decode", spy_decode)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (TUNE_SHAPE["batch"], TUNE_SHAPE["d_in"]))
    v = jax.random.normal(key, (TUNE_SHAPE["n_out"], TUNE_SHAPE["k"]))
    idx = jax.random.randint(key, (TUNE_SHAPE["n_out"], TUNE_SHAPE["k"]), 0,
                             TUNE_SHAPE["d_in"])
    y = ops.condensed_linear(x, v, idx)
    np.testing.assert_allclose(
        np.array(y), np.array(ref.condensed_matmul_ref(x, v, idx)),
        rtol=1e-5, atol=1e-5)
    assert seen["block_b"] == res.block_b
    assert seen["block_n"] == res.block_n


def test_ops_fall_back_to_vmem_default_without_cache(tmp_cache, monkeypatch):
    captured = {}
    orig = cm.condensed_matmul

    def spy(x, v, i, **kw):
        captured.update(kw)
        return orig(x, v, i, **kw)

    monkeypatch.setattr(cm, "condensed_matmul", spy)
    x = jnp.ones((4, 32))
    v = jnp.ones((64, 3))
    idx = jnp.zeros((64, 3), jnp.int32)
    ops.condensed_linear(x, v, idx)
    assert captured["block_b"] is None       # cm auto-dispatch decides
    assert captured["block_n"] is None


# ---------------------------------------------------------------------------
# review regressions: forced-dim block resolution + ablated-shape tuning
# ---------------------------------------------------------------------------

def test_fit_block_b_respects_budget_at_forced_block_n():
    """A caller-forced (large) neuron tile must shrink the auto batch tile
    against the SAME VMEM budget — the 128-target default would overflow."""
    budget = cm.vmem_budget_bytes()
    for words_fn in (cm.fwd_vmem_words, cm.dw_vmem_words):
        for bn in (128, 512, 1024):
            bb = cm._fit_block_b(words_fn, bn, 512, 3072, 307)
            assert bb % cm.SUBLANE == 0
            if bb != cm.SUBLANE:   # the 8-row floor is kept unconditionally
                assert words_fn(bb, bn, 3072, 307) * 4 <= budget
    # concrete overflow case from review: bn=1024 at d_in=3072, k=307 must
    # not get the bn=128-sized default batch tile
    bb = cm._fit_block_b(cm.dw_vmem_words, 1024, 512, 3072, 307)
    assert cm.dw_vmem_words(bb, 1024, 3072, 307) * 4 <= budget
    assert bb < cm.default_dw_blocks(512, 3072, 768, 307)[0]


def test_grads_exact_with_forced_block_n_only():
    """custom-VJP backward with a forced block_n and auto block_b (the
    resolution path that re-sizes the dw batch tile)."""
    b, d_in, n_out, k = 40, 48, 129, 5
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (b, d_in))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    f = lambda x, w: jnp.sum(jnp.tanh(ops.condensed_linear(x, w, idx,
                                                           None, 256)))
    g = lambda x, w: jnp.sum(jnp.tanh(ref.condensed_matmul_ref(x, w, idx)))
    gx1, gw1 = jax.grad(f, (0, 1))(x, w)
    gx2, gw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), atol=1e-5)
    np.testing.assert_allclose(np.array(gw1), np.array(gw2), atol=1e-5)


def test_tune_registry_covers_ablated_row_count(tmp_cache):
    """Stacks with ablation are tuned at BOTH the full d_out shape and the
    surviving-row shape — the latter under the FUSED coa kernel's key (the
    condensed-over-active leaf's (a, k) arrays plus the d_out-wide scatter
    are what ops looks up); ablation-ONLY stacks additionally tune the
    structured kernel's key."""
    import types

    from repro.sparse import condensed as COND
    from repro.sparse import formats as F
    stack = types.SimpleNamespace(name="s", d_in=48, d_out=96)
    stats = {"s": COND.ExportStats(k=4, max_active=64, active_fraction=0.66)}
    out = AT.tune_registry([stack], stats, batch=1, reps=1)
    assert set(out) == {"s", "s@a64"}
    assert AT.lookup_blocks(1, 48, 96, 4) is not None    # full rows
    spec = F.spec_for_stack(stack, stats["s"], 4)
    assert AT.lookup_entry(F.CondensedOverActive.spec_tuning_key(
        spec, 1)) is not None                            # surviving rows (coa)
    # NOT ablation-only (min_fan_in < d_in): no structured entry
    assert AT.lookup_entry(F.StructuredFanIn.spec_tuning_key(spec, 1)) is None
    # ablation-ONLY stack: the structured kernel's key is tuned too
    stats3 = {"s3": COND.ExportStats(k=48, max_active=64, active_fraction=0.66,
                                     min_fan_in=48)}
    stack3 = types.SimpleNamespace(name="s3", d_in=48, d_out=96)
    out3 = AT.tune_registry([stack3], stats3, batch=1, reps=1)
    assert set(out3) == {"s3", "s3@a64", "s3@structured"}
    spec3 = F.spec_for_stack(stack3, stats3["s3"], 4)
    assert AT.lookup_entry(F.StructuredFanIn.spec_tuning_key(
        spec3, 1)) is not None
    # no ablation -> only the full shape is tuned
    stats2 = {"s2": COND.ExportStats(k=4, max_active=80, active_fraction=1.0)}
    out2 = AT.tune_registry(
        [types.SimpleNamespace(name="s2", d_in=32, d_out=80)], stats2,
        batch=1, reps=1)
    assert set(out2) == {"s2"}


def test_fit_block_n_respects_budget_at_forced_block_b():
    """Mirror of the forced-block_n case: an explicit (large) batch tile must
    shrink the auto neuron tile against the budget, not take the default."""
    budget = cm.vmem_budget_bytes()
    for words_fn in (cm.fwd_vmem_words, cm.dw_vmem_words):
        for bb in (8, 128, 256):
            bn = cm._fit_block_n(words_fn, bb, 4096, 16384, 307)
            assert bn % cm.LANE == 0
            if bn != cm.LANE:
                assert words_fn(bb, bn, 16384, 307) * 4 <= budget


def test_tune_registry_keys_by_dtype_itemsize(tmp_cache):
    """Tuning at bf16 must store w16 keys — what a bf16 serving run looks up
    (serve --autotune passes the config dtype through)."""
    import types

    from repro.sparse import condensed as COND
    stack = types.SimpleNamespace(name="s", d_in=32, d_out=64)
    stats = {"s": COND.ExportStats(k=3, max_active=64, active_fraction=1.0)}
    AT.tune_registry([stack], stats, batch=1, reps=1, dtype=jnp.bfloat16)
    assert AT.lookup_blocks(1, 32, 64, 3, itemsize=2) is not None
    assert AT.lookup_blocks(1, 32, 64, 3, itemsize=4) is None
