"""Sharding rules: specs are rank-correct and divisible for every arch."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.sharding import ShardingRules, _map_with_path
from repro.models import model as M
from repro.sparse import registry as REG
from repro.train.state import init_train_state


class FakeMesh:
    """Shape-only stand-in for the 16x16 production mesh (no devices needed)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


def _check_divisible(path, leaf, spec, mesh_shape):
    assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)
    for dim, ax in zip(leaf.shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh_shape[a]
        assert dim % n == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("name", configs.ALL_ARCHS)
def test_param_specs_divisible(name):
    cfg = configs.get_config(name)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    state_sds = jax.eval_shape(lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0))

    def check(tree):
        def f(path, leaf, fmt=None):
            spec = rules.param_spec(path, leaf, fmt)
            _check_divisible(path, leaf, spec, mesh.shape)
        _map_with_path(f, tree)

    check(state_sds.params)
    check(state_sds.masks)


@pytest.mark.parametrize("name", configs.ALL_ARCHS)
def test_cache_specs_divisible(name):
    cfg = configs.get_config(name)
    if not cfg.causal:
        pytest.skip("encoder-only")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    for shape in configs.shapes_for(name, cfg.family, cfg.causal):
        if shape.kind != "decode":
            continue
        cache_sds = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))

        def f(path, leaf, fmt=None):
            spec = rules.cache_spec(path, leaf, global_batch=shape.global_batch)
            _check_divisible(path, leaf, spec, mesh.shape)

        _map_with_path(f, cache_sds)


@pytest.mark.parametrize("name", configs.ALL_ARCHS)
def test_paged_pool_specs_page_sharded_and_divisible(name):
    """The paged KV pool shards its PAGE axis over the batch axes (the
    paged analog of batch sharding) whenever the page count divides."""
    cfg = configs.get_config(name)
    if not M.supports_paged(cfg):
        pytest.skip("outside the paged serving path")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    for shape in configs.shapes_for(name, cfg.family, cfg.causal):
        if shape.kind != "decode":
            continue
        nb = -(-(shape.seq_len + 16) // 16)
        pool_sds = jax.eval_shape(
            lambda: M.init_paged_pool(cfg, shape.global_batch * nb, 16))

        def f(path, leaf, fmt=None):
            spec = rules.cache_spec(path, leaf,
                                    global_batch=shape.global_batch)
            _check_divisible(path, leaf, spec, mesh.shape)
            if shape.global_batch % 16 == 0:
                assert spec[-4] is not None, (name, path, spec)

        _map_with_path(f, pool_sds)


def test_dst_compute_specs_put_model_on_neuron_axis():
    cfg = configs.get_config("mistral-large-123b")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    reg = REG.build_registry(cfg)
    specs = rules.dst_compute_specs(reg)
    for s in reg:
        sp = specs[s.name]
        assert sp[-2] is None          # fan-in axis local (sorted over)
        # neuron axis sharded when divisible
        if s.d_out % 16 == 0:
            assert sp[-1] == "model"


def test_small_ssm_stays_dp_only():
    """mamba2-130m: 24 ssm heads don't divide 16 — TP must be off."""
    cfg = configs.get_config("mamba2-130m")
    rules = ShardingRules(cfg, FakeMesh({"data": 16, "model": 16}))
    assert not rules.ssm_tp
    spec = rules.param_spec(("blocks", "in_x"), _Leaf((24, 768, 1536)))
    assert spec == P(None, None, None)


def test_zamba_ssm_tp_on():
    cfg = configs.get_config("zamba2-7b")
    rules = ShardingRules(cfg, FakeMesh({"data": 16, "model": 16}))
    assert rules.ssm_tp  # 112 heads / 16 = 7


def test_fsdp_axis_for_big_archs():
    cfg = configs.get_config("mistral-large-123b").replace()
    # fsdp flag off by default in config? ensure rules honor the attribute
    rules = ShardingRules(cfg, FakeMesh({"data": 16, "model": 16}))
    spec = rules.param_spec(("blocks", "w_gate"), _Leaf((88, 12288, 28672)))
    assert spec[-1] == "model"


class _Leaf:
    def __init__(self, shape):
        self.shape = shape
        self.ndim = len(shape)


def test_single_device_mesh_runs_sharded_step():
    """End-to-end: shardings on the degenerate 1x1 mesh execute correctly."""
    from repro.launch.mesh import make_host_mesh
    cfg = configs.get_smoke_config("qwen3-1.7b")
    mesh = make_host_mesh()
    rules = ShardingRules(cfg, mesh)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    sh = rules.params(state.params)
    placed = jax.device_put(state.params, sh)
    assert float(jax.tree.leaves(placed)[0].sum()) == pytest.approx(
        float(jax.tree.leaves(state.params)[0].sum()), rel=1e-6)


def test_opt_state_factored_drop_of_a_sharded_axis():
    """Adafactor vr/vc drop one weight axis — when the DROPPED axis is the
    sharded one, the resulting spec must lose that mesh axis entirely (not
    shift it onto a surviving dim), and when the dropped axis is unsharded
    the surviving sharding must stay put."""
    from repro.launch.mesh import make_host_mesh
    cfg = configs.get_config("qwen3-1.7b")
    mesh = make_host_mesh()  # real mesh: opt_state builds NamedShardings
    rules = ShardingRules(cfg, mesh)
    cp = (cfg.n_layers, cfg.d_model, cfg.d_ff)   # w_gate: P(None, None, 'model')
    rp = (cfg.n_layers, cfg.d_ff, cfg.d_model)   # w_down: P(None, 'model', None)
    params = {"blocks": {"w_gate": _Leaf(cp), "w_down": _Leaf(rp)}}
    assert rules.param_spec(("blocks", "w_gate"), _Leaf(cp)) == P(None, None, "model")
    assert rules.param_spec(("blocks", "w_down"), _Leaf(rp)) == P(None, "model", None)
    opt = {"count": _Leaf(()),
           "v": {"blocks": {
               "w_gate": {"vr": _Leaf(cp[:-1]), "vc": _Leaf(cp[:-2] + cp[-1:])},
               "w_down": {"vr": _Leaf(rp[:-1]), "vc": _Leaf(rp[:-2] + rp[-1:])},
           }}}
    out = rules.opt_state(opt, params)
    g, d = out["v"]["blocks"]["w_gate"], out["v"]["blocks"]["w_down"]
    # col-parallel: vr drops the SHARDED last axis -> 'model' gone;
    #               vc drops the unsharded -2 axis -> 'model' survives at -1
    assert g["vr"].spec == P(None, None)
    assert g["vc"].spec == P(None, "model")
    # row-parallel: vr drops the unsharded last axis -> 'model' survives;
    #               vc drops the SHARDED -2 axis -> 'model' gone
    assert d["vr"].spec == P(None, "model")
    assert d["vc"].spec == P(None, None)
    # rank always matches the factored stat's rank (spec never longer)
    for leafs, specs in ((opt["v"]["blocks"], out["v"]["blocks"]),):
        for w in specs:
            for stat in specs[w]:
                assert len(specs[w][stat].spec) == leafs[w][stat].ndim


def test_paged_pool_page_axis_fallback_when_indivisible():
    """pk/pv shard the PAGE axis over the batch axes only when the page
    count divides them — an odd page count must fall back to an unsharded
    page axis (not raise, not emit an indivisible spec)."""
    cfg = configs.get_config("qwen3-1.7b")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    hkv, hd = cfg.n_kv_heads_padded, cfg.head_dim
    lead = (cfg.n_layers,)
    # 32 pages divide the 16-way batch axis: page axis sharded
    spec = rules.cache_spec(("cache", "pk"), _Leaf(lead + (32, 16, hkv, hd)),
                            global_batch=16)
    assert spec[-4] is not None
    _check_divisible(("pk",), _Leaf(lead + (32, 16, hkv, hd)), spec, mesh.shape)
    # 17 pages do NOT divide: clean fallback to an unsharded page axis,
    # every other axis unchanged
    spec = rules.cache_spec(("cache", "pk"), _Leaf(lead + (17, 16, hkv, hd)),
                            global_batch=16)
    assert spec == P(*([None] * len(lead) + [None, None, None, None]))
    # batch itself unsharded (global_batch=1): page axis must not pick up
    # the batch axes either, whatever the page count
    spec = rules.cache_spec(("cache", "pv"), _Leaf(lead + (32, 16, hkv, hd)),
                            global_batch=1)
    assert spec[-4] is None


def test_masked_dense_format_leaf_shards_like_its_weight():
    """A MaskedDense serving leaf has the weight's (lead, d_in, d_out) shape
    and must inherit the weight's TP sharding — the legacy bare-bool masked
    leaf sat AT the stack path and got the weight spec; the format's 'mask'
    field must not silently fall back to replicated."""
    from repro.sparse import formats as F
    cfg = configs.get_config("qwen3-1.7b")
    rules = ShardingRules(cfg, FakeMesh({"data": 2, "model": 2}))
    shape = (cfg.n_layers, cfg.q_dim, cfg.d_model)
    weight_spec = rules.param_spec(("blocks", "wo"), _Leaf(shape))
    legacy_spec = rules.param_spec(("blocks", "wo"), _Leaf(shape))
    fmt_spec = rules.param_spec(("blocks", "wo", "mask"), _Leaf(shape))
    assert fmt_spec == legacy_spec == weight_spec
    assert any(ax is not None for ax in fmt_spec)  # really TP-sharded

    # and through the tree mapper: a serving tree with a MaskedDense node
    tree = {"blocks": {"wo": F.MaskedDense(mask=_Leaf(shape))}}
    specs = _map_with_path(lambda p, l, f: rules.param_spec(p, l, f), tree)
    assert specs["blocks"]["wo"].mask == weight_spec
