"""Pallas condensed-matmul kernels vs the pure-jnp oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import topology
from repro.kernels import condensed_matmul as cm
from repro.kernels import ops, ref


SHAPES = [
    (1, 64, 32, 8),        # online inference (paper Fig. 4a)
    (4, 64, 32, 8),
    (130, 300, 257, 5),    # non-aligned everything
    (256, 3072, 768, 307), # the paper's ViT-B/16 benchmark layer @ 90%
    (8, 128, 128, 1),      # k=1 edge
    (3, 16, 8, 16),        # k == d_in (dense-equivalent)
]


@pytest.mark.parametrize("b,d_in,n_out,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_condensed_matmul_sweep(b, d_in, n_out, k, dtype):
    key = jax.random.PRNGKey(b * 7 + k)
    kx, kw, ki = jax.random.split(key, 3)
    x = jax.random.normal(kx, (b, d_in), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (n_out, k), jnp.float32).astype(dtype)
    idx = jax.random.randint(ki, (n_out, k), 0, d_in)
    y = ops.condensed_linear(x, w, idx)
    # oracle in f32 (the kernel accumulates f32 regardless of input dtype, so
    # a bf16-accumulated oracle would be the LESS accurate side at large k)
    y_ref = ref.condensed_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32), idx)
    if dtype == jnp.float32:
        np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-5, atol=1e-5)
    else:  # bf16 inputs: elementwise products rounded to bf16 before f32 sum
        atol = 0.05 * np.sqrt(k)
        np.testing.assert_allclose(np.array(y, np.float32), np.array(y_ref),
                                   rtol=3e-2, atol=atol)


def test_condensed_matmul_grads_match_oracle():
    key = jax.random.PRNGKey(0)
    b, d_in, n_out, k = 16, 96, 48, 12
    x = jax.random.normal(key, (b, d_in))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    f = lambda x, w: jnp.sum(jnp.tanh(ops.condensed_linear(x, w, idx)))
    g = lambda x, w: jnp.sum(jnp.tanh(ref.condensed_matmul_ref(x, w, idx)))
    gx1, gw1 = jax.grad(f, (0, 1))(x, w)
    gx2, gw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), atol=1e-5)
    np.testing.assert_allclose(np.array(gw1), np.array(gw2), atol=1e-5)


def test_onehot_formulation_equivalent():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 6))
    # distinct indices per row for exact one-hot equivalence
    idx = jnp.stack([jax.random.permutation(jax.random.fold_in(key, i), 64)[:6]
                     for i in range(32)])
    np.testing.assert_allclose(
        np.array(ref.onehot_matmul_ref(x, w, idx)),
        np.array(ref.condensed_matmul_ref(x, w, idx)), atol=1e-4)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_condensed_equals_masked_dense_property(seed):
    """The paper's core identity: condensed(x) == x @ (mask * W)."""
    key = jax.random.PRNGKey(seed)
    d_in, n_out, k = 48, 24, 7
    mask = topology.random_constant_fan_in_mask(key, d_in, n_out, k)
    w_dense = jax.random.normal(jax.random.fold_in(key, 1), (d_in, n_out)) * mask
    x = jax.random.normal(jax.random.fold_in(key, 2), (5, d_in))
    vals, idx = topology.dense_to_condensed(w_dense, mask, k)
    y_cond = ops.condensed_linear(x, vals, idx)
    y_dense = x @ w_dense
    np.testing.assert_allclose(np.array(y_cond), np.array(y_dense), atol=1e-5)


def test_structured_dense_path():
    """Fig. 4 'structured' representation: ablated neurons dropped exactly."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (32, 16))
    active = jnp.arange(16) % 3 != 0
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32))
    y = ops.structured_dense(x, w, active)
    assert np.allclose(np.array(y[:, ~np.array(active)]), 0.0)
    np.testing.assert_allclose(np.array(y[:, np.array(active)]),
                               np.array((x @ w)[:, np.array(active)]), atol=1e-5)


def test_blockspec_padding_paths():
    """Shapes straddling block boundaries exercise the pallas padding logic."""
    for b, n in [(127, 129), (128, 128), (129, 127), (1, 1)]:
        x = jnp.ones((b, 32))
        w = jnp.ones((n, 4))
        idx = jnp.zeros((n, 4), jnp.int32)
        y = cm.condensed_matmul(x, w, idx, block_b=128, block_n=128, interpret=True)
        assert y.shape == (b, n)
        np.testing.assert_allclose(np.array(y), 4.0)


# ---------------------------------------------------------------------------
# hardened edge/property coverage: dw kernel, non-aligned blocks, bf16 accum,
# duplicate indices
# ---------------------------------------------------------------------------

DW_SHAPES = [
    (130, 300, 257, 5),   # b % block_b != 0 AND n_out % block_n != 0
    (7, 64, 129, 3),      # n_out just past one block
    (128, 96, 128, 1),    # k=1, exactly aligned
    (1, 32, 1, 4),        # single output neuron, single example
]


@pytest.mark.parametrize("b,d_in,n_out,k", DW_SHAPES)
def test_condensed_dw_kernel_vs_oracle(b, d_in, n_out, k):
    key = jax.random.PRNGKey(b * 13 + n_out)
    dy = jax.random.normal(key, (b, n_out))
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d_in))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    dw = cm.condensed_matmul_dw(dy, x, idx, block_n=128, interpret=True)
    dw_ref = ref.condensed_matmul_dw_ref(dy, x, idx)
    assert dw.shape == (n_out, k)
    np.testing.assert_allclose(np.array(dw), np.array(dw_ref), rtol=1e-5,
                               atol=1e-5)


def test_condensed_dw_bf16_accumulates_f32():
    """bf16 dy/x: gradient comes back f32 (values_dtype) and is close to the
    f32 oracle — the kernel upcasts before the batch reduction, so the error
    is one bf16 rounding per operand, not O(sqrt(B)) accumulation drift."""
    b, d_in, n_out, k = 512, 64, 32, 8
    key = jax.random.PRNGKey(0)
    dy = jax.random.normal(key, (b, n_out)).astype(jnp.bfloat16)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d_in)).astype(jnp.bfloat16)
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    dw = cm.condensed_matmul_dw(dy, x, idx, interpret=True)
    assert dw.dtype == jnp.float32
    dw_ref = ref.condensed_matmul_dw_ref(dy.astype(jnp.float32),
                                         x.astype(jnp.float32), idx)
    # inputs rounded to bf16 once; the f32-accumulated result stays within a
    # few bf16 ulps of the f32 oracle even at B=512
    np.testing.assert_allclose(np.array(dw), np.array(dw_ref), rtol=3e-2,
                               atol=0.15 * np.sqrt(b) / 8)


def test_condensed_fwd_duplicate_indices():
    """Duplicate indices within a neuron are summed, matching the oracle and
    the scatter-based one-hot formulation (a neuron may reference the same
    input feature twice after export padding)."""
    x = jnp.arange(1, 13, dtype=jnp.float32).reshape(3, 4)
    w = jnp.array([[2.0, 3.0, 0.5], [1.0, 1.0, 1.0]])
    idx = jnp.array([[1, 1, 3], [0, 0, 0]])  # heavy duplication
    y = ops.condensed_linear(x, w, idx)
    y_ref = ref.condensed_matmul_ref(x, w, idx)
    y_onehot = ref.onehot_matmul_ref(x, w, idx)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), atol=1e-6)
    np.testing.assert_allclose(np.array(y_ref), np.array(y_onehot), atol=1e-6)
    # hand-check one entry: neuron 0, example 0: 2*x[1] + 3*x[1] + 0.5*x[3]
    assert float(y[0, 0]) == pytest.approx(2 * 2 + 3 * 2 + 0.5 * 4)


def test_condensed_dw_duplicate_indices():
    """dw gathers (never scatters), so duplicate indices each get their own
    gradient entry: dw[n, j] = sum_b dy[b, n] * x[b, idx[n, j]] independently."""
    key = jax.random.PRNGKey(4)
    dy = jax.random.normal(key, (6, 2))
    x = jax.random.normal(jax.random.fold_in(key, 1), (6, 5))
    idx = jnp.array([[2, 2, 2], [0, 4, 4]])
    dw = cm.condensed_matmul_dw(dy, x, idx, interpret=True)
    np.testing.assert_allclose(np.array(dw),
                               np.array(ref.condensed_matmul_dw_ref(dy, x, idx)),
                               atol=1e-5)
    # duplicated columns carry identical gradients
    np.testing.assert_allclose(np.array(dw[0, 0]), np.array(dw[0, 1]), atol=1e-6)


@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_condensed_fwd_dw_property_nonaligned(seed, b_off, n_off):
    """fwd and dw match the oracle for shapes straddling block boundaries in
    both grid dimensions simultaneously (block_b=block_n=32 here to keep the
    interpret-mode sweep fast while still crossing block edges)."""
    key = jax.random.PRNGKey(seed)
    b, d_in, n_out, k = 32 + b_off, 40, 32 + n_off, 4
    x = jax.random.normal(key, (b, d_in))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    y = cm.condensed_matmul(x, w, idx, block_b=32, block_n=32, interpret=True)
    np.testing.assert_allclose(np.array(y),
                               np.array(ref.condensed_matmul_ref(x, w, idx)),
                               rtol=1e-5, atol=1e-5)
    dy = jax.random.normal(jax.random.fold_in(key, 3), (b, n_out))
    dw = cm.condensed_matmul_dw(dy, x, idx, block_n=32, interpret=True)
    np.testing.assert_allclose(np.array(dw),
                               np.array(ref.condensed_matmul_dw_ref(dy, x, idx)),
                               rtol=1e-5, atol=1e-5)


def test_condensed_linear_nd_leading_dims():
    """Rank-polymorphic wrapper: (B, T, d) and (d,) inputs agree with the 2-D
    kernel — the decode path calls it on (B, 1, d) activations."""
    key = jax.random.PRNGKey(2)
    d_in, n_out, k = 24, 16, 5
    w = jax.random.normal(key, (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (n_out, k), 0, d_in)
    x = jax.random.normal(jax.random.fold_in(key, 2), (3, 7, d_in))
    y = ops.condensed_linear_nd(x, w, idx)
    assert y.shape == (3, 7, n_out)
    y2 = ops.condensed_linear(x.reshape(-1, d_in), w, idx).reshape(3, 7, n_out)
    np.testing.assert_allclose(np.array(y), np.array(y2), atol=1e-6)
