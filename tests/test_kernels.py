"""Pallas condensed-matmul kernels vs the pure-jnp oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.kernels import condensed_matmul as cm
from repro.kernels import ops, ref


SHAPES = [
    (1, 64, 32, 8),        # online inference (paper Fig. 4a)
    (4, 64, 32, 8),
    (130, 300, 257, 5),    # non-aligned everything
    (256, 3072, 768, 307), # the paper's ViT-B/16 benchmark layer @ 90%
    (8, 128, 128, 1),      # k=1 edge
    (3, 16, 8, 16),        # k == d_in (dense-equivalent)
]


@pytest.mark.parametrize("b,d_in,n_out,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_condensed_matmul_sweep(b, d_in, n_out, k, dtype):
    key = jax.random.PRNGKey(b * 7 + k)
    kx, kw, ki = jax.random.split(key, 3)
    x = jax.random.normal(kx, (b, d_in), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (n_out, k), jnp.float32).astype(dtype)
    idx = jax.random.randint(ki, (n_out, k), 0, d_in)
    y = ops.condensed_linear(x, w, idx)
    # oracle in f32 (the kernel accumulates f32 regardless of input dtype, so
    # a bf16-accumulated oracle would be the LESS accurate side at large k)
    y_ref = ref.condensed_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32), idx)
    if dtype == jnp.float32:
        np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-5, atol=1e-5)
    else:  # bf16 inputs: elementwise products rounded to bf16 before f32 sum
        atol = 0.05 * np.sqrt(k)
        np.testing.assert_allclose(np.array(y, np.float32), np.array(y_ref),
                                   rtol=3e-2, atol=atol)


def test_condensed_matmul_grads_match_oracle():
    key = jax.random.PRNGKey(0)
    b, d_in, n_out, k = 16, 96, 48, 12
    x = jax.random.normal(key, (b, d_in))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    f = lambda x, w: jnp.sum(jnp.tanh(ops.condensed_linear(x, w, idx)))
    g = lambda x, w: jnp.sum(jnp.tanh(ref.condensed_matmul_ref(x, w, idx)))
    gx1, gw1 = jax.grad(f, (0, 1))(x, w)
    gx2, gw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), atol=1e-5)
    np.testing.assert_allclose(np.array(gw1), np.array(gw2), atol=1e-5)


def test_onehot_formulation_equivalent():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 6))
    # distinct indices per row for exact one-hot equivalence
    idx = jnp.stack([jax.random.permutation(jax.random.fold_in(key, i), 64)[:6]
                     for i in range(32)])
    np.testing.assert_allclose(
        np.array(ref.onehot_matmul_ref(x, w, idx)),
        np.array(ref.condensed_matmul_ref(x, w, idx)), atol=1e-4)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_condensed_equals_masked_dense_property(seed):
    """The paper's core identity: condensed(x) == x @ (mask * W)."""
    key = jax.random.PRNGKey(seed)
    d_in, n_out, k = 48, 24, 7
    mask = topology.random_constant_fan_in_mask(key, d_in, n_out, k)
    w_dense = jax.random.normal(jax.random.fold_in(key, 1), (d_in, n_out)) * mask
    x = jax.random.normal(jax.random.fold_in(key, 2), (5, d_in))
    vals, idx = topology.dense_to_condensed(w_dense, mask, k)
    y_cond = ops.condensed_linear(x, vals, idx)
    y_dense = x @ w_dense
    np.testing.assert_allclose(np.array(y_cond), np.array(y_dense), atol=1e-5)


def test_structured_dense_path():
    """Fig. 4 'structured' representation: ablated neurons dropped exactly."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (32, 16))
    active = jnp.arange(16) % 3 != 0
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32))
    y = ops.structured_dense(x, w, active)
    assert np.allclose(np.array(y[:, ~np.array(active)]), 0.0)
    np.testing.assert_allclose(np.array(y[:, np.array(active)]),
                               np.array((x @ w)[:, np.array(active)]), atol=1e-5)


def test_blockspec_padding_paths():
    """Shapes straddling block boundaries exercise the pallas padding logic."""
    for b, n in [(127, 129), (128, 128), (129, 127), (1, 1)]:
        x = jnp.ones((b, 32))
        w = jnp.ones((n, 4))
        idx = jnp.zeros((n, 4), jnp.int32)
        y = cm.condensed_matmul(x, w, idx, block_b=128, block_n=128, interpret=True)
        assert y.shape == (b, n)
        np.testing.assert_allclose(np.array(y), 4.0)
