"""Self-draft speculative decoding: bitwise token identity with the
non-speculative greedy engine, under every rollback edge case the paged
rewind can hit.

The acceptance criteria made executable:

* speculative greedy output is BITWISE identical to plain greedy decode on
  every format-typed path (the protocol guarantee: verify rewrites every
  drafted slot with target-weight K/V before attending, commits only the
  agreed prefix plus the target's own next token);
* identity survives the rewind edge cases — a draft that is ALWAYS wrong
  (every round rejects all gamma guesses and commits exactly one token), a
  pool too starved to grant any overshoot page (draft/verify writes clamp
  into the garbage page; commits are capped at held-page capacity, i.e.
  rejection at a page boundary), and mid-generation admission interleaved
  with speculative rollback rounds;
* the zero-extra-weight-residency contract: every value buffer of the
  draft tree IS (by identity) a buffer of the target serving tree;
* a live-sync weight update adopted between speculative rounds invalidates
  the cached draft trees and the post-update stream is bitwise identical
  to a non-speculative engine refreshed at the same committed length.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import engine as ENG
from repro.launch import speculative as SP
from repro.models import model as M
from repro.models import paged as PG
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG
from repro.sync import DirChannel, Publisher, Subscriber, engine_from_snapshot


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    return cfg, reg, params, masks


def _prompts(b, t, seed=1, vocab=512):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, vocab)


def _serve_one(eng, prompts, gen_len):
    rid = eng.submit(prompts, gen_len)
    eng.step()
    [res] = eng.retire(rid)
    return res


# ---------------------------------------------------------------------------
# bitwise identity on plain runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", ["condensed", "structured"])
def test_spec_tokens_bitwise_identical(smoke_setup, path):
    """Speculative greedy == non-speculative greedy, token for token, and
    the spec stats land in ``Result.spec`` (fixed path: pricing may not
    favour the draft at smoke dims, ``force`` runs it anyway)."""
    cfg, reg, params, masks = smoke_setup
    prompts = _prompts(2, 8, seed=3, vocab=cfg.vocab_size)
    base = ENG.ServingEngine(cfg, params, masks, reg, path=path)
    ref = _serve_one(base, prompts, 10)
    assert ref.spec is None

    spec = ENG.ServingEngine(
        cfg, params, masks, reg, path=path,
        speculative=SP.SpecConfig(gamma=3, draft_ablation=0.5, force=True))
    res = _serve_one(spec, prompts, 10)
    assert np.array_equal(np.asarray(res.tokens), np.asarray(ref.tokens))
    assert res.spec is not None
    assert res.spec["committed"] == 2 * 10
    assert res.spec["rounds"] >= 1
    # every round verifies ONCE for >= 1 committed token per stream
    assert res.spec["full_dispatches_per_token"] <= 1.0


def test_draft_tree_shares_every_value_buffer(smoke_setup):
    """Zero extra weight residency: the draft plan's value/scale buffers
    are the target plan's buffers BY IDENTITY, for every stack."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(
        cfg, params, masks, reg, path="condensed",
        speculative=SP.SpecConfig(gamma=2, draft_ablation=0.5, force=True))
    key = eng.plan_key(2)
    draft = eng.draft_tree_for(key)
    assert draft is not None
    target = eng.serving_tree_for(key)
    shared, extra = PLAN.draft_weight_overhead_bytes(reg, target, draft)
    assert extra == 0
    assert shared > 0


# ---------------------------------------------------------------------------
# rewind edge cases
# ---------------------------------------------------------------------------

def test_all_gamma_drafts_rejected_every_round(smoke_setup, monkeypatch):
    """A pathologically wrong draft (its guesses are corrupted after the
    dispatch) forces the all-reject path: nearly every round commits
    exactly ONE token (the target's own), the drafted KV is rewound every
    round, and the output is STILL bitwise identical — speculation must
    never be able to corrupt the stream, only fail to accelerate it."""
    cfg, reg, params, masks = smoke_setup
    prompts = _prompts(2, 8, seed=5, vocab=cfg.vocab_size)
    base = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    ref = _serve_one(base, prompts, 8)

    real = SP.draft_dispatch

    def bad_draft(cfg_, params_, tree, pool, table, lengths, cur, gamma):
        drafted, pool, dt, cold = real(cfg_, params_, tree, pool, table,
                                       lengths, cur, gamma)
        return (drafted + 1) % cfg_.vocab_size, pool, dt, cold

    monkeypatch.setattr(ENG.SP, "draft_dispatch", bad_draft)
    spec = ENG.ServingEngine(
        cfg, params, masks, reg, path="condensed",
        speculative=SP.SpecConfig(gamma=3, draft_ablation=0.5, force=True))
    res = _serve_one(spec, prompts, 8)
    assert np.array_equal(np.asarray(res.tokens), np.asarray(ref.tokens))
    # the corrupted draft tokens (x+1 mod V) almost never coincide with the
    # target's argmax: acceptance collapses and rounds approach one-per-token
    assert res.spec["acceptance_rate"] < 0.2
    assert res.spec["rounds"] >= 8 - 1


def test_overshoot_into_garbage_page_and_boundary_rejection(smoke_setup,
                                                            monkeypatch):
    """Starve the allocator after admission so NO overshoot page is ever
    granted: with block_size=2 the gamma+1 verify window is guaranteed to
    overrun the held pages in the final rounds — writes clamp into the
    garbage page, the commit is capped at held capacity (a rejection
    pinned exactly at the page boundary, down to the commit-one floor),
    and the stream must still finish bitwise identical."""
    cfg, reg, params, masks = smoke_setup
    prompts = _prompts(2, 8, seed=7, vocab=cfg.vocab_size)
    base = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                             block_size=2)
    ref = _serve_one(base, prompts, 6)

    real_alloc = PG.BlockAllocator.alloc
    admissions = {"left": 2}            # one alloc call per admitted row

    def starved(self, n):
        if admissions["left"] <= 0:
            raise RuntimeError("paged KV pool exhausted (test starvation)")
        admissions["left"] -= 1
        return real_alloc(self, n)

    monkeypatch.setattr(PG.BlockAllocator, "alloc", starved)
    spec = ENG.ServingEngine(
        cfg, params, masks, reg, path="condensed", block_size=2,
        speculative=SP.SpecConfig(gamma=3, draft_ablation=0.5, force=True))
    res = _serve_one(spec, prompts, 6)
    assert np.array_equal(np.asarray(res.tokens), np.asarray(ref.tokens))
    # capacity capping costs extra rounds but never correctness
    assert res.spec["committed"] == 2 * 6


def test_mid_generation_admission_interleaves_with_rollback(smoke_setup):
    """A second request is admitted BETWEEN speculative rounds of the
    first (continuous batching: ``max_chunks=1`` hands control back after
    every round). Admission must not disturb in-flight rollback state and
    both streams finish bitwise identical to the plain engine."""
    cfg, reg, params, masks = smoke_setup
    pa = _prompts(1, 8, seed=11, vocab=cfg.vocab_size)
    pb = _prompts(1, 8, seed=13, vocab=cfg.vocab_size)

    base = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    ra = _serve_one(base, pa, 10)
    rb = _serve_one(base, pb, 6)

    spec = ENG.ServingEngine(
        cfg, params, masks, reg, path="condensed",
        speculative=SP.SpecConfig(gamma=3, draft_ablation=0.5, force=True))
    rid_a = spec.submit(pa, 10)
    spec.step(max_chunks=2)             # a mid-generation, rollbacks live
    rid_b = spec.submit(pb, 6)          # joins at the next round boundary
    for _ in range(32):
        spec.step(max_chunks=1)
        if len(spec._done) == 2:
            break
    [res_a] = spec.retire(rid_a)
    [res_b] = spec.retire(rid_b)
    assert np.array_equal(np.asarray(res_a.tokens), np.asarray(ra.tokens))
    assert np.array_equal(np.asarray(res_b.tokens), np.asarray(rb.tokens))


# ---------------------------------------------------------------------------
# live-sync interleaving
# ---------------------------------------------------------------------------

def test_sync_update_between_spec_rounds_stays_bitwise(smoke_setup,
                                                       tmp_path):
    """A published weight update adopted between speculative rounds: the
    cached draft trees are invalidated BEFORE the donation runs, the draft
    re-derives from the new serving tree, and the full stream is bitwise
    identical to a NON-speculative engine refreshed with the same weights
    at the same committed length."""
    cfg, reg, params, masks = smoke_setup
    versions = {s.name: 0 for s in reg}
    prompts = _prompts(2, 8, seed=17, vocab=cfg.vocab_size)
    ch = DirChannel(str(tmp_path))
    pub = Publisher(cfg, reg, ch, path="condensed", batch_size=2)
    pub.publish(params=params, masks=masks, mask_versions=versions)

    sub = Subscriber(ch.subscribe("r0"))
    eng = engine_from_snapshot(
        cfg, sub, registry=reg,
        speculative=SP.SpecConfig(gamma=3, draft_ablation=0.5, force=True))
    rid = eng.submit(prompts, 16)
    eng.step(max_chunks=2)              # two spec rounds on gen-1 weights
    key = eng.plan_key(prompts.shape[0])
    runner = eng._runners[key]
    committed = int(runner.lengths[runner.active[rid].rows[0]]) - 8
    assert 2 <= committed <= 8
    old_draft = eng.draft_tree_for(key)
    assert old_draft is not None

    # publish a topology + values update; the engine adopts it at the next
    # round boundary inside step()
    s0 = reg[0]
    masks2 = jax.tree_util.tree_map(lambda x: x, masks)
    REG.set_path(masks2, s0.path,
                 jnp.roll(REG.get_path(masks2, s0.path), 1, axis=-2))
    params2 = jax.tree_util.tree_map(
        lambda x: x * 1.01 if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
    versions2 = dict(versions)
    versions2[s0.name] += 1
    pub.publish(params=params2, masks=masks2, mask_versions=versions2)
    eng.step()
    [res] = eng.retire(rid)
    assert eng._sync_generation == 2
    assert eng.draft_tree_for(key) is not old_draft   # re-derived post-sync
    assert res.spec["committed"] == 2 * 16

    # reference: NON-speculative engine, gen_chunk=1 so the refresh lands
    # at exactly the same committed length
    eng2 = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                             mask_versions=dict(versions), gen_chunk=1)
    rid2 = eng2.submit(prompts, 16)
    eng2.step(max_chunks=committed)
    eng2.refresh(params2, masks2, versions2, donate=False)
    eng2.step()
    [res2] = eng2.retire(rid2)
    assert res2.spec is None
    assert np.array_equal(np.asarray(res.tokens), np.asarray(res2.tokens))


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_speculative_rejects_masked_and_unpaged(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    sc = SP.SpecConfig(gamma=2, draft_ablation=0.5)
    with pytest.raises(ValueError, match="masked"):
        ENG.ServingEngine(cfg, params, masks, reg, path="masked",
                          speculative=sc)
    with pytest.raises(ValueError, match="paged"):
        ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                          paged=False, speculative=sc)


def test_auto_path_can_decline_speculation(smoke_setup):
    """``--path auto`` without force: the cost model prices the draft
    against the target (at smoke dims lane padding makes the draft no
    cheaper), declines, and the engine serves plain decode — with the
    estimate still inspectable."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(
        cfg, params, masks, reg, path="auto",
        speculative=SP.SpecConfig(gamma=3, draft_ablation=0.5, force=False))
    prompts = _prompts(2, 8, seed=19, vocab=cfg.vocab_size)
    res = _serve_one(eng, prompts, 6)
    est = eng.spec_estimate_for(res.plan_key)
    assert est is not None
    if eng.draft_tree_for(res.plan_key) is None:
        assert not est.worthwhile
        assert res.spec is None
    else:
        assert res.spec is not None
