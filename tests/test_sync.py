"""Live train->serve sync (repro.sync): wire format, generation handshake,
engine integration, and the refresh-path satellites.

The acceptance criteria made executable:

* every ``formats.py`` dataclass round-trips the wire (including quantized
  ``values_dtype`` and ``tp``-sharded layouts); corrupt blobs are rejected;
* a subscriber fed an ADVERSARIAL stream — duplicated, reordered, one
  dropped delta forcing a resync — converges bitwise to the publisher's
  latest state, for f32, int8-quantized, and tp-layout leaves (property
  tests via the hypothesis compat shim);
* a live ``ServingEngine`` applies a topology delta mid-generation with no
  recompile of unchanged plan keys, the old buffers donated (asserted via
  ``.is_deleted()``), and token output identical to an engine refreshed
  from the same updated weights at the same chunk boundary — and a fresh
  replica restarted from the updated snapshot serves identically;
* satellite 1: a no-op ``Plan.refresh`` with host-side cached versions does
  ZERO blocking device fetches (device_get call-counted);
* satellite 2: ``ServingEngine.refresh`` re-exports each changed stack ONCE
  across all cached plan keys and the plans share the resulting leaf
  objects.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.launch import engine as ENG
from repro.models import model as M
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG
from repro.sync import (DirChannel, Publisher, QueueChannel, Subscriber,
                        engine_from_snapshot)
from repro.sync import delta as D


# ---------------------------------------------------------------------------
# synthetic two-stack world (fast: no model, just trees)
# ---------------------------------------------------------------------------

class _Cfg:
    param_dtype = jnp.float32


def _tiny_registry():
    return [REG.SparseStack(path=("blk0", "w"), d_in=16, d_out=8, lead=(),
                            density=0.5),
            REG.SparseStack(path=("blk1", "w"), d_in=12, d_out=8, lead=(2,),
                            density=0.5)]


def _random_masks(reg, rng, k=4):
    """Constant fan-in k boolean masks (valid SRigL topologies)."""
    masks = {}
    for s in reg:
        shape = tuple(s.lead) + (s.d_in, s.d_out)
        m = np.zeros(shape, dtype=bool)
        flat = m.reshape(-1, s.d_in, s.d_out)
        for r in range(flat.shape[0]):
            for c in range(s.d_out):
                rows = rng.choice(s.d_in, size=k, replace=False)
                flat[r, rows, c] = True
        REG.set_path(masks, s.path, jnp.asarray(m))
    return masks


def _random_params(reg, rng):
    params = {}
    for s in reg:
        shape = tuple(s.lead) + (s.d_in, s.d_out)
        REG.set_path(params, s.path,
                     jnp.asarray(rng.standard_normal(shape),
                                 dtype=jnp.float32))
    params["emb"] = jnp.asarray(rng.standard_normal((4, 6)),
                                dtype=jnp.float32)
    return params


def _evolve(reg, params, masks, rng, *, rewire: bool = True):
    """One synthetic training step: perturb every weight; optionally rewire
    one stack's topology at constant fan-in (roll along the input axis)."""
    params = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(rng.standard_normal(x.shape) * 0.1,
                                  x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    changed = []
    if rewire:
        s = reg[rng.integers(len(reg))]
        m = REG.get_path(masks, s.path)
        masks = jax.tree_util.tree_map(lambda x: x, masks)
        REG.set_path(masks, s.path,
                     jnp.roll(m, int(rng.integers(1, 4)), axis=-2))
        changed = [s.name]
    return params, masks, changed


def _leaves_bitwise_equal(sub: Subscriber, pub: Publisher, reg) -> bool:
    host = jax.device_get(
        {s.name: REG.get_path(pub._plan.serving_tree, s.path) for s in reg})
    for s in reg:
        rec = sub.leaves[s.name]
        leaf = host[s.name]
        for f in leaf._array_fields:
            mine = rec.arrays.get(f)
            theirs = getattr(leaf, f)
            if (mine is None) != (theirs is None):
                return False
            if mine is not None and not np.array_equal(
                    mine, np.asarray(theirs)):
                return False
    return np.array_equal(sub.params["emb"],
                          np.asarray(pub._params["emb"]))


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def test_wire_roundtrip_every_format():
    """Every formats.py dataclass — incl. quantized values_dtype, tp shards
    and None optional fields — survives encode/decode bitwise."""
    leaves = {
        "masked": F.MaskedDense(mask=jnp.asarray(
            np.random.default_rng(0).random((4, 6)) > 0.5),
            weight_itemsize=4),
        "structured": F.StructuredFanIn(
            neuron_active=jnp.asarray([True, False, True, True]),
            active_index=jnp.asarray([0, 2, 3, 0], jnp.int32),
            d_in=6, weight_itemsize=4),
        "condensed": F.Condensed(
            values=jnp.ones((8, 3), jnp.int8),
            indices=jnp.zeros((8, 3), jnp.int32), d_in=16,
            scales=jnp.full((8,), 0.5, jnp.float32),
            values_dtype="int8", tp=4),
        "condensed_over_active": F.CondensedOverActive(
            values=jnp.ones((2, 5, 3), jnp.float32),
            indices=jnp.zeros((2, 5, 3), jnp.int32),
            out_index=jnp.zeros((2, 5), jnp.int32),
            d_in=16, d_out=8, scales=None, values_dtype=None, tp=1),
    }
    recs = [D.leaf_to_wire(name, 7, jax.device_get(leaf))
            for name, leaf in leaves.items()]
    blob = D.encode(D.Delta(generation=3, stacks=recs,
                            dense={"emb": np.arange(6, dtype=np.float32)}))
    back = D.decode(blob)
    assert back.generation == 3
    assert np.array_equal(back.dense["emb"], np.arange(6, dtype=np.float32))
    for rec in back.stacks:
        orig = leaves[rec.name]
        rebuilt = D.wire_to_leaf(rec)
        assert type(rebuilt) is type(orig)
        for f in orig._static_fields:
            assert getattr(rebuilt, f) == getattr(orig, f)
        for f in orig._array_fields:
            a, b = getattr(orig, f), getattr(rebuilt, f)
            assert (a is None) == (b is None)
            if a is not None:
                assert a.dtype == b.dtype
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_wire_roundtrip_bf16_values():
    if not hasattr(jnp, "bfloat16"):
        pytest.skip("no bfloat16 in this jax build")
    leaf = F.Condensed(values=jnp.ones((4, 2), jnp.bfloat16),
                       indices=jnp.zeros((4, 2), jnp.int32), d_in=8)
    rec = D.leaf_to_wire("x", 0, jax.device_get(leaf))
    back = D.decode(D.encode(D.Delta(generation=1, stacks=[rec], dense={})))
    rebuilt = D.wire_to_leaf(back.stacks[0])
    assert rebuilt.values.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(rebuilt.values, dtype=np.float32),
                          np.ones((4, 2), np.float32))


def test_corrupt_and_truncated_blobs_rejected():
    leaf = F.Condensed(values=jnp.ones((4, 2)), d_in=8,
                       indices=jnp.zeros((4, 2), jnp.int32))
    blob = D.encode(D.Delta(generation=1, stacks=[
        D.leaf_to_wire("x", 0, jax.device_get(leaf))], dense={}))
    # flipped payload byte -> checksum catches it
    bad = bytearray(blob)
    bad[len(bad) // 2] ^= 0xFF
    with pytest.raises(D.DeltaCorruptError):
        D.decode(bytes(bad))
    with pytest.raises(D.DeltaCorruptError):
        D.decode(blob[:-7])            # truncated
    with pytest.raises(D.DeltaCorruptError):
        D.decode(b"NOPE" + blob[4:])   # bad magic
    # a subscriber counts + drops instead of raising
    class _Feed:
        def __init__(self, blobs): self._b = list(blobs)
        def recv_new(self):
            out, self._b = self._b, []
            return out
        def request_resync(self, reason, needed_generation=None): pass
    sub = Subscriber(_Feed([bytes(bad), blob]))
    sub.poll()
    assert sub.counters["corrupt"] == 1


# ---------------------------------------------------------------------------
# adversarial delta streams (property tests)
# ---------------------------------------------------------------------------

class _ScriptedFeed:
    """Subscription stub replaying a hand-scrambled blob schedule."""

    def __init__(self):
        self.queue: list[bytes] = []
        self.resyncs: list[str] = []

    def recv_new(self):
        out, self.queue = self.queue, []
        return out

    def request_resync(self, reason: str = "",
                       needed_generation: int | None = None):
        self.resyncs.append(reason)


def _publish_run(rng, *, values_dtype=None, tp=1, n_gens=4):
    """Publish a snapshot + n_gens deltas on a QueueChannel; return the
    publisher and the raw blob list in publish order."""
    reg = _tiny_registry()
    params = _random_params(reg, rng)
    masks = _random_masks(reg, rng)
    versions = {s.name: 0 for s in reg}
    ch = QueueChannel()
    pub = Publisher(_Cfg(), reg, ch, path="condensed",
                    values_dtype=values_dtype, tp=tp)
    pub.publish(params=params, masks=masks, mask_versions=versions)
    for g in range(n_gens):
        params, masks, changed = _evolve(reg, params, masks, rng,
                                         rewire=(g % 2 == 0))
        for name in changed:
            versions[name] += 1
        pub.publish(params=params, masks=masks, mask_versions=versions)
    blobs = [blob for _, blob in ch._log]
    return pub, reg, blobs


def _adversarial_converges(seed: int, *, values_dtype=None, tp=1) -> None:
    rng = np.random.default_rng(seed)
    pub, reg, blobs = _publish_run(rng, values_dtype=values_dtype, tp=tp)
    snapshot, deltas = blobs[0], blobs[1:]
    # adversarial schedule: shuffle, duplicate one, DROP one (forces a gap)
    sched = list(deltas)
    drop = int(rng.integers(len(sched)))
    dup = sched[int(rng.integers(len(sched)))]
    del sched[drop]
    sched.append(dup)
    rng.shuffle(sched)
    # generations: snapshot=1, deltas 2..n+1; a drop below the stream's max
    # is OBSERVABLE (later deltas reveal the hole); dropping the newest is
    # not — the subscriber only learns of it from future traffic/resync
    dropped_gen = drop + 2
    observable_gap = dropped_gen < 1 + len(deltas)

    feed = _ScriptedFeed()
    sub = Subscriber(feed, name=f"adv{seed}")
    feed.queue = [snapshot] + sched
    sub.poll()
    if sub.generation != pub.generation:
        if observable_gap:
            assert feed.resyncs, "observable gap did not request a resync"
        # the ISSUE's "plus one resync": answer with the latest snapshot
        pub.channel._requests.append({"subscriber": sub.name})
        pub.serve_resyncs()
        feed.queue = [pub.channel._log[-1][1]]
        sub.poll()
    assert sub.generation == pub.generation
    assert _leaves_bitwise_equal(sub, pub, reg)
    # replaying the whole scrambled history again must be a no-op
    before = dict(sub.counters)
    feed.queue = list(sched)
    sub.poll()
    assert sub.generation == pub.generation
    assert sub.counters["applied_deltas"] == before["applied_deltas"]
    assert _leaves_bitwise_equal(sub, pub, reg)


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=6, deadline=None)
def test_adversarial_stream_converges_f32(seed):
    _adversarial_converges(seed)


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=6, deadline=None)
def test_adversarial_stream_converges_int8(seed):
    _adversarial_converges(seed, values_dtype="int8")


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=6, deadline=None)
def test_adversarial_stream_converges_tp2(seed):
    _adversarial_converges(seed, tp=2)


def test_deltas_before_bootstrap_request_resync():
    rng = np.random.default_rng(0)
    pub, reg, blobs = _publish_run(rng)
    feed = _ScriptedFeed()
    sub = Subscriber(feed)
    feed.queue = blobs[1:]          # deltas only, no snapshot
    sub.poll()
    assert sub.generation is None
    assert feed.resyncs
    feed.queue = [blobs[0]] + blobs[1:]
    sub.poll()
    assert sub.generation == pub.generation
    assert _leaves_bitwise_equal(sub, pub, reg)


def test_incoherent_delta_rejected_all_or_nothing():
    """A delta whose stack set does not match the replica's is rejected
    WITHOUT mutating anything (all-or-nothing commit)."""
    rng = np.random.default_rng(1)
    pub, reg, blobs = _publish_run(rng, n_gens=1)
    feed = _ScriptedFeed()
    sub = Subscriber(feed)
    feed.queue = [blobs[0]]
    sub.poll()
    gen0, leaves0 = sub.generation, dict(sub.leaves)
    # doctor the delta: drop one stack's record, re-encode
    delta = D.decode(blobs[1])
    delta.stacks = delta.stacks[:1]
    feed.queue = [D.encode(delta)]
    sub.poll()
    assert sub.counters["rejected"] == 1
    assert sub.generation == gen0
    assert all(sub.leaves[k] is leaves0[k] for k in leaves0)
    assert feed.resyncs              # fell back to a resync request


def test_values_only_deltas_are_smaller_than_topology():
    rng = np.random.default_rng(2)
    reg = _tiny_registry()
    params = _random_params(reg, rng)
    masks = _random_masks(reg, rng)
    versions = {s.name: 0 for s in reg}
    ch = QueueChannel()
    pub = Publisher(_Cfg(), reg, ch, path="condensed")
    snap = pub.publish(params=params, masks=masks, mask_versions=versions)
    params2, _, _ = _evolve(reg, params, masks, rng, rewire=False)
    vals = pub.publish(params=params2, masks=masks, mask_versions=versions)
    params3, masks3, changed = _evolve(reg, params2, masks, rng, rewire=True)
    versions2 = dict(versions)
    for name in changed:
        versions2[name] += 1
    topo = pub.publish(params=params3, masks=masks3,
                       mask_versions=versions2)
    assert vals["kind"] == topo["kind"] == "delta"
    assert vals["topology"] == [] and topo["topology"] == changed
    assert vals["topology_bytes"] == 0
    assert vals["bytes"] < topo["bytes"] < snap["bytes"]


def test_publisher_rejects_live_weight_paths():
    with pytest.raises(ValueError):
        Publisher(_Cfg(), _tiny_registry(), QueueChannel(), path="masked")
    with pytest.raises(ValueError):
        Publisher(_Cfg(), _tiny_registry(), QueueChannel(), path="auto")


# ---------------------------------------------------------------------------
# DirChannel (multi-process transport)
# ---------------------------------------------------------------------------

def test_dir_channel_pubsub_and_pruned_gap_resync(tmp_path):
    """File transport end-to-end: tail the dir, then a pruned-away delta
    (slow subscriber) forces the gap->resync path and still converges."""
    rng = np.random.default_rng(3)
    reg = _tiny_registry()
    params = _random_params(reg, rng)
    masks = _random_masks(reg, rng)
    versions = {s.name: 0 for s in reg}
    ch = DirChannel(str(tmp_path), retain=2)    # aggressive pruning
    pub = Publisher(_Cfg(), reg, ch, path="condensed")
    pub.publish(params=params, masks=masks, mask_versions=versions)
    sub = Subscriber(ch.subscribe("r0"), name="r0")
    assert sub.wait_for_bootstrap(timeout=5.0)
    assert sub.generation == 1
    # publish 4 generations while the subscriber sleeps; retain=2 prunes
    # the middle deltas off disk -> guaranteed gap on next poll
    for g in range(4):
        params, masks, changed = _evolve(reg, params, masks, rng,
                                         rewire=(g % 2 == 0))
        for name in changed:
            versions[name] += 1
        pub.publish(params=params, masks=masks, mask_versions=versions)
    sub.poll()
    assert sub.counters["gaps"] >= 1
    # the resync request is a FILE the publisher drains on its next publish
    assert pub.serve_resyncs() >= 1
    sub.poll()
    assert sub.generation == pub.generation
    assert _leaves_bitwise_equal(sub, pub, reg)


def test_resync_storm_coalesces_to_one_snapshot():
    """A fleet-wide resync storm (N replicas missing the same generation)
    costs ONE snapshot publish; stragglers asking for an already-covered
    generation cost ZERO. The publisher counters prove the accounting and a
    late subscriber still converges bitwise off the coalesced snapshot."""
    rng = np.random.default_rng(7)
    reg = _tiny_registry()
    params = _random_params(reg, rng)
    masks = _random_masks(reg, rng)
    versions = {s.name: 0 for s in reg}
    ch = QueueChannel()
    pub = Publisher(_Cfg(), reg, ch, path="condensed")
    pub.publish(params=params, masks=masks, mask_versions=versions)
    params, masks, changed = _evolve(reg, params, masks, rng)
    for name in changed:
        versions[name] += 1
    pub.publish(params=params, masks=masks, mask_versions=versions)
    assert pub.generation == 2

    # storm: 8 replicas all gap on generation 2 at once
    sends0 = len(ch._log)
    for i in range(8):
        ch.subscribe(f"r{i}").request_resync(
            "gap at generation 2", needed_generation=2)
    assert pub.serve_resyncs() == 8
    assert pub.counters == {"resync_requests": 8, "resync_snapshots": 1,
                            "resync_coalesced": 7}
    assert len(ch._log) == sends0 + 1      # exactly one record hit the wire

    # stragglers for the SAME missing generation arrive after the publish:
    # the snapshot already on the channel covers them -> no new publish
    for i in range(8, 12):
        ch.subscribe(f"r{i}").request_resync(
            "gap at generation 2", needed_generation=2)
    assert pub.serve_resyncs() == 4
    assert pub.counters["resync_snapshots"] == 1
    assert pub.counters["resync_coalesced"] == 11
    assert len(ch._log) == sends0 + 1

    # a gap at a NEWER generation is NOT covered -> fresh snapshot
    params, masks, changed = _evolve(reg, params, masks, rng)
    for name in changed:
        versions[name] += 1
    pub.publish(params=params, masks=masks, mask_versions=versions)
    ch.subscribe("r0").request_resync(
        "gap at generation 3", needed_generation=3)
    assert pub.serve_resyncs() == 1
    assert pub.counters["resync_snapshots"] == 2

    # convergence off the coalesced stream
    late = Subscriber(ch.subscribe("late"), name="late")
    late.poll()
    assert late.generation == pub.generation
    assert _leaves_bitwise_equal(late, pub, reg)


# ---------------------------------------------------------------------------
# engine integration (real smoke model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    return cfg, reg, params, masks, prompts


def _bump(reg, params, masks, versions, *, stack_idx=0):
    """Rewire one stack at constant fan-in + train every float param."""
    s = reg[stack_idx]
    masks2 = jax.tree_util.tree_map(lambda x: x, masks)
    REG.set_path(masks2, s.path,
                 jnp.roll(REG.get_path(masks2, s.path), 1, axis=-2))
    params2 = jax.tree_util.tree_map(
        lambda x: x * 1.01 if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
    versions2 = dict(versions)
    versions2[s.name] += 1
    return params2, masks2, versions2


def test_engine_mid_generation_sync(smoke_setup, tmp_path):
    """The tentpole acceptance test: a topology delta lands at a paged-chunk
    boundary mid-generation — no recompile of the decode program, old
    buffers donated, tokens identical to an engine refreshed from the same
    updated weights at the same boundary, and a replica restarted from the
    updated snapshot serves new requests identically."""
    cfg, reg, params, masks, prompts = smoke_setup
    versions = {s.name: 0 for s in reg}
    ch = DirChannel(str(tmp_path))
    pub = Publisher(cfg, reg, ch, path="condensed", batch_size=2)
    pub.publish(params=params, masks=masks, mask_versions=versions)

    sub = Subscriber(ch.subscribe("r0"))
    eng = engine_from_snapshot(cfg, sub, registry=reg, gen_chunk=4)
    rid = eng.submit(prompts, 16)
    eng.step(max_chunks=2)          # half the generation on gen-1 weights

    params2, masks2, versions2 = _bump(reg, params, masks, versions)
    info = pub.publish(params=params2, masks=masks2,
                       mask_versions=versions2)
    assert len(info["topology"]) == 1

    key = eng.plan_key(prompts.shape[0])
    plan = eng.plan_for(key)
    changed_name = info["topology"][0]
    s_changed = next(s for s in reg if s.name == changed_name)
    old_leaf = REG.get_path(plan.serving_tree, s_changed.path)
    n_jit = ENG._jit_entries(ENG._paged_decode_chunk)
    ec, vr = plan.export_calls, plan.value_refreshes

    eng.step()                      # drains the delta at the chunk boundary
    [res] = eng.retire(rid)
    assert eng._sync_generation == 2
    # unchanged plan key: adoption kept every aval -> zero recompiles
    assert ENG._jit_entries(ENG._paged_decode_chunk) == n_jit
    # incremental: ONE topology export, values-only for the rest
    assert plan.export_calls == ec + 1
    assert plan.value_refreshes == vr + len(reg) - 1
    # zero weight-memory doubling: the replaced buffers were donated
    assert old_leaf.values.is_deleted()
    assert old_leaf.indices.is_deleted()

    # reference: plain engine, refresh() with the SAME weights at the SAME
    # chunk boundary (donate=False: it shares buffers with the publisher)
    eng2 = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                             mask_versions=dict(versions), gen_chunk=4)
    rid2 = eng2.submit(prompts, 16)
    eng2.step(max_chunks=2)
    eng2.refresh(params2, masks2, versions2, donate=False)
    eng2.step()
    [res2] = eng2.retire(rid2)
    assert np.array_equal(np.asarray(res.tokens), np.asarray(res2.tokens))

    # restart identity: a FRESH replica bootstrapped from the stream (which
    # now includes the update) serves a new request exactly like the live
    # synced engine does post-update
    rid_a = eng.submit(prompts, 8)
    eng.step()
    [res_a] = eng.retire(rid_a)
    sub3 = Subscriber(ch.subscribe("r1"), name="r1")
    eng3 = engine_from_snapshot(cfg, sub3, registry=reg, gen_chunk=4)
    assert eng3._sync_generation in (1, 2)
    rid_b = eng3.submit(prompts, 8)
    eng3.step()
    [res_b] = eng3.retire(rid_b)
    assert eng3._sync_generation == 2
    assert np.array_equal(np.asarray(res_a.tokens),
                          np.asarray(res_b.tokens))


def test_attach_subscriber_rejects_live_weight_paths(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="masked")
    with pytest.raises(ValueError):
        eng.attach_subscriber(Subscriber(_ScriptedFeed()))


# ---------------------------------------------------------------------------
# satellite 1: no-op refresh does zero device syncs
# ---------------------------------------------------------------------------

def _count_device_gets(monkeypatch):
    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    return calls


def test_noop_refresh_zero_device_syncs(smoke_setup, monkeypatch):
    cfg, reg, params, masks, _ = smoke_setup
    versions = {s.name: 0 for s in reg}
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1,
                          path="condensed", mask_versions=versions)
    calls = _count_device_gets(monkeypatch)
    changed = plan.refresh(params, masks, versions, refresh_values=False)
    assert changed == []
    assert calls["n"] == 0, ("no-op refresh with host-cached versions must "
                             "not block on the device")


def test_engine_refresh_single_fused_version_fetch(smoke_setup, monkeypatch):
    """Device counters across N cached plans: exactly ONE fused device_get
    (the version fetch), zero per-plan re-fetches."""
    cfg, reg, params, masks, _ = smoke_setup
    dev_versions = {s.name: jnp.zeros((), jnp.int32) for s in reg}
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            mask_versions=PLAN._host_versions(dev_versions))
    eng.plan_for(eng.plan_key(1))
    eng.plan_for(eng.plan_key(8))
    assert len(eng._plans) == 2
    calls = _count_device_gets(monkeypatch)
    eng.refresh(params, masks, dev_versions, donate=False)
    assert calls["n"] == 1
    # after refresh the engine's cache is host ints: now zero
    calls["n"] = 0
    eng.refresh(params, masks, eng._mask_versions, donate=False)
    assert calls["n"] == 0


# ---------------------------------------------------------------------------
# satellite 2: re-export deduped across plan keys
# ---------------------------------------------------------------------------

def test_refresh_dedupes_export_across_plan_keys(smoke_setup, monkeypatch):
    cfg, reg, params, masks, _ = smoke_setup
    versions = {s.name: 0 for s in reg}
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            mask_versions=dict(versions))
    p1 = eng.plan_for(eng.plan_key(1))
    p8 = eng.plan_for(eng.plan_key(8))
    assert p1 is not p8

    recondense_calls = {"n": 0}
    real = PLAN.COND.recondense_stack_leaf

    def counting(*a, **kw):
        recondense_calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(PLAN.COND, "recondense_stack_leaf", counting)

    params2, masks2, versions2 = _bump(reg, params, masks, versions)
    changed = eng.refresh(params2, masks2, versions2, donate=False)
    changed_names = {n for names in changed.values() for n in names}
    assert len(changed_names) == 1
    # the changed stack re-condensed ONCE, not once per plan key
    assert recondense_calls["n"] == 1
    # and both plans share the exact same leaf objects (topology AND the
    # values-only refreshes)
    for s in reg:
        l1 = REG.get_path(p1.serving_tree, s.path)
        l8 = REG.get_path(p8.serving_tree, s.path)
        assert l1 is l8
