"""Training runtime: optimizer masking, DST-in-the-loop, checkpoint/restart."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import topology
from repro.data.pipeline import SyntheticLM
from repro.optim import make_optimizer
from repro.sparse import registry as REG
from repro.train import checkpoint as CKPT
from repro.train.state import init_train_state
from repro.train.trainer import Trainer, make_dst_step, make_train_step


def _cfg(name="qwen3-1.7b", **sp):
    cfg = configs.get_smoke_config(name)
    return cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, **sp))


def _batches(cfg, n, bsz=4, seq=32):
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, batch_size=bsz,
                       seed=0, family=cfg.family, n_codebooks=cfg.n_codebooks,
                       d_model=cfg.d_model)
    return [jax.tree.map(jnp.asarray, data.batch(i)) for i in range(n)]


def test_optimizer_respects_masks():
    """Pruned weights never move; active weights do."""
    cfg = _cfg(delta_t=10_000)  # no DST updates in this test
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(1e-2)))
    w0 = REG.get_path(state.params, reg[0].path)
    m = REG.get_path(state.masks, reg[0].path)
    for b in _batches(cfg, 3):
        state, _ = step(state, b)
    w1 = REG.get_path(state.params, reg[0].path)
    diff = np.abs(np.array(w1 - w0))
    assert diff[~np.array(m)].max() == 0.0       # pruned slots frozen
    assert diff[np.array(m)].max() > 0.0         # active slots trained


def test_dst_step_maintains_invariants_and_zeroes_grown():
    cfg = _cfg(delta_t=5)
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    batches = _batches(cfg, 12)
    for i, b in enumerate(batches):
        state, _ = step(state, b)
        if (i + 1) % 5 == 0:
            old_masks = jax.tree.map(lambda x: x, state.masks)
            state = dst(state, b)
            for s in reg:
                m_new = np.array(REG.get_path(state.masks, s.path))
                m_old = np.array(REG.get_path(old_masks, s.path))
                w = np.array(REG.get_path(state.params, s.path))
                grown = m_new & ~m_old
                if grown.any():
                    assert np.abs(w[grown]).max() == 0.0  # regrown start at 0
                a = np.array(REG.get_path(state.neuron_active, s.path))
                m2 = m_new.reshape(-1, *m_new.shape[-2:])
                a2 = a.reshape(-1, a.shape[-1])
                for j in range(m2.shape[0]):
                    nnz = m2[j].sum(0)
                    k = nnz[a2[j]].max() if a2[j].any() else 0
                    assert topology.check_constant_fan_in(m2[j], int(k), a2[j])


def test_dst_step_stamps_mask_versions():
    """The trainer's per-stack mask-version counters (consumed by the serving
    Plan's incremental export): train_step leaves them alone; the DST step
    bumps exactly the stacks whose masks actually changed."""
    cfg = _cfg(delta_t=2)
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    assert set(state.mask_versions) == {s.name for s in reg}
    assert all(int(v) == 0 for v in state.mask_versions.values())

    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    for b in _batches(cfg, 2):
        state, _ = step(state, b)
    assert all(int(v) == 0 for v in state.mask_versions.values())  # no DST yet

    old_masks = jax.tree.map(lambda x: x, state.masks)
    state = dst(state, _batches(cfg, 1)[0])
    for s in reg:
        changed = bool(np.any(np.array(REG.get_path(state.masks, s.path))
                              != np.array(REG.get_path(old_masks, s.path))))
        assert int(state.mask_versions[s.name]) == int(changed)


def test_loss_decreases_with_dst():
    cfg = _cfg(delta_t=5)
    trainer = Trainer(cfg=cfg, lr_fn=lambda s: jnp.float32(3e-3), log_every=1000)
    state = trainer.init_or_restore(jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=0)
    batches = (jax.tree.map(jnp.asarray, data.batch(i)) for i in range(10_000))
    state = trainer.fit(state, batches, 50, log_fn=lambda *_: None)
    # measure directly
    step = jax.jit(make_train_step(cfg, trainer.registry, lambda s: jnp.float32(0.0)))
    _, m = step(state, jax.tree.map(jnp.asarray, data.batch(0)))
    assert float(m["loss"]) < 5.4  # init CE is ~ln(256)=5.55


def test_rigl_and_set_methods_run():
    for method in ("rigl", "set"):
        cfg = _cfg(delta_t=3)
        cfg = cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, method=method))
        reg = REG.build_registry(cfg)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(1e-3)))
        dst = jax.jit(make_dst_step(cfg, reg))
        for i, b in enumerate(_batches(cfg, 4)):
            state, metrics = step(state, b)
            if (i + 1) % 3 == 0:
                state = dst(state, b)
        assert bool(jnp.isfinite(metrics["loss"]))


def test_dense_method_no_masks():
    cfg = _cfg().replace(sparsity=dataclasses.replace(
        configs.get_smoke_config("qwen3-1.7b").sparsity, method="dense"))
    reg = REG.build_registry(cfg)
    assert reg == []
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(1e-3)))
    state, m = step(state, _batches(cfg, 1)[0])
    assert bool(jnp.isfinite(m["loss"]))


def test_grad_accum_saliency_window():
    cfg = _cfg(delta_t=4, grad_accum_for_saliency=4)
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    assert state.grad_accum  # accumulator allocated
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(1e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    for i, b in enumerate(_batches(cfg, 8)):
        state, _ = step(state, b)
        if (i + 1) % 4 == 0:
            state = dst(state, b)
    acc = REG.get_path(state.grad_accum, reg[0].path)
    assert bool(jnp.isfinite(acc).all())


def test_checkpoint_restart_resumes_exactly():
    cfg = _cfg(delta_t=100)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg=cfg, lr_fn=lambda s: jnp.float32(1e-3), ckpt_dir=d,
                     ckpt_every=5, log_every=1000)
        state = tr.init_or_restore(jax.random.PRNGKey(0))
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=0)

        def batches(start):
            i = start
            while True:
                yield jax.tree.map(jnp.asarray, data.batch(i))
                i += 1

        state = tr.fit(state, batches(0), 10, log_fn=lambda *_: None)
        # simulate crash: fresh trainer restores from step 10
        tr2 = Trainer(cfg=cfg, lr_fn=lambda s: jnp.float32(1e-3), ckpt_dir=d,
                      log_every=1000)
        restored = tr2.init_or_restore(jax.random.PRNGKey(42))
        assert int(restored.step) == 10
        for (ka, a), (kb, b) in zip(
                sorted(CKPT._flatten(state._asdict()).items()),
                sorted(CKPT._flatten(restored._asdict()).items())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=ka)


@pytest.mark.parametrize("opt", ["sgdm", "adamw", "adafactor"])
def test_optimizers_step(opt):
    init, update = make_optimizer(opt)
    params = {"a": {"w": jnp.ones((8, 4))}, "b": jnp.ones((4,))}
    grads = jax.tree.map(jnp.ones_like, params)
    st = init(params)
    p1, st1 = update(params, grads, st, 0.1)
    assert float(p1["a"]["w"][0, 0]) < 1.0
    # masked variant: masked slots unchanged
    masks = {"a": {"w": jnp.zeros((8, 4), bool).at[0].set(True)}}
    p2, _ = update(params, grads, st, 0.1, masks=masks)
    assert float(p2["a"]["w"][1, 0]) == 1.0
    assert float(p2["a"]["w"][0, 0]) < 1.0


def test_elastic_mesh_helper():
    from repro.train.elastic import largest_feasible_mesh
    assert largest_feasible_mesh(256, 16) == (16, 16)
    assert largest_feasible_mesh(240, 16) == (15, 16)
    assert largest_feasible_mesh(8, 16) == (1, 16)
