"""hypothesis when installed, else a seeded-parametrize fallback.

The property-test modules import ``given``, ``settings`` and ``st`` from here
instead of from hypothesis directly. With hypothesis installed these ARE the
hypothesis objects (shrinking, example database, the works). Without it, the
fallback turns each ``@given`` property into a deterministic
``pytest.mark.parametrize`` over seeded draws — weaker (no shrinking, fixed
example count) but it keeps every property exercised, so the suite collects
and runs on minimal containers.

Fallback subset implemented: st.integers / st.floats / st.sampled_from /
st.booleans, settings(max_examples=, deadline=), @given with positional
strategies. That is exactly the surface the test modules use; extend here
before reaching for new hypothesis features.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # ---- seeded fallback ---------------------------------
    HAVE_HYPOTHESIS = False
    import random
    import zlib

    import pytest

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw_fn, label):
            self._draw_fn = draw_fn
            self.label = label

        def draw(self, rng: random.Random):
            return self._draw_fn(rng)

        def __repr__(self):
            return f"_Strategy({self.label})"

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             f"integers({min_value},{max_value})")

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                             f"floats({min_value},{max_value})")

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements),
                             f"sampled_from({elements!r})")

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")

    st = _StrategiesModule()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            if getattr(fn, "_hyp_given_applied", False):
                # real hypothesis accepts either order; the fallback reads
                # max_examples at @given time, so settings applied above it
                # would be silently dropped — fail loudly instead
                raise RuntimeError(
                    "_hypothesis_compat fallback: apply @settings BELOW "
                    "@given (given outermost), or max_examples is ignored")
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n = getattr(fn, "_hyp_max_examples", _DEFAULT_EXAMPLES)
            # stable per-test seed base so renaming other tests never
            # reshuffles this one's examples
            base = zlib.crc32(fn.__name__.encode())

            def wrapper(_hyp_example):
                rng = random.Random(base * 1_000_003 + _hyp_example)
                fn(*[s.draw(rng) for s in strategies])

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._hyp_given_applied = True
            return pytest.mark.parametrize("_hyp_example", range(n))(wrapper)
        return deco
