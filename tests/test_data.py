"""Data pipeline: determinism, restart-reproducibility, prefetch, specs."""
import numpy as np

from repro import configs
from repro.data.pipeline import Prefetcher, SyntheticLM, make_batch_spec


def test_batch_deterministic_per_step():
    d1 = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    d2 = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    for step in (0, 5, 123):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_restart_reproducibility():
    """Restarting from step N regenerates the same stream (fault tolerance)."""
    d = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    full = [d.batch(i)["tokens"] for i in range(6)]
    resumed = []
    it = d.iterate(start_step=3)
    for _ in range(3):
        resumed.append(next(it)["tokens"])
    for a, b in zip(full[3:], resumed):
        np.testing.assert_array_equal(a, b)


def test_different_seeds_differ():
    a = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=1).batch(0)
    b = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=2).batch(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_markov_structure_learnable():
    """The stream is not iid — successor entropy is below uniform."""
    d = SyntheticLM(vocab_size=64, seq_len=256, batch_size=8, seed=0)
    toks = d.batch(0)["tokens"]
    # each token has <= 8 successors, so pair entropy is bounded
    pairs = set()
    for row in toks:
        pairs.update(zip(row[:-1], row[1:]))
    assert len(pairs) < 64 * 16  # far fewer than 64*64 possible


def test_prefetcher():
    d = SyntheticLM(vocab_size=50, seq_len=8, batch_size=2, seed=0)
    pf = Prefetcher(d.iterate(), depth=2)
    got = [next(pf) for _ in range(4)]
    assert all(g["tokens"].shape == (2, 8) for g in got)
    np.testing.assert_array_equal(got[0]["tokens"], d.batch(0)["tokens"])
    pf.close()


def test_audio_and_vlm_batches():
    cfg_a = configs.get_smoke_config("musicgen-medium")
    d = SyntheticLM(vocab_size=cfg_a.vocab_size, seq_len=16, batch_size=2, seed=0,
                    family="audio", n_codebooks=cfg_a.n_codebooks)
    b = d.batch(0)
    assert b["tokens"].shape == (2, cfg_a.n_codebooks, 16)
    cfg_v = configs.get_smoke_config("qwen2-vl-7b")
    d = SyntheticLM(vocab_size=cfg_v.vocab_size, seq_len=16, batch_size=2, seed=0,
                    family="vlm", d_model=cfg_v.d_model)
    b = d.batch(0)
    assert b["frontend_embeds"].shape == (2, 16, cfg_v.d_model)
    assert b["mrope_positions"].shape == (3, 2, 16)


def test_batch_specs_cover_all_cells():
    """Every (arch x shape) cell has a well-defined input spec."""
    for name in configs.ALL_ARCHS:
        cfg = configs.get_config(name)
        for shape in configs.shapes_for(name, cfg.family, cfg.causal):
            spec = make_batch_spec(cfg, shape)
            assert "tokens" in spec or "frontend_embeds" in spec
            for leaf in spec.values():
                assert all(d > 0 for d in leaf.shape)
