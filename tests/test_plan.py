"""Serving execution plans (repro.sparse.plan): per-stack representation
selection, the composed condensed-over-active path, and incremental export.

The acceptance criteria made executable:

* ``--path auto`` on the smoke config selects condensed at batch 1 and
  masked at batch 256 per the bytes/FLOPs cost model;
* condensed-over-active greedy decode is token-identical to masked when
  ablated neurons are present (the paper's combined Fig. 4 point);
* ``Plan.refresh`` re-condenses ONLY stacks whose mask version changed
  (asserted via the plan's export-call counter);
* ``export_structured`` is token-identical to masked on ablation-ONLY masks
  and degrades gracefully (runs, but diverges) on unstructured masks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import serve
from repro.models import model as M
from repro.sparse import condensed as COND
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    return cfg, reg, params, masks, prompts


def _ablate(reg, masks, frac=0.25):
    """SRigL-style ablation: zero the last ``frac`` of each stack's mask
    columns (those output neurons become exact zeros on the masked path)."""
    out = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        cut = s.d_out - max(1, int(s.d_out * frac))
        REG._set_path(out, s.path, m & (jnp.arange(s.d_out) < cut)[None, :])
    return out


def _ablation_only(reg, masks, frac=0.25):
    """Masks whose sparsity is PURELY neuron ablation: active columns fully
    dense, ablated columns fully empty — the regime where the structured
    (column-drop) representation is exact."""
    out = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        cut = s.d_out - max(1, int(s.d_out * frac))
        col_active = (jnp.arange(s.d_out) < cut)[None, :]
        REG._set_path(out, s.path, jnp.broadcast_to(col_active, m.shape))
    return out


# ---------------------------------------------------------------------------
# cost model / auto selection
# ---------------------------------------------------------------------------

def test_auto_selects_condensed_at_b1_and_masked_at_b256(smoke_setup):
    """The acceptance-criteria crossover: bandwidth-bound decode (B=1) goes
    to the condensed gather; the MXU wins back at large batch (B=256)."""
    cfg, reg, params, masks, _ = smoke_setup
    p1 = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto")
    p256 = PLAN.build_plan(cfg, reg, params, masks, batch_size=256, path="auto")
    for s in reg:
        assert p1.representation_of(s.name) == "condensed"
        assert p256.representation_of(s.name) == "masked"


def test_auto_with_ablation_selects_condensed_over_active(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    abl = _ablate(reg, masks)
    plan = PLAN.build_plan(cfg, reg, params, abl, batch_size=1, path="auto")
    for s in reg:
        assert plan.representation_of(s.name) == "condensed_over_active"
        assert plan.decisions[s.name].active_fraction < 1.0


def test_auto_never_selects_structured(smoke_setup):
    """structured keeps active columns dense, so it is not output-equivalent
    for fine-grained masks — auto must only choose exact representations."""
    cfg, reg, params, masks, _ = smoke_setup
    for batch in (1, 8, 64, 256):
        for m in (masks, _ablate(reg, masks)):
            plan = PLAN.build_plan(cfg, reg, params, m, batch_size=batch,
                                   path="auto")
            assert all(d.representation != "structured"
                       for d in plan.decisions.values())


def test_cost_model_crossover_is_batch_monotonic(smoke_setup):
    """Once the MXU wins a stack, it keeps winning at larger batch (gather
    compute grows linearly in B on a ~50x slower unit)."""
    cfg, reg, params, masks, _ = smoke_setup
    stats = COND.export_stats(reg, masks)
    for s in reg:
        was_masked = False
        for batch in (1, 4, 16, 64, 128, 256, 1024):
            dec = PLAN.select_representation(
                s, batch_size=batch, itemsize=4, stats=stats[s.name])
            if was_masked:
                assert dec.representation == "masked"
            was_masked = dec.representation == "masked"
        assert was_masked  # big-batch endpoint is always the MXU


def test_build_plan_rejects_unknown_path(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    with pytest.raises(ValueError):
        PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="csr")


def test_plan_for_shape_matches_concrete_auto_without_ablation(smoke_setup):
    """The dry-run's static (density-based) selection agrees with the
    concrete plan when no ablation has happened yet."""
    cfg, reg, params, masks, _ = smoke_setup
    for batch in (1, 256):
        static = PLAN.plan_for_shape(cfg, reg, batch_size=batch)
        concrete = PLAN.build_plan(cfg, reg, params, masks, batch_size=batch,
                                   path="auto")
        assert static == {n: d.representation
                          for n, d in concrete.decisions.items()}


def test_abstract_serving_tree_shapes_match_concrete_condensed(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    reps = {s.name: "condensed" for s in reg}
    abstract = PLAN.abstract_serving_tree(cfg, reg, reps)
    concrete = COND.export_condensed(cfg, reg, params, masks)
    for s in reg:
        a = REG.get_path(abstract, s.path)
        c = REG.get_path(concrete, s.path)
        # same rank/lead dims; k may differ (target vs realized fan-in)
        assert a["values"].shape[:-1] == c["values"].shape[:-1]
        assert a["indices"].dtype == c["indices"].dtype


# ---------------------------------------------------------------------------
# condensed-over-active exactness
# ---------------------------------------------------------------------------

def test_condensed_over_active_token_identical_with_ablation(smoke_setup):
    """The combined Fig. 4 point: drop ablated neurons, condense survivors —
    greedy decode must match the masked path token for token."""
    cfg, reg, params, masks, prompts = smoke_setup
    abl = _ablate(reg, masks)
    coa = serve.build_serving_masks(cfg, reg, params, abl,
                                    "condensed_over_active")
    out_masked = serve.generate(cfg, params, abl, prompts, gen_len=8)
    out_coa = serve.generate(cfg, params, coa, prompts, gen_len=8)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_coa))


def test_condensed_over_active_shrinks_row_count(smoke_setup):
    """With 25% of neurons ablated the gather runs over ~75% of the rows —
    the leaf's row dim is the realized max active count, not d_out."""
    cfg, reg, params, masks, _ = smoke_setup
    abl = _ablate(reg, masks, frac=0.25)
    stats = COND.export_stats(reg, abl)
    tree = COND.export_condensed_over_active(cfg, reg, params, abl, stats)
    for s in reg:
        leaf = REG.get_path(tree, s.path)
        a = leaf["values"].shape[-2]
        assert a == stats[s.name].max_active < s.d_out
        assert leaf["out_index"].shape == leaf["values"].shape[:-1]
        # padded rows (if any) point out of range; real rows are in range
        oi = np.array(leaf["out_index"])
        assert oi.max() <= s.d_out


def test_condensed_over_active_token_identical_without_ablation(smoke_setup):
    """Degenerate case (no ablated neurons): still exact, a == d_out."""
    cfg, reg, params, masks, prompts = smoke_setup
    coa = serve.build_serving_masks(cfg, reg, params, masks,
                                    "condensed_over_active")
    out_masked = serve.generate(cfg, params, masks, prompts, gen_len=6)
    out_coa = serve.generate(cfg, params, coa, prompts, gen_len=6)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_coa))


def test_auto_plan_decode_token_identical(smoke_setup):
    """Whatever mix auto picks must still evaluate the same function."""
    cfg, reg, params, masks, prompts = smoke_setup
    abl = _ablate(reg, masks)
    for batch_size in (1, 256):
        plan = PLAN.build_plan(cfg, reg, params, abl, batch_size=batch_size,
                               path="auto")
        out_masked = serve.generate(cfg, params, abl, prompts, gen_len=6)
        out_auto = serve.generate(cfg, params, plan.serving_tree, prompts,
                                  gen_len=6)
        np.testing.assert_array_equal(np.array(out_masked), np.array(out_auto))


# ---------------------------------------------------------------------------
# export_structured exactness contract (satellite)
# ---------------------------------------------------------------------------

def test_structured_token_identical_on_ablation_only_masks(smoke_setup):
    """When sparsity is PURELY neuron ablation (active columns dense), the
    structured column-drop representation is exact."""
    cfg, reg, params, masks, prompts = smoke_setup
    abl_only = _ablation_only(reg, masks)
    struct = serve.build_serving_masks(cfg, reg, params, abl_only, "structured")
    out_masked = serve.generate(cfg, params, abl_only, prompts, gen_len=8)
    out_struct = serve.generate(cfg, params, struct, prompts, gen_len=8)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_struct))


def test_structured_degrades_gracefully_on_unstructured_masks(smoke_setup):
    """On fine-grained masks structured still RUNS (graceful degradation) but
    is documented as NOT equivalent — single-step logits must diverge."""
    cfg, reg, params, masks, prompts = smoke_setup
    struct = serve.build_serving_masks(cfg, reg, params, masks, "structured")
    out = serve.generate(cfg, params, struct, prompts, gen_len=4)
    assert out.shape == (2, 8 + 4)
    tok = prompts[:, :1]
    lm, _ = M.decode_step(cfg, params, masks, {"tokens": tok},
                          M.init_cache(cfg, 2, 4))
    ls, _ = M.decode_step(cfg, params, struct, {"tokens": tok},
                          M.init_cache(cfg, 2, 4))
    assert float(jnp.max(jnp.abs(lm - ls))) > 1e-4


# ---------------------------------------------------------------------------
# fused export stats (single host sync)
# ---------------------------------------------------------------------------

def test_export_stats_matches_naive_per_stack(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    abl = _ablate(reg, masks)
    stats = COND.export_stats(reg, abl)
    for s in reg:
        m = np.array(REG.get_path(abl, s.path))
        nnz = m.sum(axis=-2)
        act = m.any(axis=-2)
        assert stats[s.name].k == int(nnz.max())
        assert stats[s.name].max_active == int(act.sum(axis=-1).max())
        np.testing.assert_allclose(stats[s.name].active_fraction,
                                   act.mean(), rtol=1e-5)


def test_export_condensed_matches_legacy_path(smoke_setup):
    """The fused-stats export produces the same condensed pytree as the
    per-stack computation it replaced."""
    cfg, reg, params, masks, _ = smoke_setup
    tree = COND.export_condensed(cfg, reg, params, masks)
    for s in reg:
        w = REG.get_path(params, s.path)
        m = REG.get_path(masks, s.path)
        k = int(np.array(m).sum(axis=-2).max())
        legacy = COND._condense_stack(w * m, m, k)
        got = REG.get_path(tree, s.path)
        np.testing.assert_array_equal(np.array(got["values"]),
                                      np.array(legacy["values"]))
        np.testing.assert_array_equal(np.array(got["indices"]),
                                      np.array(legacy["indices"]))


# ---------------------------------------------------------------------------
# incremental export (Plan.refresh)
# ---------------------------------------------------------------------------

def test_refresh_recondenses_only_changed_stacks(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    versions = {s.name: 0 for s in reg}
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions=versions)
    assert plan.export_calls == len(reg)  # initial full export

    # no version movement -> no re-condense (frozen-params serving mode)
    assert plan.refresh(params, masks, versions, refresh_values=False) == []
    assert plan.export_calls == len(reg)

    # one stack's mask changes (and its version is stamped)
    target = reg[1]
    new_masks = jax.tree.map(lambda m: m, masks)
    REG._set_path(new_masks, target.path,
                  REG.get_path(_ablate([target], masks), target.path))
    new_versions = dict(versions)
    new_versions[target.name] = 1

    before = {s.name: REG.get_path(plan.serving_tree, s.path) for s in reg}
    changed = plan.refresh(params, new_masks, new_versions,
                           refresh_values=False)
    assert changed == [target.name]
    assert plan.export_calls == len(reg) + 1  # exactly ONE re-condense
    assert plan.value_refreshes == 0
    for s in reg:
        leaf = REG.get_path(plan.serving_tree, s.path)
        if s.name == target.name:
            assert leaf is not before[s.name]
        else:  # untouched stacks keep their exported arrays verbatim
            assert leaf is before[s.name]


def test_refresh_values_regathers_unchanged_stacks_without_resort(smoke_setup):
    """Default refresh: unchanged-topology stacks get a values-only regather
    (indices reused verbatim, NOT counted as a re-condense) so the serving
    snapshot stays coherent with weights that kept training. The old values
    buffers are DONATED (refresh runs against a live serving job), so values
    are snapshotted to host numpy before refreshing."""
    cfg, reg, params, masks, _ = smoke_setup
    versions = {s.name: 0 for s in reg}
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions=versions)
    before_idx = {s.name: REG.get_path(plan.serving_tree, s.path)["indices"]
                  for s in reg}
    before_vals = {s.name: np.array(
        REG.get_path(plan.serving_tree, s.path)["values"]) for s in reg}
    target = reg[1]
    new_versions = dict(versions)
    new_versions[target.name] = 1
    new_masks = jax.tree.map(lambda m: m, masks)
    REG._set_path(new_masks, target.path,
                  REG.get_path(_ablate([target], masks), target.path))

    changed = plan.refresh(params, new_masks, new_versions)
    assert changed == [target.name]
    assert plan.export_calls == len(reg) + 1        # one full re-condense
    assert plan.value_refreshes == len(reg) - 1     # cheap regathers
    for s in reg:
        leaf = REG.get_path(plan.serving_tree, s.path)
        if s.name != target.name:
            # indices reused verbatim; same params -> identical values
            assert leaf["indices"] is before_idx[s.name]
            np.testing.assert_array_equal(np.array(leaf["values"]),
                                          before_vals[s.name])


def test_refresh_keeps_snapshot_coherent_when_params_train_on(smoke_setup):
    """The live-serving regression: weights keep training between DST steps
    (no mask change anywhere), and the refreshed plan must serve the NEW
    weights — not the values baked in at build time."""
    cfg, reg, params, masks, prompts = smoke_setup
    # no ablation -> condensed leaves; with ablation -> condensed_over_active
    # leaves (both regather paths must stay exact)
    for serving_masks in (masks, _ablate(reg, masks)):
        plan = PLAN.build_plan(cfg, reg, params, serving_masks, batch_size=1,
                               path="auto", mask_versions={s.name: 0 for s in reg})
        # simulate further training: perturb every sparse stack's weights
        new_params = jax.tree.map(lambda x: x, params)
        for s in reg:
            w = REG.get_path(new_params, s.path)
            REG._set_path(new_params, s.path,
                          w + 0.1 * jax.random.normal(jax.random.PRNGKey(7),
                                                      w.shape))
        assert plan.refresh(new_params, serving_masks,
                            {s.name: 0 for s in reg}) == []
        out_masked = serve.generate(cfg, new_params, serving_masks, prompts,
                                    gen_len=6)
        out_plan = serve.generate(cfg, new_params, plan.serving_tree, prompts,
                                  gen_len=6)
        np.testing.assert_array_equal(np.array(out_masked), np.array(out_plan))


def test_refresh_flips_representation_when_ablation_appears(smoke_setup):
    """Ablation appearing mid-training flips an auto stack from condensed to
    condensed-over-active on the next refresh."""
    cfg, reg, params, masks, _ = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions={s.name: 0 for s in reg})
    assert plan.representation_of(reg[0].name) == "condensed"
    abl = _ablate(reg, masks)
    plan.refresh(params, abl, {s.name: 1 for s in reg})
    for s in reg:
        assert plan.representation_of(s.name) == "condensed_over_active"


def test_refreshed_plan_serves_correctly(smoke_setup):
    """After an incremental refresh the serving tree evaluates the NEW masks."""
    cfg, reg, params, masks, prompts = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions={s.name: 0 for s in reg})
    abl = _ablate(reg, masks)
    plan.refresh(params, abl, {s.name: 1 for s in reg})
    out_masked = serve.generate(cfg, params, abl, prompts, gen_len=6)
    out_plan = serve.generate(cfg, params, plan.serving_tree, prompts, gen_len=6)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_plan))


def test_plan_weight_bytes_orders_representations(smoke_setup):
    """Bytes under the plan: the masked path is the reference (ratio 1.0 by
    definition), condensed beats it at 90% sparsity, and ablation shrinks
    condensed-over-active below plain condensed."""
    cfg, reg, params, masks, _ = smoke_setup
    cond = PLAN.build_plan(cfg, reg, params, masks, batch_size=1,
                           path="condensed")
    masked = PLAN.build_plan(cfg, reg, params, masks, batch_size=1,
                             path="masked")
    sb_c, ref = cond.weight_bytes()
    sb_m, ref_m = masked.weight_bytes()
    assert ref == ref_m
    assert sb_m == ref  # all-masked plan reports exactly the reference
    assert sb_c < sb_m
    abl = _ablate(reg, masks)
    coa = PLAN.build_plan(cfg, reg, params, abl, batch_size=1,
                          path="condensed_over_active")
    sb_a, _ = coa.weight_bytes()
    assert sb_a < sb_c
    # priced at EXPORTED size: max_active rows (+4B out_index), not mean act
    for s in reg:
        dec = coa.decisions[s.name]
        assert dec.stats.max_active < s.d_out


# ---------------------------------------------------------------------------
# jitted donated refresh: no 2x weight footprint, no host weight traffic
# ---------------------------------------------------------------------------

def _fresh_constant_fan_in_masks(reg, masks, seed=99):
    """New random topology at the SAME realized fan-in k per stack (a DST
    rewire step: indices move, shapes don't)."""
    from repro.core import topology
    out = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        k = int(np.array(m).sum(axis=-2).max())
        key = jax.random.fold_in(jax.random.PRNGKey(seed), hash(s.name) % 2**31)
        fn = lambda kk: topology.random_constant_fan_in_mask(kk, s.d_in,
                                                             s.d_out, k)
        for _ in range(len(s.lead)):
            fn = jax.vmap(fn)
        keys = jax.random.split(key, max(s.n_replicas, 1)).reshape(
            *(s.lead or (1,)), 2)
        if not s.lead:
            keys = keys[0]
        REG._set_path(out, s.path, fn(keys).reshape(*s.lead, s.d_in, s.d_out))
    return out


def test_refresh_values_donates_old_buffers(smoke_setup):
    """Values-only regather writes INTO the old values buffer (donation):
    the new array reuses the old storage and the old jax.Array is deleted —
    a live refresh never holds two copies of a stack's values."""
    cfg, reg, params, masks, prompts = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions={s.name: 0 for s in reg})
    old = {s.name: REG.get_path(plan.serving_tree, s.path)["values"]
           for s in reg}
    old_ptrs = {n: v.unsafe_buffer_pointer() for n, v in old.items()}

    new_params = jax.tree.map(lambda x: x, params)
    for s in reg:
        w = REG.get_path(new_params, s.path)
        REG._set_path(new_params, s.path, w * 1.5)
    assert plan.refresh(new_params, masks, {s.name: 0 for s in reg}) == []

    for s in reg:
        leaf = REG.get_path(plan.serving_tree, s.path)
        assert old[s.name].is_deleted()
        assert leaf["values"].unsafe_buffer_pointer() == old_ptrs[s.name]
    # and the donated-regather snapshot still serves the new weights exactly
    out_masked = serve.generate(cfg, new_params, masks, prompts, gen_len=4)
    out_plan = serve.generate(cfg, new_params, plan.serving_tree, prompts,
                              gen_len=4)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_plan))


def test_refresh_recondense_donates_on_same_shape_topology_change(smoke_setup):
    """A DST rewire (new indices, same fan-in k, no ablation) re-condenses
    under jit with BOTH old {values, indices} buffers donated: new leaf
    arrays alias the old storage."""
    cfg, reg, params, masks, prompts = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions={s.name: 0 for s in reg})
    old = {s.name: REG.get_path(plan.serving_tree, s.path) for s in reg}
    old_ptrs = {n: {kk: l[kk].unsafe_buffer_pointer()
                    for kk in ("values", "indices")} for n, l in old.items()}

    new_masks = _fresh_constant_fan_in_masks(reg, masks)
    changed = plan.refresh(params, new_masks, {s.name: 1 for s in reg})
    assert sorted(changed) == sorted(s.name for s in reg)
    assert plan.export_calls == 2 * len(reg)

    for s in reg:
        leaf = REG.get_path(plan.serving_tree, s.path)
        assert plan.representation_of(s.name) == "condensed"
        for kk in ("values", "indices"):
            assert old[s.name][kk].is_deleted()
            assert leaf[kk].unsafe_buffer_pointer() == old_ptrs[s.name][kk]
    # token-identical to a fresh export of the new masks
    out_masked = serve.generate(cfg, params, new_masks, prompts, gen_len=4)
    out_plan = serve.generate(cfg, params, plan.serving_tree, prompts,
                              gen_len=4)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_plan))


def test_refresh_donate_false_preserves_old_leaves(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions={s.name: 0 for s in reg})
    old = {s.name: REG.get_path(plan.serving_tree, s.path)["values"]
           for s in reg}
    plan.refresh(params, masks, {s.name: 0 for s in reg}, donate=False)
    for s in reg:
        assert not old[s.name].is_deleted()
        np.testing.assert_array_equal(
            np.array(old[s.name]),
            np.array(REG.get_path(plan.serving_tree, s.path)["values"]))


def test_refresh_no_host_device_get_for_weight_data(smoke_setup, monkeypatch):
    """The refresh host-transfer contract: host-int version counters are
    used as-is (ZERO device_gets on a values-only regather — the no-op
    fast path); a changed-stack refresh fetches exactly one payload (the
    fused per-stack scalar stats). Nothing weight-sized ever crosses to
    the host."""
    cfg, reg, params, masks, _ = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1, path="auto",
                           mask_versions={s.name: 0 for s in reg})

    fetched = []
    orig = jax.device_get

    def counting_device_get(tree):
        fetched.append(sum(getattr(l, "nbytes", 8)
                           for l in jax.tree_util.tree_leaves(tree)))
        return orig(tree)

    monkeypatch.setattr(jax, "device_get", counting_device_get)

    # values-only regather: host-int versions short-circuit the fetch
    plan.refresh(params, masks, {s.name: 0 for s in reg})
    assert len(fetched) == 0

    # changed-stack re-condense: one fused stats fetch, still no weights
    new_masks = _fresh_constant_fan_in_masks(reg, masks, seed=7)
    plan.refresh(params, new_masks, {s.name: 1 for s in reg})
    assert len(fetched) == 1
    assert all(n < 1024 for n in fetched)


# ---------------------------------------------------------------------------
# measured hardware profile
# ---------------------------------------------------------------------------

@pytest.fixture()
def tmp_autotune_cache(tmp_path, monkeypatch):
    from repro.sparse import autotune as AT
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    AT.reset_cache_state()
    yield AT
    AT.reset_cache_state()


_QUICK_MEASURE = dict(stream_mb=2.0, matmul_shape=(16, 128, 128),
                      gather_shape=(8, 256, 256, 16), reps=2)


def test_hardware_profile_measure_rates_sane(tmp_autotune_cache):
    prof = PLAN.HardwareProfile.measure(use_cache=False, save=False,
                                        **_QUICK_MEASURE)
    assert prof.name == f"measured-{jax.default_backend()}"
    for rate in (prof.hbm_bytes_per_s, prof.mxu_flops_per_s,
                 prof.gather_flops_per_s):
        assert np.isfinite(rate) and rate > 0
    # a dense matmul unit beats the gather formulation per FLOP everywhere
    assert prof.mxu_flops_per_s > prof.gather_flops_per_s


def test_measured_profile_drives_plan_and_stays_exact(smoke_setup,
                                                      tmp_autotune_cache):
    cfg, reg, params, masks, _ = smoke_setup
    prof = PLAN.HardwareProfile.measure(use_cache=False, save=False,
                                        **_QUICK_MEASURE)
    for batch in (1, 256):
        plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=batch,
                               path="auto", profile=prof)
        for dec in plan.decisions.values():
            assert dec.representation in ("masked", "condensed",
                                          "condensed_over_active")
        static = PLAN.plan_for_shape(cfg, reg, batch_size=batch, profile=prof)
        assert set(static) == {s.name for s in reg}


def test_hardware_profile_measure_persists_and_caches(tmp_autotune_cache,
                                                      monkeypatch):
    AT = tmp_autotune_cache
    prof = PLAN.HardwareProfile.measure(use_cache=True, **_QUICK_MEASURE)
    stored = AT.cached_profile()
    assert stored is not None
    assert stored["hbm_bytes_per_s"] == prof.hbm_bytes_per_s
    # second call must come from the cache: timing is forbidden
    def _no_timing(*a, **kw):
        raise AssertionError("measure() re-timed despite a cached profile")
    monkeypatch.setattr(AT, "_time_us", _no_timing)
    prof2 = PLAN.HardwareProfile.measure(use_cache=True, **_QUICK_MEASURE)
    assert prof2 == prof


def test_coa_priced_at_exported_rows_not_mean_activity(smoke_setup):
    """Uneven ablation (mean activity low but max_active == d_out): the
    exported leaf still carries d_out rows per replica, so the cost model
    must not price condensed_over_active below plain condensed."""
    cfg, reg, params, masks, _ = smoke_setup
    s = reg[0]
    costs = PLAN.stack_costs(s, batch_size=4, itemsize=4, k=8,
                             active_fraction=0.5, max_active_fraction=1.0)
    assert costs["condensed_over_active"] >= costs["condensed"]
    # and with genuinely shrunk rows the discount tracks the ROW fraction
    half = PLAN.stack_costs(s, batch_size=4, itemsize=4, k=8,
                            active_fraction=0.5, max_active_fraction=0.5)
    assert half["condensed_over_active"] < costs["condensed_over_active"]


def test_auto_prefers_plain_condensed_under_uneven_ablation(smoke_setup):
    """Uneven ablation where one replica stays fully active: the exported
    condensed-over-active leaf is the full d_out rows PLUS out_index bytes,
    so plain condensed (exact for any mask) must win the auto choice."""
    cfg, reg, params, masks, _ = smoke_setup
    s = reg[0]
    stats = COND.ExportStats(k=8, max_active=s.d_out, active_fraction=0.5)
    dec = PLAN.select_representation(s, batch_size=1, itemsize=4, stats=stats)
    assert dec.representation == "condensed"
    assert dec.est_s["condensed"] <= dec.est_s["condensed_over_active"]
