"""Quantized condensed decode: int8/fp8 values with per-neuron scales.

The PR's acceptance criteria made executable:

* quantize/dequantize round-trip error stays within the documented per-dtype
  bound (int8: half a quantization step; fp8-e4m3: ~2^-4 relative);
* every quantized format's ``apply`` matches the scale-after-sum reference
  EXACTLY (float-associativity atol) and the f32 oracle within the
  quantization bound — the kernel adds no error of its own;
* int8 condensed streams <= 0.35x the HBM value bytes of f32 condensed at
  the benchmark decode fan-ins (k=13, k=26), priced via
  ``estimate_values_bytes`` AND measured from the exported arrays' nbytes;
* quantized tuning keys carry a ``wint8``/``wfp8`` width tag while float
  keys keep the byte-identical legacy ``w{bits}`` layout;
* the scalar-prefetch decode variant removes the hoisted XLA column gather
  (HLO dispatch count on the ``hoisted_column_gather`` scope tag);
* the out-blocked scatter epilogue (``block_o``) is bit-identical to the
  unblocked one;
* checkpoint round-trips both ways: a pre-quantization f32 archive restores
  into a quantized template (scales rebuilt), a quantized archive restores
  into an f32 template (dequantized);
* plans built with ``values_dtype`` export quantized leaves, price the real
  byte width, and ``refresh`` preserves the precision.
"""
import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.kernels import condensed_matmul as cm
from repro.kernels import structured_matmul as sm
from repro.sparse import formats as F

D_IN, D_OUT, K = 32, 48, 5
HAS_FP8 = "fp8" in F.VALUES_DTYPES
QDTYPES = ("int8",) + (("fp8",) if HAS_FP8 else ())
# documented relative-error bounds (Frobenius norm) for quantized apply vs
# the f32 oracle: int8 step = amax/127 (rel RMS ~0.7% on gaussian weights),
# e4m3 half-ulp = 2^-4 relative (~3.6% RMS) — bounds leave ~4x headroom
ORACLE_REL = {"int8": 0.03, "fp8": 0.15}


@pytest.fixture(scope="module")
def wm():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (D_IN, D_OUT), jnp.float32)
    mask = topology.random_constant_fan_in_mask(
        jax.random.fold_in(key, 1), D_IN, D_OUT, K)
    cut = D_OUT - D_OUT // 4
    abl = mask & (jnp.arange(D_OUT) < cut)[None, :]
    abl_only = jnp.broadcast_to((jnp.arange(D_OUT) < cut)[None, :],
                                (D_IN, D_OUT))
    return w, mask, abl, abl_only


def _rel(a, b):
    return float(np.linalg.norm(np.asarray(a) - np.asarray(b))
                 / max(np.linalg.norm(np.asarray(b)), 1e-12))


# ---------------------------------------------------------------------------
# quantize/dequantize round-trip bounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qdt", QDTYPES)
def test_quantize_roundtrip_within_documented_bound(qdt):
    v = jax.random.normal(jax.random.PRNGKey(3), (16, 7), jnp.float32)
    q, s = F.quantize_values(v, qdt)
    assert q.dtype == jnp.dtype(F.VALUES_DTYPES[qdt])
    assert s.dtype == jnp.float32 and s.shape == (16,)
    deq = F.dequantize_values(q, s)
    err = np.abs(np.asarray(deq) - np.asarray(v))
    scol = np.asarray(s)[:, None]
    if qdt == "int8":
        # symmetric rounding: at most half a quantization step per element
        bound = scol * (0.5 + 1e-3)
    else:
        # e4m3: half-ulp relative error for normals + a subnormal floor
        bound = np.abs(np.asarray(v)) * 2.0**-4 + scol * 2.0**-6
    assert (err <= bound).all(), float((err - bound).max())


def test_quantize_all_zero_rows_get_unit_scale():
    v = jnp.zeros((4, 6), jnp.float32)
    q, s = F.quantize_values(v, "int8")
    np.testing.assert_array_equal(np.asarray(s), np.ones(4, np.float32))
    np.testing.assert_array_equal(np.asarray(q), np.zeros((4, 6), np.int8))


# ---------------------------------------------------------------------------
# format apply: exact vs scale-after-sum reference, bounded vs f32 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qdt", QDTYPES)
def test_condensed_quantized_apply_exact_and_bounded(wm, qdt):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask, quantize_spec=qdt)
    assert fmt.values_dtype == qdt and fmt.scales is not None
    x = jax.random.normal(jax.random.PRNGKey(4), (2, D_IN))
    y = fmt.apply(x, w)
    # scale-after-sum reference: the kernel's exact contract
    deq = F.dequantize_values(fmt.values, fmt.scales)
    xg = jnp.take(x, fmt.indices, axis=1)            # (B, d_out, k)
    y_ref = (xg * deq[None]).sum(-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    # f32 oracle within the quantization bound (kernel adds no error)
    assert _rel(y, x @ (w * mask)) <= ORACLE_REL[qdt]


@pytest.mark.parametrize("qdt", QDTYPES)
def test_coa_quantized_apply_exact_and_bounded(wm, qdt):
    w, abl = wm[0], wm[2]
    fmt = F.CondensedOverActive.export_from_dense(w, abl, quantize_spec=qdt)
    assert fmt.values_dtype == qdt and fmt.scales is not None
    x = jax.random.normal(jax.random.PRNGKey(5), (2, D_IN))
    y = fmt.apply(x, w)
    deq = np.asarray(F.dequantize_values(fmt.values, fmt.scales))
    xg = np.take(np.asarray(x), np.asarray(fmt.indices), axis=1)
    compact = (xg * deq[None]).sum(-1)               # (B, a)
    oi = np.asarray(fmt.out_index)
    y_ref = np.zeros((2, D_OUT), np.float32)
    valid = oi < D_OUT
    y_ref[:, oi[valid]] = compact[:, valid]
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    assert _rel(y, x @ (w * abl)) <= ORACLE_REL[qdt]


@pytest.mark.parametrize("qdt", QDTYPES)
def test_structured_quantized_apply_bounded(wm, qdt):
    w, abl_only = wm[0], wm[3]
    fmt = F.StructuredFanIn.export_from_dense(w, abl_only, quantize_spec=qdt)
    assert fmt.values_dtype == qdt and fmt.scales is not None
    assert fmt.values.dtype == jnp.dtype(F.VALUES_DTYPES[qdt])
    x = jax.random.normal(jax.random.PRNGKey(6), (2, D_IN))
    y = fmt.apply(x, w)
    assert _rel(y, x @ (w * abl_only)) <= ORACLE_REL[qdt]


def test_float_quantize_spec_keeps_float_values_no_scales(wm):
    w, mask = wm[0], wm[1]
    for spec, dt in ((None, jnp.float32), ("f32", jnp.float32),
                     ("bf16", jnp.bfloat16)):
        fmt = F.Condensed.export_from_dense(w, mask, quantize_spec=spec)
        assert fmt.values.dtype == dt and fmt.scales is None


# ---------------------------------------------------------------------------
# kernels: dequant-fused matmuls match the scale-after-sum jnp reference
# ---------------------------------------------------------------------------

def _condensed_operands(b, d_in, n_out, k, seed=7):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, d_in), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k))
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    q, s = F.quantize_values(v, "int8")
    y_ref = (jnp.take(x, idx, axis=1)
             * F.dequantize_values(q, s)[None]).sum(-1)
    return x, q, idx, s, y_ref


def test_condensed_matmul_decode_scaled_matches_reference():
    x, q, idx, s, y_ref = _condensed_operands(2, 64, 128, 13)
    y = cm.condensed_matmul_decode(x, q, idx, scales=s, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_condensed_matmul_tiled_scaled_matches_reference():
    x, q, idx, s, y_ref = _condensed_operands(32, 64, 128, 13)
    y = cm.condensed_matmul(x, q, idx, scales=s, block_b=8, block_n=64,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def _coa_operands(b, d_in, d_out, seed=8):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d_in, d_out))
    col = (jnp.arange(d_out) % 4) != 0
    mask = jnp.broadcast_to(col[None, :], (d_in, d_out))
    fmt = F.CondensedOverActive.export_from_dense(w, mask,
                                                  quantize_spec="int8")
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d_in))
    return x, fmt, np.asarray(x @ (w * mask))


@pytest.mark.parametrize("b", [2, 32])
def test_coa_matmul_scaled_matches_oracle_within_bound(b):
    x, fmt, oracle = _coa_operands(b, 32, 96)
    y = sm.condensed_over_active_matmul(
        x, fmt.values, fmt.indices, fmt.out_index, fmt.d_out,
        scales=fmt.scales, interpret=True,
        **({} if b <= 8 else {"block_b": 8, "block_n": 64}))
    assert _rel(y, oracle) <= ORACLE_REL["int8"]


# ---------------------------------------------------------------------------
# out-blocked epilogue: bit-identical to the unblocked scatter
# ---------------------------------------------------------------------------

def _structured_setup(b, d_in, d_out, seed=9):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d_in, d_out))
    col = (jnp.arange(d_out) % 3) != 0
    fmt = F.StructuredFanIn.export_from_dense(
        w, jnp.broadcast_to(col[None, :], (d_in, d_out)))
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d_in))
    return x, w, fmt.active_index


def test_structured_decode_block_o_bit_identical():
    x, w, ai = _structured_setup(2, 32, 256)
    base = sm.structured_matmul_decode(x, w, ai, interpret=True,
                                       prefetch_gather=False)
    tiled = sm.structured_matmul_decode(x, w, ai, block_o=128,
                                        interpret=True,
                                        prefetch_gather=False)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


def test_structured_tiled_block_o_bit_identical():
    x, w, ai = _structured_setup(32, 32, 256)
    base = sm.structured_matmul(x, w, ai, block_b=8, block_n=128,
                                interpret=True)
    tiled = sm.structured_matmul(x, w, ai, block_b=8, block_n=128,
                                 block_o=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


@pytest.mark.parametrize("b", [2, 32])
def test_coa_block_o_bit_identical(b):
    x, fmt, _ = _coa_operands(b, 32, 256)
    kw = {} if b <= 8 else {"block_b": 8, "block_n": 64}
    base = sm.condensed_over_active_matmul(
        x, fmt.values, fmt.indices, fmt.out_index, fmt.d_out,
        scales=fmt.scales, interpret=True, **kw)
    tiled = sm.condensed_over_active_matmul(
        x, fmt.values, fmt.indices, fmt.out_index, fmt.d_out,
        scales=fmt.scales, block_o=128, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


# ---------------------------------------------------------------------------
# scalar-prefetch gather: HLO dispatch count + numerics
# ---------------------------------------------------------------------------

def _gather_count(hlo_text: str) -> int:
    """Occurrences of the ``hoisted_column_gather`` scope tag in op_name
    metadata — the ONE XLA gather pass the decode scan hoists (see
    structured_matmul._gather_columns). The scalar-prefetch variant performs
    the gather in-kernel, so its program must not carry the tag at all."""
    return hlo_text.count("hoisted_column_gather")


def test_prefetch_gather_removes_hoisted_column_gather_from_hlo():
    x, w, ai = _structured_setup(2, 16, 128)

    def lower(prefetch):
        return jax.jit(
            lambda x, w, ai: sm.structured_matmul_decode(
                x, w, ai, interpret=True, prefetch_gather=prefetch)
        ).lower(x, w, ai).compile().as_text()

    assert _gather_count(lower(False)) >= 1   # control: the hoist is there
    assert _gather_count(lower(True)) == 0    # prefetch: moved in-kernel


def test_prefetch_gather_matches_hoisted_variant():
    x, w, ai = _structured_setup(2, 16, 128)
    hoisted = sm.structured_matmul_decode(x, w, ai, interpret=True,
                                          prefetch_gather=False)
    prefetched = sm.structured_matmul_decode(x, w, ai, interpret=True,
                                             prefetch_gather=True)
    np.testing.assert_allclose(np.asarray(hoisted), np.asarray(prefetched),
                               atol=1e-5)


def test_prefetch_env_flag_default_off(monkeypatch):
    monkeypatch.delenv("REPRO_PREFETCH_GATHER", raising=False)
    assert sm._prefetch_default() is False
    monkeypatch.setenv("REPRO_PREFETCH_GATHER", "1")
    assert sm._prefetch_default() is True
    monkeypatch.setenv("REPRO_PREFETCH_GATHER", "0")
    assert sm._prefetch_default() is False


# ---------------------------------------------------------------------------
# VMEM cap override
# ---------------------------------------------------------------------------

def test_vmem_cap_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_CAP_BYTES", "123456")
    # the usable fraction still applies on top of the overridden cap
    # (double-buffering headroom — see the vmem_budget_bytes docstring)
    assert cm.vmem_budget_bytes() == int(123456 * cm.VMEM_USABLE_FRACTION)
    monkeypatch.delenv("REPRO_VMEM_CAP_BYTES")
    assert cm.vmem_budget_bytes() != int(123456 * cm.VMEM_USABLE_FRACTION)


def test_vmem_tiny_cap_keeps_minimum_block(monkeypatch):
    # documented stance: the (8, 128) minimum is kept even over budget
    monkeypatch.setenv("REPRO_VMEM_CAP_BYTES", "4096")
    cands = cm.block_candidates(8, 64, 128, 13)
    assert (8, 128) in cands


# ---------------------------------------------------------------------------
# tuning keys: quantized width tags, float keys byte-identical legacy
# ---------------------------------------------------------------------------

def test_tuning_key_float_layout_unchanged():
    key = F.shape_tuning_key(64, 128, 13, 1, backend="cpu", itemsize=4)
    assert key == "cpu/w32/d64/n128/k13/b1"
    key16 = F.shape_tuning_key(64, 128, 13, 1, backend="cpu", itemsize=2)
    assert key16 == "cpu/w16/d64/n128/k13/b1"
    # "f32" spelled explicitly resolves to the same legacy key as None
    assert F.shape_tuning_key(64, 128, 13, 1, backend="cpu", itemsize=4,
                              values_dtype="f32") == key


def test_tuning_key_quantized_width_tag():
    key = F.shape_tuning_key(64, 128, 13, 1, backend="cpu", itemsize=4,
                             values_dtype="int8")
    assert key == "cpu/wint8/d64/n128/k13/b1"
    if HAS_FP8:
        key8 = F.shape_tuning_key(64, 128, 13, 1, backend="cpu", itemsize=4,
                                  values_dtype="fp8")
        assert key8 == "cpu/wfp8/d64/n128/k13/b1"
        assert key8 != key  # same byte width, distinct key spaces


def test_quantized_leaf_tuning_key_tagged(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask, quantize_spec="int8")
    assert "/wint8/" in fmt.tuning_key(1)
    f32 = F.Condensed.export_from_dense(w, mask)
    assert "/w32/" in f32.tuning_key(1)


# ---------------------------------------------------------------------------
# autotune: quantized smoke — tuned never slower than default, key tagged
# ---------------------------------------------------------------------------

def test_autotune_quantized_smoke():
    from repro.sparse import autotune as AT
    res = AT.autotune_blocks(1, 64, 128, 13, reps=1, values_dtype="int8",
                             save=False)
    assert "/wint8/" in res.key
    assert res.us <= res.default_us  # the default is IN the measured table
    assert res.table


# ---------------------------------------------------------------------------
# 0.35x acceptance: int8 value stream vs f32, priced AND measured
# ---------------------------------------------------------------------------

class _Shape(typing.NamedTuple):
    d_in: int
    d_out: int


@pytest.mark.parametrize("d_in,d_out,k", [(64, 128, 13), (128, 256, 26)])
def test_int8_value_stream_at_most_035x_of_f32(d_in, d_out, k):
    """The PR's headline number at the benchmark decode fan-ins: int8 values
    + f32 per-neuron scales stream <= 0.35x the bytes of f32 values —
    (k + 4) / (4k), so it needs k >= 10 (documented in the benchmark)."""
    stats = F.ExportStats(k=k, max_active=d_out, active_fraction=1.0)
    shape = _Shape(d_in, d_out)
    priced_q = F.Condensed.estimate_values_bytes(
        F.spec_for_stack(shape, stats, 4, "int8"))
    priced_f = F.Condensed.estimate_values_bytes(
        F.spec_for_stack(shape, stats, 4))
    assert priced_q / priced_f <= 0.35
    assert priced_q / priced_f == (k + 4) / (4 * k)

    mask = topology.random_constant_fan_in_mask(
        jax.random.PRNGKey(0), d_in, d_out, k)
    w = jax.random.normal(jax.random.PRNGKey(1), (d_in, d_out))
    leaf_q = F.Condensed.export_from_dense(w, mask, quantize_spec="int8")
    leaf_f = F.Condensed.export_from_dense(w, mask)
    measured_q = leaf_q.values.nbytes + leaf_q.scales.nbytes
    measured_f = leaf_f.values.nbytes
    assert measured_q / measured_f <= 0.35
    # priced == measured: the estimator prices exactly what export allocates
    assert measured_q == priced_q and measured_f == priced_f


# ---------------------------------------------------------------------------
# checkpoint round-trips: f32 archive <-> quantized template
# ---------------------------------------------------------------------------

class _State(typing.NamedTuple):
    step: jnp.int32
    serve: dict


def test_checkpoint_f32_archive_restores_into_quantized_template(
        wm, tmp_path):
    """A pre-quantization archive (float values, no scales) restores into an
    int8 template: the restored float values are quantized and the missing
    scales rebuilt — NOT left at the template's (wrong) scales."""
    from repro.train import checkpoint as CKPT

    w, mask = wm[0], wm[1]
    f32 = F.Condensed.export_from_dense(w, mask)
    CKPT.save(str(tmp_path), _State(step=jnp.int32(1),
                                    serve={"stack": f32}))

    # template exported from DIFFERENT weights so its scales are wrong on
    # purpose — the restore must re-derive them from the archive's values
    w2 = jax.random.normal(jax.random.PRNGKey(7), (D_IN, D_OUT))
    tmpl = F.Condensed.export_from_dense(w2, mask, quantize_spec="int8")
    got = CKPT.restore(str(tmp_path), 1,
                       _State(step=jnp.int32(0),
                              serve={"stack": tmpl})).serve["stack"]
    assert got.values_dtype == "int8"
    assert got.values.dtype == jnp.int8 and got.scales is not None
    q, s = F.quantize_values(f32.values, "int8")
    np.testing.assert_array_equal(np.asarray(got.values), np.asarray(q))
    np.testing.assert_allclose(np.asarray(got.scales), np.asarray(s))
    x = jax.random.normal(jax.random.PRNGKey(8), (2, D_IN))
    assert _rel(got.apply(x, w), x @ (w * mask)) <= ORACLE_REL["int8"]


def test_checkpoint_quantized_archive_restores_into_f32_template(
        wm, tmp_path):
    """The reverse direction: a quantized archive restores into a float
    template by dequantizing through the ADOPTED scales (a blind astype
    would reinterpret int8 codes as floats)."""
    from repro.train import checkpoint as CKPT

    w, mask = wm[0], wm[1]
    qfmt = F.Condensed.export_from_dense(w, mask, quantize_spec="int8")
    CKPT.save(str(tmp_path), _State(step=jnp.int32(2),
                                    serve={"stack": qfmt}))

    tmpl = F.Condensed.export_from_dense(
        jnp.zeros((D_IN, D_OUT), jnp.float32), mask)
    got = CKPT.restore(str(tmp_path), 2,
                       _State(step=jnp.int32(0),
                              serve={"stack": tmpl})).serve["stack"]
    assert got.values_dtype is None
    assert got.values.dtype == jnp.float32 and got.scales is None
    np.testing.assert_allclose(
        np.asarray(got.values),
        np.asarray(F.dequantize_values(qfmt.values, qfmt.scales)))
    x = jax.random.normal(jax.random.PRNGKey(9), (2, D_IN))
    assert _rel(got.apply(x, w), x @ (w * mask)) <= ORACLE_REL["int8"]


# ---------------------------------------------------------------------------
# plan: values_dtype exports quantized leaves, prices real bytes, survives
# refresh
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_setup():
    from repro import configs
    from repro.models import model as M
    from repro.sparse import registry as REG
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    return cfg, reg, params, masks


def test_plan_int8_exports_quantized_leaves_and_prices_bytes(smoke_setup):
    from repro.sparse import plan as PLAN
    from repro.sparse import registry as REG
    cfg, reg, params, masks = smoke_setup
    pf = PLAN.build_plan(cfg, reg, params, masks, batch_size=1,
                         path="condensed")
    pq = PLAN.build_plan(cfg, reg, params, masks, batch_size=1,
                         path="condensed", values_dtype="int8")
    assert pq.values_dtype == "int8"
    for s in reg:
        leaf = REG.get_path(pq.serving_tree, s.path)
        assert isinstance(leaf, F.Condensed)
        assert leaf.values.dtype == jnp.int8 and leaf.scales is not None
    assert pq.weight_bytes() < pf.weight_bytes()
    assert "values_dtype=int8" in pq.describe()


def test_plan_refresh_preserves_values_dtype(smoke_setup):
    from repro.sparse import plan as PLAN
    from repro.sparse import registry as REG
    cfg, reg, params, masks = smoke_setup
    plan = PLAN.build_plan(cfg, reg, params, masks, batch_size=1,
                          path="condensed", values_dtype="int8",
                          mask_versions={s.name: 0 for s in reg})
    # topology change on every stack: drop the last quarter of columns
    new_masks = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        cut = s.d_out - max(1, s.d_out // 4)
        REG.set_path(new_masks, s.path,
                     m & (jnp.arange(s.d_out) < cut)[None, :])
    changed = plan.refresh(params, new_masks, {s.name: 1 for s in reg})
    assert set(changed) == {s.name for s in reg}
    assert plan.values_dtype == "int8"
    for s in reg:
        leaf = REG.get_path(plan.serving_tree, s.path)
        assert leaf.values.dtype == jnp.int8 and leaf.scales is not None


def test_engine_values_dtype_resolves_and_keys(smoke_setup):
    from repro.launch.engine import ServingEngine
    cfg, reg, params, masks = smoke_setup
    eng = ServingEngine(cfg, params, masks, reg, path="condensed",
                        paged=False, values_dtype="int8")
    assert eng.values_dtype == "int8"
    plan = eng.plan_for(eng.plan_key(1))
    assert plan.values_dtype == "int8"
    # "f32" resolves to None — same plans/keys as the unspecified default
    eng_f = ServingEngine(cfg, params, masks, reg, path="condensed",
                          paged=False, values_dtype="f32")
    assert eng_f.values_dtype is None


# ---------------------------------------------------------------------------
# donated refresh paths keep quantized storage without reallocating
# ---------------------------------------------------------------------------

def test_refresh_values_requantizes_in_place(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask, quantize_spec="int8")
    w2 = w * 1.5
    out = fmt.refresh_values(w2, mask)
    assert out.values.dtype == jnp.int8 and out.scales is not None
    fresh = F.Condensed.export_from_dense(w2, mask, quantize_spec="int8")
    np.testing.assert_array_equal(np.asarray(out.values),
                                  np.asarray(fresh.values))
    # the donated program computes amax/qmax in a different op order than
    # the fresh export — identical to float rounding, not bitwise
    np.testing.assert_allclose(np.asarray(out.scales),
                               np.asarray(fresh.scales), rtol=1e-5)


def test_donate_refresh_requantizes_new_topology(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask, quantize_spec="int8")
    # same fan-in, different topology: the donated fast path applies
    mask2 = topology.random_constant_fan_in_mask(
        jax.random.PRNGKey(11), D_IN, D_OUT, K)
    out = fmt.donate_refresh(w, mask2)
    fresh = F.Condensed.export_from_dense(w, mask2, quantize_spec="int8")
    np.testing.assert_array_equal(np.asarray(out.values),
                                  np.asarray(fresh.values))
    np.testing.assert_array_equal(np.asarray(out.indices),
                                  np.asarray(fresh.indices))
    np.testing.assert_allclose(np.asarray(out.scales),
                               np.asarray(fresh.scales))
