"""Protocol conformance for the four serving formats (repro.sparse.formats).

One parametrized suite runs over MaskedDense / Condensed / StructuredFanIn /
CondensedOverActive and asserts the protocol contracts the plan, engine and
kernel layers rely on:

* pytree round-trip through ``jit`` and ``device_put`` (arrays traced,
  statics preserved);
* ``apply`` agreement with the masked-dense reference on shared topologies
  (all masks for the exact formats; ablation-only masks for structured);
* ``cost`` >= 0 and monotone (non-decreasing) in batch;
* ``tuning_key`` stability (same instance -> same string; survives the
  pytree round-trip; None only for formats with no tunable kernel);
* the legacy dict-leaf deprecation shim: recognized key sets upgrade,
  unrecognized extras raise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.models import layers as L
from repro.sparse import formats as F
from repro.sparse import plan as PLAN

D_IN, D_OUT, K = 32, 48, 5
ALL_FORMATS = tuple(F.FORMATS.values())


@pytest.fixture(scope="module")
def wm():
    """A (weight, fine-grained mask, ablation-only mask) triple."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (D_IN, D_OUT), jnp.float32)
    mask = topology.random_constant_fan_in_mask(
        jax.random.fold_in(key, 1), D_IN, D_OUT, K)
    # ablate the last quarter of output neurons on top of the fan-in mask
    cut = D_OUT - D_OUT // 4
    abl = mask & (jnp.arange(D_OUT) < cut)[None, :]
    abl_only = jnp.broadcast_to((jnp.arange(D_OUT) < cut)[None, :],
                                (D_IN, D_OUT))
    return w, mask, abl, abl_only


def _export(cls, w, mask):
    return cls.export_from_dense(w, mask)


# ---------------------------------------------------------------------------
# pytree round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
def test_pytree_roundtrip_through_jit_and_device_put(cls, wm):
    w, mask = wm[0], wm[2]   # ablated mask: exercises every format's arrays
    fmt = _export(cls, w, mask)

    rt = jax.jit(lambda f: f)(fmt)
    assert type(rt) is type(fmt)
    for name in cls._static_fields:
        assert getattr(rt, name) == getattr(fmt, name)
    for name in cls._array_fields:
        if getattr(fmt, name) is None:   # optional fields (e.g. scales)
            assert getattr(rt, name) is None
            continue
        np.testing.assert_array_equal(np.array(getattr(rt, name)),
                                      np.array(getattr(fmt, name)))

    dp = jax.device_put(fmt)
    assert type(dp) is type(fmt)
    for name in cls._array_fields:
        if getattr(fmt, name) is None:
            assert getattr(dp, name) is None
            continue
        np.testing.assert_array_equal(np.array(getattr(dp, name)),
                                      np.array(getattr(fmt, name)))


@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
def test_scan_slices_stacked_formats_per_layer(cls, wm):
    """The model scans layer stacks with the masks pytree as scan xs: a
    format whose arrays carry a leading layer dim must slice per step and
    reconstruct with statics intact."""
    w, mask = wm[0], wm[2]
    stacked = _export(cls, jnp.stack([w, w * 2.0]), jnp.stack([mask, mask]))

    def body(carry, fmt_i):
        assert type(fmt_i) is cls
        return carry, fmt_i.apply(carry, w)

    x = jax.random.normal(jax.random.PRNGKey(3), (2, D_IN))
    _, ys = jax.lax.scan(body, x, stacked)
    assert ys.shape == (2, 2, D_OUT)


# ---------------------------------------------------------------------------
# apply exactness vs masked reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
@pytest.mark.parametrize("which", ["fanin", "ablated", "ablation_only"])
def test_apply_matches_masked_reference(cls, which, wm):
    w, mask, abl, abl_only = wm
    m = {"fanin": mask, "ablated": abl, "ablation_only": abl_only}[which]
    if cls is F.StructuredFanIn and which != "ablation_only":
        pytest.skip("structured is exact only for ablation-only masks "
                    "(documented Fig. 4 contract)")
    x = jax.random.normal(jax.random.PRNGKey(2), (4, D_IN))
    ref = x @ (w * m)
    got = _export(cls, w, m).apply(x, w)
    np.testing.assert_allclose(np.array(got), np.array(ref), atol=1e-5)


@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
def test_layers_linear_dispatches_on_type(cls, wm):
    w, _, abl, abl_only = wm
    m = abl_only if cls is F.StructuredFanIn else abl
    x = jax.random.normal(jax.random.PRNGKey(4), (3, D_IN))
    got = L.linear(x, w, _export(cls, w, m))
    np.testing.assert_allclose(np.array(got), np.array(x @ (w * m)),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
def test_cost_nonnegative_and_monotone_in_batch(cls, wm):
    w, mask = wm[0], wm[2]
    fmt = _export(cls, w, mask)
    for profile in (PLAN.DEFAULT_PROFILE,
                    dataclasses.replace(PLAN.DEFAULT_PROFILE,
                                        gather_flops_per_s_large=1.0e12)):
        prev = 0.0
        for batch in (1, 2, 8, 32, 128, 512, 2048):
            c = fmt.cost(batch, profile)
            assert np.isfinite(c) and c >= 0.0
            assert c >= prev  # more rows never cost less
            prev = c


@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
def test_estimate_weight_bytes_positive_and_matches_instance_spec(cls, wm):
    w, mask = wm[0], wm[2]
    fmt = _export(cls, w, mask)
    b = cls.estimate_weight_bytes(fmt.spec())
    assert b > 0
    # condensed-over-active must undercut plain condensed once ablated
    if cls is F.CondensedOverActive:
        cond = F.Condensed.export_from_dense(w, mask)
        assert b < F.Condensed.estimate_weight_bytes(cond.spec())


def test_two_point_gather_rate_interpolates_and_clamps():
    prof = dataclasses.replace(PLAN.DEFAULT_PROFILE,
                               gather_flops_per_s=4.0e12,
                               gather_flops_per_s_large=1.0e12,
                               gather_small_batch=8, gather_large_batch=512)
    assert prof.gather_rate(1) == prof.gather_rate(8) == 4.0e12
    assert prof.gather_rate(512) == prof.gather_rate(4096) == 1.0e12
    mid = prof.gather_rate(64)  # geometric midpoint of 8..512
    assert 1.0e12 < mid < 4.0e12
    assert mid == pytest.approx(2.0e12, rel=1e-6)
    # single-point profiles keep the old scalar behavior
    assert PLAN.DEFAULT_PROFILE.gather_rate(1) == \
        PLAN.DEFAULT_PROFILE.gather_rate(2048) == \
        PLAN.DEFAULT_PROFILE.gather_flops_per_s


# ---------------------------------------------------------------------------
# tuning keys
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", ALL_FORMATS, ids=lambda c: c.format_name)
def test_tuning_key_stability(cls, wm):
    w, mask = wm[0], wm[2]
    fmt = _export(cls, w, mask)
    k1 = fmt.tuning_key(4, backend="cpu")
    k2 = fmt.tuning_key(4, backend="cpu")
    assert k1 == k2
    rt = jax.jit(lambda f: f)(fmt)
    assert rt.tuning_key(4, backend="cpu") == k1
    if cls in F.CONDENSED_FAMILY or cls is F.StructuredFanIn:
        assert isinstance(k1, str) and "/b8" in k1  # batch 4 -> bucket 8
        # batches in the same bucket share the key; other buckets do not
        assert fmt.tuning_key(8, backend="cpu") == k1
        assert fmt.tuning_key(9, backend="cpu") != k1
    else:
        assert k1 is None  # no tunable kernel behind masked
    if cls is F.StructuredFanIn:
        # the structured kernel's key space is tagged apart from condensed
        assert "/structured-o" in k1
    if cls is F.CondensedOverActive:
        # the fused scatter-epilogue kernel's key space carries the dense
        # scatter width (part of its VMEM geometry)
        assert "/coa-o" in k1


def test_tuning_key_matches_ops_trace_time_derivation(wm):
    """The key a Condensed instance reports is byte-for-byte the key the
    kernel dispatch derives from its argument shapes at trace time."""
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask)
    n_out, k = fmt.values.shape[-2:]
    assert fmt.tuning_key(4, backend="cpu") == F.shape_tuning_key(
        D_IN, n_out, k, 4, backend="cpu",
        itemsize=jnp.dtype(fmt.values.dtype).itemsize)
    # and the spec-level (allocation-free) derivation agrees
    assert F.Condensed.spec_tuning_key(fmt.spec(), 4, backend="cpu") == \
        fmt.tuning_key(4, backend="cpu")


# ---------------------------------------------------------------------------
# donate_refresh / refresh_values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", F.CONDENSED_FAMILY,
                         ids=lambda c: c.format_name)
def test_donate_refresh_aliases_old_buffers_on_matching_avals(cls, wm):
    w, mask = wm[0], wm[2]
    stats = F._realized_stats(mask)
    fmt = cls.export_from_dense(w, mask, stats)
    live = [n for n in cls._array_fields if getattr(fmt, n) is not None]
    old_ptrs = {n: getattr(fmt, n).unsafe_buffer_pointer() for n in live}
    new = fmt.donate_refresh(w * 1.5, mask, stats)
    for n in live:
        assert getattr(fmt, n).is_deleted()
        assert getattr(new, n).unsafe_buffer_pointer() == old_ptrs[n]
    x = jax.random.normal(jax.random.PRNGKey(5), (2, D_IN))
    np.testing.assert_allclose(np.array(new.apply(x, w)),
                               np.array(x @ (w * 1.5 * mask)), atol=1e-5)


@pytest.mark.parametrize("cls", F.CONDENSED_FAMILY,
                         ids=lambda c: c.format_name)
def test_refresh_values_reuses_indices_verbatim(cls, wm):
    w, mask = wm[0], wm[2]
    fmt = cls.export_from_dense(w, mask)
    new = fmt.refresh_values(w * 2.0, mask, donate=False)
    assert new.indices is fmt.indices
    x = jax.random.normal(jax.random.PRNGKey(6), (2, D_IN))
    np.testing.assert_allclose(np.array(new.apply(x, w)),
                               np.array(x @ (w * 2.0 * mask)), atol=1e-5)


@pytest.mark.parametrize("cls", (F.MaskedDense, F.StructuredFanIn),
                         ids=lambda c: c.format_name)
def test_live_weight_formats_refresh_values_is_identity(cls, wm):
    w, mask = wm[0], wm[3]
    fmt = _export(cls, w, mask)
    assert fmt.refresh_values(w * 2.0, mask) is fmt


# ---------------------------------------------------------------------------
# legacy dict-leaf deprecation shim
# ---------------------------------------------------------------------------

def test_legacy_condensed_dict_upgrades(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask)
    with pytest.warns(DeprecationWarning):
        up = F.from_legacy_leaf({"values": fmt.values,
                                 "indices": fmt.indices}, d_in=D_IN)
    assert isinstance(up, F.Condensed) and up.d_in == D_IN
    x = jax.random.normal(jax.random.PRNGKey(7), (2, D_IN))
    np.testing.assert_array_equal(np.array(up.apply(x)),
                                  np.array(fmt.apply(x)))


def test_legacy_coa_and_structured_dicts_upgrade(wm):
    w, _, abl, abl_only = wm
    coa = F.CondensedOverActive.export_from_dense(w, abl)
    with pytest.warns(DeprecationWarning):
        up = F.from_legacy_leaf(coa.to_legacy_dict(), d_in=D_IN, d_out=D_OUT)
    assert isinstance(up, F.CondensedOverActive) and up.d_out == D_OUT
    st = F.StructuredFanIn.export_from_dense(w, abl_only)
    with pytest.warns(DeprecationWarning):
        up2 = F.from_legacy_leaf({"neuron_active": st.neuron_active})
    assert isinstance(up2, F.StructuredFanIn)


def test_legacy_coa_dict_without_d_out_raises(wm):
    w, _, abl, _ = wm
    coa = F.CondensedOverActive.export_from_dense(w, abl)
    with pytest.raises(ValueError, match="d_out"):
        F.from_legacy_leaf(coa.to_legacy_dict(), d_in=D_IN, warn=False)


def test_unrecognized_dict_keys_raise_clear_error(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask)
    bad = {"values": fmt.values, "indices": fmt.indices, "scales": fmt.values}
    with pytest.raises(ValueError, match="unrecognized serving-leaf"):
        F.from_legacy_leaf(bad, warn=False)
    # …including through the linear dispatch (no silent fall-through)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, D_IN))
    with pytest.raises(ValueError, match="unrecognized serving-leaf"):
        L.linear(x, w, bad)


def test_linear_accepts_legacy_dict_with_deprecation(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, D_IN))
    with pytest.warns(DeprecationWarning):
        got = L.linear(x, w, fmt.to_legacy_dict())
    np.testing.assert_allclose(np.array(got), np.array(x @ (w * mask)),
                               atol=1e-5)


def test_upgrade_serving_tree_walks_nested_dicts(wm):
    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask)
    tree = {"blocks": {"w_gate": fmt.to_legacy_dict(), "ln": w}}
    up = F.upgrade_serving_tree(tree, warn=False)
    assert isinstance(up["blocks"]["w_gate"], F.Condensed)
    assert up["blocks"]["ln"] is w  # non-leaf arrays untouched


def test_checkpoint_restores_legacy_dict_archive_into_format_template(
        wm, tmp_path):
    """Format array fields are checkpointed under the SAME keys the legacy
    dict leaves used, so an old archive (dict serving tree) restores into a
    new format-template tree — and a format tree round-trips."""
    import numpy as np_
    from repro.train import checkpoint as CKPT

    w, mask = wm[0], wm[1]
    fmt = F.Condensed.export_from_dense(w, mask)

    # "old" archive: dict leaves (the pre-redesign layout)
    old_state = {"step": jnp.zeros((), jnp.int32),
                 "serving": {"blocks": {"w_gate": fmt.to_legacy_dict()}}}
    CKPT.save(str(tmp_path), type("S", (), {
        "step": 0, "_asdict": lambda self=None: old_state})())

    template = {"step": jnp.zeros((), jnp.int32),
                "serving": {"blocks": {"w_gate": F.Condensed(
                    values=jnp.zeros_like(fmt.values),
                    indices=jnp.zeros_like(fmt.indices), d_in=D_IN)}}}
    restored = CKPT.restore(str(tmp_path), 0, template)
    leaf = restored["serving"]["blocks"]["w_gate"]
    assert isinstance(leaf, F.Condensed) and leaf.d_in == D_IN
    np_.testing.assert_array_equal(np_.array(leaf.values),
                                   np_.array(fmt.values))
    np_.testing.assert_array_equal(np_.array(leaf.indices),
                                   np_.array(fmt.indices))

    # and the format tree itself checkpoints (save walks format nodes)
    CKPT.save(str(tmp_path), type("S", (), {
        "step": 1, "_asdict": lambda self=None: {
            "step": jnp.ones((), jnp.int32),
            "serving": {"blocks": {"w_gate": fmt}}}})())
    again = CKPT.restore(str(tmp_path), 1, template)
    np_.testing.assert_array_equal(
        np_.array(again["serving"]["blocks"]["w_gate"].values),
        np_.array(fmt.values))

    # pre-formats MASKED leaf: a bare bool array saved AT the stack path
    # restores into a MaskedDense template via the single-array fallback
    CKPT.save(str(tmp_path), type("S", (), {
        "step": 2, "_asdict": lambda self=None: {
            "step": 2 * jnp.ones((), jnp.int32),
            "serving": {"blocks": {"w_gate": mask}}}})())
    mtemplate = {"step": jnp.zeros((), jnp.int32),
                 "serving": {"blocks": {"w_gate": F.MaskedDense(
                     mask=jnp.zeros_like(mask))}}}
    back = CKPT.restore(str(tmp_path), 2, mtemplate)
    leaf = back["serving"]["blocks"]["w_gate"]
    assert isinstance(leaf, F.MaskedDense)
    np_.testing.assert_array_equal(np_.array(leaf.mask), np_.array(mask))


def test_legacy_key_access_still_works(wm):
    w, mask = wm[0], wm[2]
    coa = F.CondensedOverActive.export_from_dense(w, mask)
    assert "out_index" in coa and "neuron_active" not in coa
    np.testing.assert_array_equal(np.array(coa["values"]),
                                  np.array(coa.values))
    with pytest.raises(KeyError):
        coa["mask"]
