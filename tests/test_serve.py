"""Serving engine: path dispatch, scan-loop decode, masked==condensed tokens.

The paper's serving claim (Sec. 4.4) made executable: greedy decode through
the condensed constant fan-in representation must be token-identical to the
masked-dense path, because both evaluate the same function — only the weight
storage/compute representation differs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import serve
from repro.models import model as M
from repro.sparse import condensed as COND
from repro.sparse import registry as REG


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    return cfg, reg, params, masks, prompts


def test_condensed_decode_tokens_identical_to_masked(smoke_setup):
    cfg, reg, params, masks, prompts = smoke_setup
    cond = serve.build_serving_masks(cfg, reg, params, masks, "condensed")
    out_masked = serve.generate(cfg, params, masks, prompts, gen_len=10)
    out_cond = serve.generate(cfg, params, cond, prompts, gen_len=10)
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_cond))


def test_scan_loop_matches_python_token_loop(smoke_setup):
    """The jitted lax.scan generation loop reproduces the reference Python
    token loop exactly (same greedy argmax chain, same cache evolution)."""
    cfg, reg, params, masks, prompts = smoke_setup
    gen_len = 6
    b, t = prompts.shape

    # reference: per-token Python loop (the pre-scan serving driver)
    cache = M.init_cache(cfg, b, max_len=t + gen_len)
    logits, cache = M.prefill_step(cfg, params, masks, {"tokens": prompts}, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    toks_ref = []
    for _ in range(gen_len):
        toks_ref.append(cur)
        logits, cache = M.decode_step(cfg, params, masks, {"tokens": cur}, cache)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    toks_ref = jnp.concatenate(toks_ref, axis=1)

    out = serve.generate(cfg, params, masks, prompts, gen_len=gen_len)
    np.testing.assert_array_equal(np.array(out[:, t:]), np.array(toks_ref))


def test_structured_path_runs_and_differs(smoke_setup):
    """The structured (neuron-drop-only) path executes but is NOT
    output-equivalent for fine-grained sparsity — it is the Fig. 4 ablation,
    not a faithful representation of the masked function."""
    cfg, reg, params, masks, prompts = smoke_setup
    struct = serve.build_serving_masks(cfg, reg, params, masks, "structured")
    out = serve.generate(cfg, params, struct, prompts, gen_len=6)
    assert out.shape == (2, 8 + 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
    # and it really is a different function: structured keeps active columns
    # dense, so single-step decode logits must diverge from the masked path
    tok = prompts[:, :1]
    lm, _ = M.decode_step(cfg, params, masks, {"tokens": tok},
                          M.init_cache(cfg, 2, 4))
    ls, _ = M.decode_step(cfg, params, struct, {"tokens": tok},
                          M.init_cache(cfg, 2, 4))
    assert float(jnp.max(jnp.abs(lm - ls))) > 1e-4


def test_export_structured_neuron_active_matches_mask_columns(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    struct = COND.export_structured(cfg, reg, masks)
    for s in reg:
        na = REG.get_path(struct, s.path)["neuron_active"]
        m = REG.get_path(masks, s.path)
        np.testing.assert_array_equal(np.array(na), np.array(m).any(axis=-2))


def test_build_serving_masks_rejects_unknown_path(smoke_setup):
    cfg, reg, params, masks, _ = smoke_setup
    with pytest.raises(ValueError):
        serve.build_serving_masks(cfg, reg, params, masks, "csr")


def test_serve_main_cli_condensed_matches_masked(capsys):
    """The acceptance-criteria invocation, end to end through the CLI."""
    common = ["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
              "--prompt-len", "8", "--gen", "6"]
    out_masked = serve.main(common + ["--path", "masked"])
    out_cond = serve.main(common + ["--path", "condensed"])
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_cond))
    logs = capsys.readouterr().out
    assert "tok/s" in logs and "[serve:condensed]" in logs


def test_serve_main_cli_auto_plans_and_matches_masked(capsys):
    """``--path auto`` builds a per-stack plan at the request batch shape,
    prints the decisions, and stays token-identical to masked."""
    common = ["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
              "--prompt-len", "8", "--gen", "6"]
    out_masked = serve.main(common + ["--path", "masked"])
    out_auto = serve.main(common + ["--path", "auto"])
    np.testing.assert_array_equal(np.array(out_masked), np.array(out_auto))
    logs = capsys.readouterr().out
    # the engine plans at the request's BATCH BUCKET (shared with the
    # autotune cache keys), so --batch 2 is planned at bucket 8 — and the
    # printout must say BOTH, not silently swap the requested batch
    assert "[plan] path=auto batch=2 (bucket 8)" in logs
    assert "-> condensed" in logs  # B=2 is decode-like: gather wins
    assert "[serve:auto]" in logs
