"""Unit + property tests for the SRigL core (the paper's contribution)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import distributions as D
from repro.core import rigl, saliency, set_sparse, srigl, topology
from repro.core.schedule import DSTSchedule


# ---------------------------------------------------------------------------
# distributions
# ---------------------------------------------------------------------------

def test_erk_hits_global_budget():
    layers = [D.LayerShape("a", 512, 256), D.LayerShape("b", 64, 64),
              D.LayerShape("c", 2048, 1024, n_replicas=4)]
    for s in (0.5, 0.8, 0.9, 0.99):
        dens = D.erk_densities(layers, s)
        realized = D.realized_sparsity(layers, dens)
        assert abs(realized - s) < 1e-6
        assert all(0 < d <= 1 for d in dens.values())


def test_erk_small_layers_denser():
    layers = [D.LayerShape("big", 4096, 4096), D.LayerShape("small", 64, 64)]
    dens = D.erk_densities(layers, 0.9)
    assert dens["small"] > dens["big"]


def test_erk_caps_at_dense():
    layers = [D.LayerShape("tiny", 8, 8), D.LayerShape("big", 4096, 4096)]
    dens = D.erk_densities(layers, 0.5)
    assert dens["tiny"] <= 1.0
    assert abs(D.realized_sparsity(layers, dens) - 0.5) < 1e-6


def test_uniform():
    layers = [D.LayerShape("a", 128, 64)]
    assert D.uniform_densities(layers, 0.9)["a"] == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

@given(st.integers(4, 64), st.integers(2, 32), st.integers(1, 4), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_constant_fan_in_mask_property(d_in, d_out, k_div, seed):
    k = max(1, d_in // k_div // 2)
    mask = topology.random_constant_fan_in_mask(jax.random.PRNGKey(seed), d_in, d_out, k)
    assert topology.check_constant_fan_in(np.array(mask), k)


@given(st.integers(4, 48), st.integers(2, 24), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_condensed_roundtrip_property(d_in, d_out, seed):
    k = max(1, d_in // 3)
    key = jax.random.PRNGKey(seed)
    mask = topology.random_constant_fan_in_mask(key, d_in, d_out, k)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d_in, d_out)) * mask
    vals, idx = topology.dense_to_condensed(w, mask, k)
    back = topology.condensed_to_dense(vals, idx, d_in)
    np.testing.assert_allclose(np.array(back), np.array(w), atol=1e-6)


def test_unstructured_mask_nnz():
    m = topology.random_unstructured_mask(jax.random.PRNGKey(0), 32, 16, 100)
    assert int(m.sum()) == 100


# ---------------------------------------------------------------------------
# saliency helpers
# ---------------------------------------------------------------------------

@given(st.integers(16, 256), st.integers(1, 100), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_topk_threshold_count(n, k_pct, seed):
    k = max(1, n * k_pct // 200)
    vals = jax.random.uniform(jax.random.PRNGKey(seed), (n,))
    sel = saliency.select_topk_threshold(vals, jnp.ones((n,), bool), k)
    cnt = int(sel.sum())
    assert abs(cnt - k) <= 1  # distinct uniforms: exact up to fp-quantile ties
    # selected are the largest
    if cnt:
        assert float(vals[sel].min()) >= float(jnp.sort(vals)[-cnt])


def test_descending_ranks_axis():
    x = jnp.array([[3.0, 1.0], [2.0, 5.0], [9.0, 4.0]])
    r = saliency.descending_ranks(x, axis=0)
    np.testing.assert_array_equal(np.array(r[:, 0]), [1, 2, 0])
    np.testing.assert_array_equal(np.array(r[:, 1]), [2, 0, 1])


# ---------------------------------------------------------------------------
# SRigL update
# ---------------------------------------------------------------------------

def _rand_layer(seed, spec):
    key = jax.random.PRNGKey(seed)
    st_ = srigl.init_layer_state(key, spec)
    w = jax.random.normal(jax.random.fold_in(key, 1), (spec.d_in, spec.d_out)) * st_.mask
    g = jax.random.normal(jax.random.fold_in(key, 2), (spec.d_in, spec.d_out))
    return w, g, st_


@given(st.integers(0, 500), st.floats(0.01, 0.3), st.floats(0.05, 0.5))
@settings(max_examples=20, deadline=None)
def test_srigl_invariants_property(seed, density, drop_frac):
    spec = srigl.SRigLSpec("l", d_in=96, d_out=48, density=density, gamma_sal=0.3)
    w, g, st_ = _rand_layer(seed, spec)
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(drop_frac))
    m = np.array(new.mask)
    a = np.array(new.neuron_active)
    k = int(stats.fan_in)
    # constant fan-in invariant: active neurons have exactly k', ablated 0
    assert topology.check_constant_fan_in(m, k, a)
    # never below min_active_neurons
    assert a.sum() >= spec.min_active_neurons
    # budget approximately preserved
    assert abs(int(stats.nnz) - spec.target_nnz) <= spec.d_out * k


def test_srigl_ablation_fires_on_dead_neurons():
    """Neurons with tiny weights AND tiny grads must be ablated."""
    spec = srigl.SRigLSpec("l", d_in=64, d_out=32, density=0.1, gamma_sal=0.5)
    key = jax.random.PRNGKey(0)
    st_ = srigl.init_layer_state(key, spec)
    w = jax.random.normal(key, (64, 32)) * st_.mask
    g = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    # make half the neurons totally non-salient
    w = w.at[:, :16].multiply(1e-8)
    g = g.at[:, :16].multiply(1e-8)
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(0.3))
    assert int(stats.n_ablated) > 0
    assert np.array(new.neuron_active)[:16].sum() < 16
    # fan-in grew to compensate
    assert int(stats.fan_in) >= spec.k0


def test_srigl_no_ablation_flag():
    spec = srigl.SRigLSpec("l", 64, 32, density=0.1, gamma_sal=0.5, ablation=False)
    w, g, st_ = _rand_layer(3, spec)
    w = w.at[:, :16].multiply(1e-9)
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(0.3))
    assert int(stats.n_ablated) == 0
    assert bool(np.array(new.neuron_active).all())


def test_srigl_grows_high_gradient_positions():
    spec = srigl.SRigLSpec("l", 32, 8, density=0.25, gamma_sal=0.0, ablation=False)
    w, g, st_ = _rand_layer(7, spec)
    g = jnp.zeros_like(g).at[5, :].set(100.0)  # row 5: huge grads everywhere
    hot = ~st_.mask[5]  # positions that were inactive
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(0.4))
    grown = np.array(new.mask[5] & hot)
    # A hot position is grown whenever its column has capacity: prune
    # survivors always outrank grow candidates, so a column that kept k'
    # survivors has no room — every other hot column must grow row 5.
    from repro.core import saliency
    nnz = int(jnp.sum(st_.mask))
    n_prune = int(jnp.floor(0.4 * nnz))
    survive = saliency.select_topk_threshold(jnp.abs(w), st_.mask, nnz - n_prune)
    has_room = np.array(survive.sum(0)) < int(stats.fan_in)
    expected = np.array(hot) & has_room
    assert expected.sum() > 0  # the scenario actually exercises growth
    assert np.all(grown[expected])  # top-|G| positions grown wherever possible


def test_srigl_expert_stack_vmap():
    spec = srigl.SRigLSpec("l", 32, 16, density=0.2)
    key = jax.random.PRNGKey(0)
    e = 4
    masks = jnp.stack([srigl.init_layer_state(jax.random.fold_in(key, i), spec).mask
                       for i in range(e)])
    st_ = srigl.LayerState(masks, jnp.ones((e, 16), bool))
    w = jax.random.normal(key, (e, 32, 16)) * masks
    g = jax.random.normal(jax.random.fold_in(key, 9), (e, 32, 16))
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(0.2))
    for i in range(e):
        assert topology.check_constant_fan_in(
            np.array(new.mask[i]), int(stats.fan_in[i]), np.array(new.neuron_active[i]))


# ---------------------------------------------------------------------------
# RigL / SET baselines
# ---------------------------------------------------------------------------

def test_rigl_nnz_constant():
    spec = rigl.RigLSpec("r", 64, 32, 0.1)
    st_ = rigl.init_layer_state(jax.random.PRNGKey(0), spec)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * st_.mask
    g = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    new, stats = rigl.rigl_update(spec, w, g, st_, jnp.float32(0.3))
    assert int(stats["nnz"]) == spec.target_nnz


def test_rigl_implicit_ablation_detected():
    """RigL at very high sparsity leaves some neurons with zero fan-in (Fig. 3b)."""
    spec = rigl.RigLSpec("r", 256, 128, 0.01)
    st_ = rigl.init_layer_state(jax.random.PRNGKey(0), spec)
    key = jax.random.PRNGKey(1)
    stats = {}
    for i in range(5):
        w = jax.random.normal(jax.random.fold_in(key, 2 * i), (256, 128)) * st_.mask
        g = jax.random.normal(jax.random.fold_in(key, 2 * i + 1), (256, 128))
        st_, stats = rigl.rigl_update(spec, w, g, st_, jnp.float32(0.3))
    assert int(stats["n_ablated"]) > 0  # unstructured updates ablate neurons


def test_set_random_growth():
    spec = rigl.RigLSpec("r", 64, 32, 0.1)
    st_ = rigl.init_layer_state(jax.random.PRNGKey(0), spec)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * st_.mask
    new, stats = set_sparse.set_update(spec, w, jax.random.PRNGKey(3), st_,
                                       jnp.float32(0.3))
    assert int(stats["nnz"]) == spec.target_nnz
    assert int(stats["n_grown"]) > 0


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

def test_cosine_schedule():
    s = DSTSchedule(delta_t=100, alpha=0.3, t_end_fraction=0.75, total_steps=1000)
    assert float(s.drop_fraction(0)) == pytest.approx(0.3)
    assert float(s.drop_fraction(750)) == pytest.approx(0.0, abs=1e-6)
    assert float(s.drop_fraction(900)) == 0.0
    assert float(s.drop_fraction(375)) == pytest.approx(0.15, abs=1e-6)
    assert bool(s.is_update_step(100))
    assert not bool(s.is_update_step(150))
    assert not bool(s.is_update_step(0))
    assert not bool(s.is_update_step(800))  # past t_end


# ---------------------------------------------------------------------------
# SRigL invariants (hardened): budget, fan-in exactness, ablation floor
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.floats(0.02, 0.5), st.floats(0.05, 0.5),
       st.floats(0.0, 0.9), st.sampled_from([(96, 48), (64, 32), (33, 17)]))
@settings(max_examples=30, deadline=None)
def test_srigl_budget_and_structure_property(seed, density, drop_frac,
                                             gamma, shape):
    """The four structural invariants every update must preserve:
    (1) exact constant fan-in k' on active columns, (2) nnz <= target budget,
    (3) >= min_active_neurons survive, (4) ablated columns are all-zero."""
    d_in, d_out = shape
    spec = srigl.SRigLSpec("l", d_in=d_in, d_out=d_out, density=density,
                           gamma_sal=gamma)
    w, g, st_ = _rand_layer(seed, spec)
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(drop_frac))
    m = np.array(new.mask)
    a = np.array(new.neuron_active)
    k = int(stats.fan_in)
    # (1) every active column has exactly k' non-zeros
    assert topology.check_constant_fan_in(m, k, a)
    # (2) the non-zero budget is never exceeded (floor semantics in step 5)
    assert int(stats.nnz) <= spec.target_nnz, (int(stats.nnz), spec.target_nnz)
    assert int(m.sum()) == int(stats.nnz)
    # (3) ablation floor
    assert a.sum() >= spec.min_active_neurons
    # (4) ablated columns contribute nothing
    if (~a).any():
        assert m[:, ~a].sum() == 0


@given(st.integers(0, 2000), st.floats(0.02, 0.3))
@settings(max_examples=15, deadline=None)
def test_srigl_budget_monotone_over_repeated_updates(seed, density):
    """Budget never creeps upward across a chain of updates (the floor in
    step 5 makes nnz non-expansive even as ablation changes n_active)."""
    spec = srigl.SRigLSpec("l", d_in=64, d_out=24, density=density,
                           gamma_sal=0.4)
    w, g, st_ = _rand_layer(seed, spec)
    key = jax.random.PRNGKey(seed)
    for i in range(4):
        st_, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(0.3))
        assert int(stats.nnz) <= spec.target_nnz
        w = jax.random.normal(jax.random.fold_in(key, 2 * i), w.shape) * st_.mask
        g = jax.random.normal(jax.random.fold_in(key, 2 * i + 1), g.shape)


def test_srigl_min_active_neurons_floor_respected():
    """Even with every neuron non-salient, min_active_neurons survive and the
    survivors still satisfy constant fan-in."""
    spec = srigl.SRigLSpec("l", d_in=48, d_out=16, density=0.15, gamma_sal=1.0,
                           min_active_neurons=3)
    key = jax.random.PRNGKey(11)
    st_ = srigl.init_layer_state(key, spec)
    w = jnp.ones((48, 16)) * 1e-9 * st_.mask  # uniformly tiny: all non-salient
    g = jnp.ones((48, 16)) * 1e-9
    new, stats = srigl.srigl_update(spec, w, g, st_, jnp.float32(0.3))
    a = np.array(new.neuron_active)
    assert a.sum() >= 3
    assert topology.check_constant_fan_in(np.array(new.mask),
                                          int(stats.fan_in), a)
