"""Beyond-paper extensions: microbatching, ITOP, N:M masks, grad compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import topology
from repro.data.pipeline import SyntheticLM
from repro.optim import grad_compress as GC
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def test_microbatch_grad_accumulation_equivalent():
    """n-microbatch accumulation == full-batch step (same loss, ~same update)."""
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, delta_t=10_000))
    reg = REG.build_registry(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8, seed=0)
    batch = jax.tree.map(jnp.asarray, data.batch(0))

    s_full = init_train_state(cfg, jax.random.PRNGKey(0))
    s_micro = init_train_state(cfg, jax.random.PRNGKey(0))
    step_full = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(1e-2)))
    step_micro = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(1e-2),
                                         microbatches=4))
    s_full, m_full = step_full(s_full, batch)
    s_micro, m_micro = step_micro(s_micro, batch)
    assert abs(float(m_full["loss"]) - float(m_micro["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_micro.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_itop_rate_grows():
    """The union of explored weights grows across topology updates (App. H)."""
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, delta_t=3))
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    itop = REG.init_itop(reg, {"masks": state.masks})
    rate0 = REG.itop_rate(reg, itop)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=0)
    for i in range(9):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        state, _ = step(state, b)
        if (i + 1) % 3 == 0:
            state = dst(state, b)
            itop = REG.update_itop(itop, state.masks)
    rate1 = REG.itop_rate(reg, itop)
    assert all(rate1[k] >= rate0[k] for k in rate0)
    assert any(rate1[k] > rate0[k] + 0.01 for k in rate0)  # exploration happened
    # and the rate is a valid fraction >= instantaneous density
    for s in reg:
        assert rate0[s.name] <= rate1[s.name] <= 1.0


@given(st.integers(1, 4), st.sampled_from([4, 8, 16]), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_nm_mask_property(n, m, seed):
    n = min(n, m)
    mask = topology.random_nm_mask(jax.random.PRNGKey(seed), 32, 12, n, m)
    assert topology.check_nm(np.array(mask), n, m)
    # N:M with M = d_in degenerates to constant fan-in (the paper's relation)
    cfi = topology.random_nm_mask(jax.random.PRNGKey(seed), 32, 12, 4, 32)
    assert topology.check_constant_fan_in(np.array(cfi), 4)


def test_grad_compression_error_feedback():
    """int8 EF compression: per-step error is bounded and fed back (unbiased
    accumulation — the mean dequantized grad converges to the true mean)."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64, 64))}
    ef = GC.init_error_feedback(g)
    total_true = jnp.zeros((64, 64))
    total_deq = jnp.zeros((64, 64))
    for i in range(50):
        gi = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        comp, ef = GC.compress_int8(gi, ef)
        deq = GC.decompress_int8(comp)
        total_true += gi["w"]
        total_deq += deq["w"]
    # error feedback keeps the cumulative sums close (EF-SGD guarantee)
    err = float(jnp.max(jnp.abs(total_true - total_deq)))
    assert err < 0.2, err  # residual bounded by one quantization step
    # bf16 variant
    comp, ef2 = GC.compress_bf16({"w": g["w"]}, GC.init_error_feedback(g))
    assert comp["w"].dtype == jnp.bfloat16


def test_compression_byte_savings():
    g = {"w": jnp.ones((128, 128), jnp.float32)}
    comp, _ = GC.compress_int8(g, GC.init_error_feedback(g))
    q, scale = comp["w"]
    assert q.dtype == jnp.int8  # 4x fewer bytes over the DCN
    assert scale.shape == ()
