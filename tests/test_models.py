"""Per-architecture smoke tests + decode/prefill equivalence (reduced configs).

Every assigned architecture instantiates its reduced config, runs one forward
and one train step on CPU, and asserts output shapes and finiteness. Decode
paths are checked against the full forward teacher-forcing logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import make_train_batch
from repro.models import model as M
from repro.sparse import registry as REG


def _setup(name, **over):
    cfg = configs.get_smoke_config(name)
    if over:
        cfg = cfg.replace(**over)
    reg = REG.build_registry(cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"] if reg else {}
    return cfg, reg, params, masks


@pytest.mark.parametrize("name", configs.ALL_ARCHS)
def test_smoke_forward_and_grad(name):
    cfg, reg, params, masks = _setup(name)
    batch = make_train_batch(cfg, jax.random.PRNGKey(1), 2, 32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, masks, batch), has_aux=True)(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-130m", "gemma3-1b",
                                  "zamba2-7b", "musicgen-medium"])
def test_decode_matches_forward(name):
    cfg, reg, params, masks = _setup(name)
    key = jax.random.PRNGKey(2)
    B, T = 2, 20
    if cfg.family == "audio":
        toks = jax.random.randint(key, (B, cfg.n_codebooks, T), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    x, positions = M.embed_inputs(cfg, params, {"tokens": toks})
    hidden, _ = M.backbone(cfg, params, masks, x, positions=positions)
    if cfg.family == "audio":
        ref = jnp.stack([(hidden[:, -1] @ params["lm_head"][k]).astype(jnp.float32)
                         for k in range(cfg.n_codebooks)], 1)
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ref = (hidden[:, -1] @ head).astype(jnp.float32)
    cache = M.init_cache(cfg, B, max_len=T)
    step = jax.jit(lambda b, c: M.decode_step(cfg, params, masks, b, c))
    for t in range(T):
        b_t = {"tokens": toks[..., t:t + 1] if cfg.family == "audio" else toks[:, t:t + 1]}
        logits, cache = step(b_t, cache)
    v = cfg.vocab_size
    got = logits[..., :v] if cfg.family != "audio" else logits[..., :v]
    rel = float(jnp.max(jnp.abs(got - ref[..., :v]))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 1e-4, (name, rel)


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-130m", "gemma3-1b",
                                  "zamba2-7b"])
def test_prefill_matches_decode(name):
    cfg, reg, params, masks = _setup(name)
    key = jax.random.PRNGKey(3)
    B, T = 2, 16
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, B, max_len=T + 4)
    logitsA, cacheA = M.prefill_step(cfg, params, masks, {"tokens": toks}, cache)
    cacheB = M.init_cache(cfg, B, max_len=T + 4)
    for t in range(T):
        logitsB, cacheB = M.decode_step(cfg, params, masks,
                                        {"tokens": toks[:, t:t + 1]}, cacheB)
    rel = float(jnp.max(jnp.abs(logitsA - logitsB))) / (
        float(jnp.max(jnp.abs(logitsB))) + 1e-9)
    assert rel < 1e-4, (name, rel)
    # continuation from the prefilled cache
    nxt = jax.random.randint(jax.random.fold_in(key, 1), (B, 1), 0, cfg.vocab_size)
    lA, _ = M.decode_step(cfg, params, masks, {"tokens": nxt}, cacheA)
    lB, _ = M.decode_step(cfg, params, masks, {"tokens": nxt}, cacheB)
    rel2 = float(jnp.max(jnp.abs(lA - lB))) / (float(jnp.max(jnp.abs(lB))) + 1e-9)
    assert rel2 < 1e-4, (name, rel2)


def test_ring_buffer_cache_smaller_than_context():
    """gemma3 local layers: cache size == window even for long contexts."""
    cfg, reg, params, masks = _setup("gemma3-1b")
    cache = M.init_cache(cfg, 2, max_len=64)  # window is 16 in the smoke config
    assert cache["g_local"]["k"].shape[-3] == cfg.sliding_window
    assert cache["g_global"]["k"].shape[-3] == 64


def test_padded_heads_bit_exact():
    base = configs.get_smoke_config("musicgen-medium").replace(pad_heads_to=0)
    padded_cfg = configs.get_smoke_config("musicgen-medium").replace(pad_heads_to=8)
    key = jax.random.PRNGKey(0)
    params = M.init_params(base, key)
    pp = M.init_params(padded_cfg, key)
    # embed the unpadded attention weights into the padded tensors
    for k in pp["blocks"]:
        w, wp = params["blocks"][k], pp["blocks"][k]
        if k in ("wq", "wk", "wv"):
            pp["blocks"][k] = jnp.zeros_like(wp).at[..., :w.shape[-1]].set(w)
        elif k == "wo":
            pp["blocks"][k] = jnp.zeros_like(wp).at[..., :w.shape[-2], :].set(w)
        else:
            pp["blocks"][k] = w
    for k in ("embed", "lm_head", "final_norm"):
        pp[k] = params[k]
    batch = make_train_batch(base, jax.random.PRNGKey(1), 2, 16)
    l0, _ = M.loss_fn(base, params, {}, batch)
    l1, _ = M.loss_fn(padded_cfg, pp, {}, batch)
    assert abs(float(l0) - float(l1)) < 1e-5


def test_vocab_padding_masked_in_loss_and_logits():
    cfg, reg, params, masks = _setup("qwen3-1.7b", pad_vocab_to=64)
    assert cfg.vocab_padded == 256  # smoke vocab is 256 — already aligned
    cfg2 = cfg.replace(vocab_size=250, pad_vocab_to=64)
    params2 = M.init_params(cfg2, jax.random.PRNGKey(0), REG.k_fan_map(cfg2, reg))
    assert params2["embed"].shape[0] == 256
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, 250)
    cache = M.init_cache(cfg2, 2, max_len=8)
    logits, _ = M.decode_step(cfg2, params2, {}, {"tokens": toks[:, :1]}, cache)
    assert logits.shape[-1] == 256
    assert bool(jnp.all(logits[:, 250:] == -jnp.inf))


def test_moe_aux_loss_and_capacity():
    cfg, reg, params, masks = _setup("granite-moe-1b-a400m")
    batch = make_train_batch(cfg, jax.random.PRNGKey(1), 2, 32)
    loss, metrics = M.loss_fn(cfg, params, masks, batch)
    assert float(metrics["aux_loss"]) > 0.5  # ~1.0 for balanced routing


def test_mrope_changes_output():
    cfg, reg, params, masks = _setup("qwen2-vl-7b")
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, cfg.vocab_size)
    p = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    b1 = {"tokens": toks, "mrope_positions": jnp.stack([p, p, p])}
    b2 = {"tokens": toks, "mrope_positions": jnp.stack([p, p * 2, p * 3])}
    x1, pos1 = M.embed_inputs(cfg, params, b1)
    x2, pos2 = M.embed_inputs(cfg, params, b2)
    h1, _ = M.backbone(cfg, params, masks, x1, positions=pos1)
    h2, _ = M.backbone(cfg, params, masks, x2, positions=pos2)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-4
