"""End-to-end behaviour: the paper's full story on one small model.

Train a small sparse LM with SRigL -> loss drops, constant fan-in holds,
ablation happens at high sparsity -> export the condensed representation ->
condensed serving matches masked-dense serving exactly (the "same weights,
two representations" claim, paper Sec. 4.4).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import topology
from repro.data.pipeline import SyntheticLM
from repro.kernels import ops
from repro.models import model as M
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def test_end_to_end_srigl_train_export_serve():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(sparsity=dataclasses.replace(cfg.sparsity, delta_t=5,
                                                   sparsity=0.8))
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8, seed=0)

    first_loss = last_loss = None
    for i in range(40):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, metrics = step(state, batch)
        if first_loss is None:
            first_loss = float(metrics["loss"])
        last_loss = float(metrics["loss"])
        if (i + 1) % 5 == 0:
            state = dst(state, batch)
    assert last_loss < first_loss - 0.2, (first_loss, last_loss)

    # --- invariants after training -----------------------------------------
    for s in reg:
        m = np.array(REG.get_path(state.masks, s.path))
        a = np.array(REG.get_path(state.neuron_active, s.path))
        m2 = m.reshape(-1, *m.shape[-2:])
        a2 = a.reshape(-1, a.shape[-1])
        for j in range(m2.shape[0]):
            nnz = m2[j].sum(0)
            k = nnz[a2[j]].max() if a2[j].any() else 0
            assert topology.check_constant_fan_in(m2[j], int(k), a2[j])

    # --- condensed export: same weights, two representations ---------------
    s0 = reg[0]  # wo stack
    w = np.array(REG.get_path(state.params, s0.path))[0]       # layer 0
    m = np.array(REG.get_path(state.masks, s0.path))[0]
    k = int(m.sum(0).max())
    vals, idx = topology.dense_to_condensed(jnp.asarray(w * m), jnp.asarray(m), k)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, w.shape[0]))
    y_cond = ops.condensed_linear(x, vals, idx)
    y_masked = x @ jnp.asarray(w * m)
    np.testing.assert_allclose(np.array(y_cond), np.array(y_masked), atol=1e-4)


def test_high_sparsity_triggers_ablation_end_to_end():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(d_ff=256, sparsity=dataclasses.replace(
        cfg.sparsity, delta_t=3, sparsity=0.97, gamma_sal=0.5))
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8, seed=0)
    for i in range(12):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, _ = step(state, batch)
        if (i + 1) % 3 == 0:
            state = dst(state, batch)
    summary = REG.sparsity_summary(reg, {"masks": state.masks,
                                         "neuron_active": state.neuron_active})
    frac_active = min(v["active_neurons"] for v in summary.values())
    assert frac_active < 1.0  # some neurons were ablated at 97% sparsity


def test_sparsity_summary_realized_density():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    reg = REG.build_registry(cfg)
    st = REG.init_sparsity_state(cfg, jax.random.PRNGKey(0), reg)
    summary = REG.sparsity_summary(reg, st)
    for s in reg:
        got = summary[s.name]["density"]
        assert abs(got - s.density) < 0.05


def test_condensed_decode_path_bit_exact():
    """Full-model decode through the condensed representation (Alg. 1) matches
    the masked-dense path bit-for-bit — 'same weights, two representations'."""
    import jax
    import jax.numpy as jnp
    from repro.sparse import condensed as COND
    cfg = configs.get_smoke_config("qwen3-1.7b")
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0), REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, jax.random.PRNGKey(0), reg)["masks"]
    cond = COND.export_condensed(cfg, reg, params, masks)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    c1, c2 = M.init_cache(cfg, 2, 6), M.init_cache(cfg, 2, 6)
    for t in range(6):
        l1, c1 = M.decode_step(cfg, params, masks, {"tokens": toks[:, t:t+1]}, c1)
        l2, c2 = M.decode_step(cfg, params, cond, {"tokens": toks[:, t:t+1]}, c2)
    rel = float(jnp.max(jnp.abs(l1 - l2))) / (float(jnp.max(jnp.abs(l1))) + 1e-9)
    assert rel < 1e-5
    cb, db = COND.condensed_bytes(cfg, reg)
    assert cb < 0.25 * db  # ~(1-s)*(1+idx overhead) at 90% sparsity
