"""Output-norm variance theory (paper App. A/B) vs Monte-Carlo simulation."""
import jax
import pytest

from repro.core import theory


@pytest.mark.parametrize("kind,theory_fn", [
    ("bernoulli", theory.var_bernoulli),
    ("const_per_layer", theory.var_const_per_layer),
    ("const_fan_in", theory.var_const_fan_in),
])
def test_theory_matches_simulation(kind, theory_fn):
    n, k = 64, 8
    th = theory_fn(n, k)
    sim = theory.simulate_output_norm_var(jax.random.PRNGKey(0), n, k, kind, 4000)
    assert abs(sim - th) / th < 0.08


def test_const_fan_in_always_smallest():
    """The paper's Fig. 1b claim: constant fan-in minimizes output-norm variance."""
    for n in (32, 64, 256):
        for k in (2, 4, 8, n // 2):
            cfi = theory.var_const_fan_in(n, k)
            assert cfi < theory.var_bernoulli(n, k)
            assert cfi < theory.var_const_per_layer(n, k)


def test_mean_is_one():
    # E[||z||^2] = 1 for the normalized init — simulation check
    n, k = 64, 8
    def mean_norm(kind):
        key = jax.random.PRNGKey(1)
        vs = []
        for i in range(3):
            vs.append(theory.simulate_output_norm_var(jax.random.fold_in(key, i), n, k, kind, 10))
        return vs
    # cheap smoke: simulator runs for each ensemble
    for kind in ("bernoulli", "const_per_layer", "const_fan_in"):
        theory.simulate_output_norm_var(jax.random.PRNGKey(2), n, k, kind, 50)
