"""Tensor-parallel sparse serving (PR 8).

Covers the three tentpole layers on a single host device:

* FORMAT sharding: ``tp_shards`` exports reorganize the neuron axis into tp
  contiguous blocks with locally rebased indices, and the vmap-over-blocks
  ``apply`` is exactly the replicated math (token-identity on one device is
  the ground truth the dryrun's SPMD invariants extend to a real mesh);
* COLLECTIVE-priced plans: ``stack_costs(tp=...)`` adds ``<rep>@tpN``
  candidates priced with ``profile.ici_bytes_per_s`` — the shard-vs-
  replicate decision comes out of the cost model, and the predicted
  crossover DIRECTION (sharded wins decode, replicated wins large batch)
  is pinned here per the acceptance criterion;
* ENGINE: a mesh with a model axis flows into ``PlanKey.tp``, per-shard
  autotune keys, and plans whose leaves carry the shard count.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import topology
from repro.launch import engine as E
from repro.models import model as M
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG

D_IN, D_OUT, K, TP = 32, 48, 5, 4


@pytest.fixture(scope="module")
def wm():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (D_IN, D_OUT), jnp.float32)
    mask = topology.random_constant_fan_in_mask(
        jax.random.fold_in(key, 1), D_IN, D_OUT, K)
    cut = D_OUT - D_OUT // 4
    abl = mask & (jnp.arange(D_OUT) < cut)[None, :]
    abl_only = jnp.broadcast_to((jnp.arange(D_OUT) < cut)[None, :],
                                (D_IN, D_OUT))
    return w, mask, abl, abl_only


def _stack(d_in=2048, d_out=2048, name="mlp"):
    return types.SimpleNamespace(name=name, d_in=d_in, d_out=d_out,
                                 n_replicas=1)


# ---------------------------------------------------------------------------
# format layer: TP export == replicated math
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", (F.Condensed, F.CondensedOverActive),
                         ids=lambda c: c.format_name)
@pytest.mark.parametrize("which", ("fan_in", "ablated"))
def test_tp_export_apply_matches_replicated(cls, which, wm):
    w, mask, abl, _ = wm
    m = mask if which == "fan_in" else abl
    x = jax.random.normal(jax.random.PRNGKey(2), (3, D_IN))
    ref = x @ (w * m)
    f1 = cls.export_from_dense(w, m, tp_shards=1)
    f4 = cls.export_from_dense(w, m, tp_shards=TP)
    assert f1.tp == 1 and f4.tp == TP
    np.testing.assert_allclose(np.array(f4.apply(x)), np.array(ref),
                               atol=1e-5)
    # on one device the sharded block math must be BIT-identical to the
    # replicated leaf (same adds in the same order per neuron)
    np.testing.assert_array_equal(np.array(f4.apply(x)),
                                  np.array(f1.apply(x)))


def test_tp_structured_export_matches_replicated(wm):
    w, _, _, abl_only = wm
    x = jax.random.normal(jax.random.PRNGKey(3), (3, D_IN))
    ref = x @ (w * abl_only)
    f4 = F.StructuredFanIn.export_from_dense(w, abl_only, tp_shards=TP)
    assert f4.tp == TP
    np.testing.assert_allclose(np.array(f4.apply(x, w)), np.array(ref),
                               atol=1e-5)


def test_tp_quantized_export_matches_replicated_quantized(wm):
    w, mask, _, _ = wm
    x = jax.random.normal(jax.random.PRNGKey(4), (2, D_IN))
    f1 = F.Condensed.export_from_dense(w, mask, quantize_spec="int8",
                                       tp_shards=1)
    f4 = F.Condensed.export_from_dense(w, mask, quantize_spec="int8",
                                       tp_shards=TP)
    assert f4.scales is not None and f4.values.dtype == jnp.int8
    np.testing.assert_array_equal(np.array(f4.apply(x)),
                                  np.array(f1.apply(x)))


def test_tp_indices_are_locally_rebased(wm):
    """Every stored index addresses the SHARD-local input of its block —
    that is what makes the gather collective-free under GSPMD."""
    w, mask, abl, _ = wm
    wloc = D_OUT // TP
    coa = F.CondensedOverActive.export_from_dense(w, abl, tp_shards=TP)
    # out_index entries are local slots or the LOCAL sentinel (== wloc)
    assert int(jnp.max(coa.out_index)) <= wloc
    # and rebasing them reconstructs valid GLOBAL positions (sentinel d_out)
    glob = F._rebased_global_index(coa.out_index, TP, D_OUT)
    assert int(jnp.max(glob)) <= D_OUT
    live = glob[glob < D_OUT]
    assert live.size and int(jnp.max(live)) < D_OUT


def test_tp_shards_must_divide_d_out(wm):
    w, mask, _, _ = wm
    with pytest.raises(ValueError, match="must divide"):
        F.Condensed.export_from_dense(w, mask, tp_shards=5)


def test_tp_tuning_key_uses_per_shard_shapes(wm):
    """Autotune cache keys shrink to the shard-local problem (n/tp) and
    must not collide with the replicated key for the same stack."""
    w, mask, _, _ = wm
    k1 = F.Condensed.export_from_dense(w, mask, tp_shards=1).tuning_key(8)
    k4 = F.Condensed.export_from_dense(w, mask, tp_shards=TP).tuning_key(8)
    assert k1 != k4
    assert f"n{D_OUT}" in k1 and f"n{D_OUT // TP}" in k4


# ---------------------------------------------------------------------------
# collective-priced plans (acceptance: crossover direction from the model)
# ---------------------------------------------------------------------------

REALISTIC = dict(itemsize=4,
                 stats=F.ExportStats(k=205, max_active=2048,
                                     active_fraction=1.0, min_fan_in=205))


def test_sharded_condensed_wins_decode_batch():
    dec = PLAN.select_representation(_stack(), batch_size=1, tp=TP,
                                     **REALISTIC)
    assert dec.representation == "condensed" and dec.tp == TP
    assert dec.cost_key == f"condensed@tp{TP}"
    # the priced candidates include both the sharded and replicated entries
    assert f"condensed@tp{TP}" in dec.est_s and "condensed" in dec.est_s
    assert dec.est_s[dec.cost_key] < dec.est_s["condensed"]


def test_replicated_wins_large_batch():
    dec = PLAN.select_representation(_stack(), batch_size=4096, tp=TP,
                                     **REALISTIC)
    assert dec.tp == 1  # collective + gather both lose at the MXU end


def test_crossover_exists_and_is_ordered():
    cross = PLAN.tp_crossover_batch(_stack(), tp=TP, **REALISTIC)
    assert cross is not None and 1 < cross <= 4096
    below = PLAN.select_representation(_stack(), batch_size=cross // 2,
                                       tp=TP, **REALISTIC)
    at = PLAN.select_representation(_stack(), batch_size=cross, tp=TP,
                                    **REALISTIC)
    assert below.tp == TP and at.tp == 1


def test_tiny_stack_stays_replicated():
    """For tiny stacks the per-layer all-gather outweighs the sharded
    gather's byte saving at EVERY batch — the cost model must keep them
    replicated rather than sharding reflexively."""
    stats = F.ExportStats(k=8, max_active=64, active_fraction=1.0,
                          min_fan_in=8)
    dec = PLAN.select_representation(_stack(64, 64, "tiny"), batch_size=1,
                                     itemsize=4, stats=stats, tp=TP)
    assert dec.tp == 1


def test_collective_priced_with_ici_rate():
    spec = F.spec_for_stack(_stack(), REALISTIC["stats"], 4)
    fast = PLAN.DEFAULT_PROFILE
    slow = PLAN.dataclasses.replace(fast, ici_bytes_per_s=fast.ici_bytes_per_s / 100)
    c_fast = F.Condensed.estimate_collective(spec, 1, fast, TP)
    c_slow = F.Condensed.estimate_collective(spec, 1, slow, TP)
    assert c_slow == pytest.approx(c_fast * 100, rel=1e-6)
    # a 100x slower interconnect flips the decode-batch decision
    dec = PLAN.select_representation(_stack(), batch_size=1, tp=TP,
                                     itemsize=4, stats=REALISTIC["stats"],
                                     profile=slow)
    assert dec.tp == 1


def test_indivisible_stack_never_offered_sharded():
    stats = F.ExportStats(k=16, max_active=98, active_fraction=1.0,
                          min_fan_in=16)
    costs = PLAN.stack_costs(_stack(128, 98, "odd"), batch_size=1,
                             itemsize=4, k=16, active_fraction=1.0, tp=TP)
    assert not any("@tp" in key for key in costs)
    dec = PLAN.select_representation(_stack(128, 98, "odd"), batch_size=1,
                                     itemsize=4, stats=stats, tp=TP)
    assert dec.tp == 1


# ---------------------------------------------------------------------------
# plan + refresh + engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    return cfg, reg, params, masks


def test_build_plan_tp_exports_sharded_leaves(smoke):
    cfg, reg, params, masks = smoke
    p4 = PLAN.build_plan(cfg, reg, params, masks, path="condensed",
                         batch_size=1, tp=TP)
    assert p4.tp == TP
    for s in reg:
        leaf = REG.get_path(p4.serving_tree, s.path)
        assert leaf.tp == TP
        # arrays keep GLOBAL shapes (shard blocks are a layout, not a split)
        assert leaf.values.shape[-2] == s.d_out


def test_recondense_tp_change_forces_fresh_export(smoke):
    cfg, reg, params, masks = smoke
    s = reg[0]
    w = REG.get_path(params, s.path)
    m = REG.get_path(masks, s.path)
    stats = COND.export_stats(reg, masks, [s])[s.name]
    old = F.Condensed.export_from_dense(w, m, stats, tp_shards=1)
    new = COND.recondense_stack_leaf(w, m, stats, old, tp=TP)
    assert new.tp == TP
    # unchanged shard layout takes the donated-refresh path and keeps tp
    again = COND.recondense_stack_leaf(w, m, stats, new, tp=TP, donate=False)
    assert again.tp == TP


def test_plan_describe_shows_requested_batch_and_bucket(smoke):
    cfg, reg, params, masks = smoke
    plan = PLAN.build_plan(cfg, reg, params, masks, path="auto",
                           batch_size=8, tp=TP)
    d = plan.describe(requested_batch=2)
    assert "batch=2 (bucket 8)" in d
    assert plan.describe(requested_batch=8).count("bucket") == 0
    assert "tp=4" in d


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 1, "model": TP}


def test_engine_mesh_flows_into_plan_key_and_leaves(smoke):
    cfg, reg, params, masks = smoke
    eng = E.ServingEngine(cfg, params, masks, reg, path="condensed",
                          mesh=_FakeMesh())
    assert eng.tp == TP
    key = eng.plan_key(2)
    assert key.tp == TP and f"/tp{TP}" in key.describe()
    plan = eng.plan_for(key)
    assert plan.tp == TP
    for s in reg:
        assert REG.get_path(plan.serving_tree, s.path).tp == TP
    # no mesh -> replicated keys, distinct from the TP group's
    eng1 = E.ServingEngine(cfg, params, masks, reg, path="condensed")
    assert eng1.tp == 1 and eng1.plan_key(2) != key


def test_engine_tp_tokens_identical_to_single_device(smoke):
    """Acceptance ground truth on one device: a TP engine's greedy tokens
    are IDENTICAL to the replicated engine's (the sharded apply is the same
    math reorganized; the dryrun's HLO invariants extend exactly this
    program to a real mesh)."""
    cfg, reg, params, masks = smoke
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0,
                                 cfg.vocab_size)
    toks = {}
    for tag, mesh in (("tp1", None), ("tp4", _FakeMesh())):
        eng = E.ServingEngine(cfg, params, masks, reg, path="condensed",
                              mesh=mesh)
        rid = eng.submit(prompts, 8)
        eng.step()
        [res] = eng.retire(rid)
        toks[tag] = np.asarray(res.tokens)
    np.testing.assert_array_equal(toks["tp1"], toks["tp4"])


def test_abstract_plan_key_and_serving_tree_carry_tp(smoke):
    cfg, reg, _, _ = smoke
    key, reps = E.abstract_plan_key(cfg, reg, 2, path="condensed", tp=TP)
    assert key.tp == TP and set(reps) == {s.name for s in reg}
    tree = PLAN.abstract_serving_tree(cfg, reg,
                                      {s.name: "condensed" for s in reg},
                                      tp=TP)
    for s in reg:
        leaf = REG.get_path(tree, s.path)
        assert leaf.tp == (TP if s.d_out % TP == 0 else 1)


def test_hlo_instruction_shapes_reads_gather_dims():
    from repro.launch import hlo_analysis as H
    f = jax.jit(lambda w, i: jnp.take_along_axis(w, i, axis=0))
    hlo = f.lower(jnp.zeros((8, 4)), jnp.zeros((2, 4), jnp.int32)).compile()
    shapes = H.instruction_shapes(hlo.as_text(), "gather")
    assert shapes and all(isinstance(s, tuple) for s in shapes)
    assert (2, 4) in shapes
