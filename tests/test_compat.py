"""The JAX version-compat layer: shims behave identically on this install.

These tests are the contract the rest of the repo codes against — if a JAX
upgrade changes mesh-context semantics, they fail here first, not deep inside
a 512-device dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M


def test_no_mesh_by_default():
    assert compat.get_abstract_mesh() is None


def test_use_mesh_exposes_abstract_mesh():
    mesh = make_host_mesh()
    with compat.use_mesh(mesh):
        am = compat.get_abstract_mesh()
        assert am is not None
        assert tuple(am.axis_names) == ("data", "model")
        assert dict(am.shape) == {"data": 1, "model": 1}
    assert compat.get_abstract_mesh() is None  # context restored


def test_use_mesh_nests_and_restores():
    mesh = make_host_mesh()
    with compat.use_mesh(mesh):
        with compat.use_mesh(mesh):
            assert compat.get_abstract_mesh() is not None
        assert compat.get_abstract_mesh() is not None
    assert compat.get_abstract_mesh() is None


def test_make_mesh_axis_names_and_usability():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert tuple(mesh.axis_names) == ("data", "model")
    sh = compat.NamedSharding(mesh, compat.PartitionSpec(None, None))
    x = jax.device_put(jnp.ones((4, 4)), sh)
    assert float(x.sum()) == 16.0


def test_production_mesh_shape_via_compat():
    # 256 host devices are not available in the test process; shape-check the
    # abstract construction path only (dryrun boots the forced-device variant)
    try:
        mesh = make_production_mesh()
    except (ValueError, RuntimeError):
        pytest.skip("256 devices unavailable in the test container (expected)")
    assert mesh.shape["data"] == 16 and mesh.shape["model"] == 16


def test_mesh_context_is_part_of_jit_trace():
    """shard_hint must see the mesh during traced execution AND the jit cache
    must distinguish with-mesh from without-mesh traces (a stale cache entry
    would silently drop the sharding constraints on real hardware)."""
    mesh = make_host_mesh()
    seen = []

    @jax.jit
    def f(x):
        seen.append(compat.get_abstract_mesh() is not None)
        return M.shard_hint(x, "data", None) * 2

    x = jnp.ones((2, 4))
    np.testing.assert_allclose(np.array(f(x)), 2.0)        # traced without mesh
    with compat.use_mesh(mesh):
        np.testing.assert_allclose(np.array(f(x)), 2.0)    # must re-trace
    assert seen == [False, True]


def test_shard_hint_noop_without_mesh():
    x = jnp.ones((6, 8))
    y = M.shard_hint(x, "data", "model")
    assert y is x  # literally untouched outside a mesh context


def test_shard_hint_skips_indivisible_dims():
    mesh = make_host_mesh()
    with compat.use_mesh(mesh):
        # 1x1 mesh: everything divides; constraint applies without error
        y = jax.jit(lambda x: M.shard_hint(x, "data", "model"))(jnp.ones((2, 2)))
        np.testing.assert_allclose(np.array(y), 1.0)
        # unknown axis name -> no-op rather than error
        z = jax.jit(lambda x: M.shard_hint(x, "nonexistent", None))(jnp.ones((2, 2)))
        np.testing.assert_allclose(np.array(z), 1.0)


def test_format_shim_present():
    """The layout shim resolves on every JAX that ships a layout module
    (Format on current, Layout on 0.4.x) — serving code may pass
    compat.default_format() anywhere a layout is accepted."""
    if not compat.HAS_FORMAT:
        pytest.skip("this JAX build has no jax.experimental.layout module")
    assert compat.Format is not None
    assert compat.default_format() is None
