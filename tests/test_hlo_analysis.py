"""HLO static cost model: trip-count awareness, dot flops, collectives."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile().as_text()


def test_scan_vs_unroll_flops_parity():
    A = jnp.zeros((256, 256))

    def scanned(x):
        def body(c, _):
            return c @ A, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    def unrolled(x):
        for _ in range(12):
            x = x @ A
        return x

    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    fs = H.analyze(_compile(scanned, sds)).flops
    fu = H.analyze(_compile(unrolled, sds)).flops
    expect = 12 * 2 * 256**3
    assert abs(fs - expect) / expect < 0.01
    assert abs(fu - expect) / expect < 0.01


def test_nested_scan_trip_counts_compose():
    A = jnp.zeros((128, 128))

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ A, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    f = H.analyze(_compile(nested, sds)).flops
    expect = 15 * 2 * 128**3
    assert abs(f - expect) / expect < 0.02


def test_dot_flops_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    sds_a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    sds_b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    flops = H.analyze(_compile(f, sds_a, sds_b)).flops
    expect = 2 * 4 * 64 * 32 * 16
    assert abs(flops - expect) / expect < 0.01


def test_shape_bytes():
    assert H._shape_bytes("f32[16,4]") == 256
    assert H._shape_bytes("bf16[8]") == 16
    assert H._shape_bytes("(f32[4], s32[2])") == 24
    assert H._shape_bytes("pred[10]") == 10


def test_roofline_terms_and_dominance():
    t = H.roofline_terms(197e12, 819e9, 0.0, 1)
    assert t["compute_s"] == 1.0 and t["memory_s"] == 1.0
    assert H.dominant_term({"compute_s": 2, "memory_s": 1, "collective_s": 0}) == "compute_s"
