"""Paged KV cache: host-side page accounting (repro.models.paged), the
device read/write primitives (attention.paged_*), and the model-level
paged prefill/decode entry points.

The load-bearing property is BITWISE identity: a stream decoded against
the paged pool — bucket-padded, right-padded prompt, non-contiguous rows,
garbage page 0 carrying other streams' stale writes — must emit exactly
the tokens the contiguous-cache ``generate`` path emits. Masked slots hit
``NEG_INF`` before the softmax, their weights underflow to exact 0.0, and
exact zeros change no sums.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import serve
from repro.models import attention as A
from repro.models import model as M
from repro.models import paged as PG
from repro.sparse import registry as REG


# ---------------------------------------------------------------------------
# host-side accounting
# ---------------------------------------------------------------------------

def test_pages_for_rounds_up():
    assert PG.pages_for(0, 16) == 0
    assert PG.pages_for(1, 16) == 1
    assert PG.pages_for(16, 16) == 1
    assert PG.pages_for(17, 16) == 2
    assert PG.pages_for(-3, 16) == 0


def test_allocator_reserves_page_zero():
    al = PG.BlockAllocator(5)
    assert al.available == 4
    pages = al.alloc(4)
    assert sorted(pages) == [1, 2, 3, 4]        # page 0 never handed out
    with pytest.raises(ValueError, match="reserved"):
        al.release([0])
    with pytest.raises(ValueError):
        PG.BlockAllocator(0)


def test_allocator_alloc_release_cycle():
    al = PG.BlockAllocator(8)
    a = al.alloc(3)
    b = al.alloc(2)
    assert al.available == 2
    al.release(a)
    assert al.available == 5
    with pytest.raises(ValueError, match="double free"):
        al.release(a)
    c = al.alloc(5)
    assert not (set(b) & set(c))
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc(1)


def test_allocator_grow_extends_free_list():
    al = PG.BlockAllocator(3)
    al.alloc(2)
    al.grow(6)
    assert al.available == 3
    assert al.num_blocks == 6
    with pytest.raises(ValueError, match="only grow"):
        al.grow(4)


# ---------------------------------------------------------------------------
# device primitives: paged == contiguous
# ---------------------------------------------------------------------------

def test_paged_write_then_attend_matches_contiguous():
    """Scatter tokens through a block table (rows deliberately owning
    shuffled, non-adjacent pages), read back via paged attention, and
    compare with the contiguous decode path on identical content."""
    key = jax.random.PRNGKey(0)
    b, s, hkv, h, d, bs = 2, 12, 2, 4, 8, 4
    nb = s // bs
    head_to_kv = (0, 0, 1, 1)
    k1, k2, k3 = jax.random.split(key, 3)
    k_all = jax.random.normal(k1, (b, s, hkv, d))
    v_all = jax.random.normal(k2, (b, s, hkv, d))
    q = jax.random.normal(k3, (b, 1, h, d))

    # contiguous reference: full caches, every slot valid
    ref = A.decode_attention(q, k_all, v_all, jnp.int32(s),
                             head_to_kv=head_to_kv)

    # paged: pool pre-filled with garbage, shuffled page ownership
    pool_k = jax.random.normal(jax.random.PRNGKey(9), (16, bs, hkv, d))
    pool_v = jax.random.normal(jax.random.PRNGKey(10), (16, bs, hkv, d))
    table = jnp.asarray([[7, 3, 11], [2, 9, 5]], jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pool_k, pool_v = A.paged_cache_write(pool_k, pool_v, k_all, v_all,
                                         table, positions)
    out = A.paged_decode_attention(q, pool_k, pool_v, table,
                                   jnp.full((b,), s, jnp.int32),
                                   head_to_kv=head_to_kv)
    np.testing.assert_array_equal(np.array(ref), np.array(out))


def test_paged_attention_masks_beyond_length_exactly():
    """Slots at/after a stream's length must contribute EXACT zeros: the
    result cannot depend on garbage in the unread tail of its pages."""
    key = jax.random.PRNGKey(1)
    b, hkv, d, bs, nb = 1, 2, 8, 4, 2
    head_to_kv = (0, 1)
    q = jax.random.normal(key, (b, 1, 2, d))
    table = jnp.asarray([[1, 2]], jnp.int32)
    lengths = jnp.asarray([5], jnp.int32)
    base_k = jax.random.normal(jax.random.PRNGKey(2), (3, bs, hkv, d))
    base_v = jax.random.normal(jax.random.PRNGKey(3), (3, bs, hkv, d))
    out1 = A.paged_decode_attention(q, base_k, base_v, table, lengths,
                                    head_to_kv=head_to_kv)
    # scribble over every slot past the length (and all of page 0)
    junk_k = base_k.at[2, 1:].set(99.0).at[0].set(-7.0)
    junk_v = base_v.at[2, 1:].set(-99.0).at[0].set(7.0)
    out2 = A.paged_decode_attention(q, junk_k, junk_v, table, lengths,
                                    head_to_kv=head_to_kv)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))


def test_paged_write_overshoot_lands_in_garbage_page():
    """Positions past a table's extent clamp into its LAST entry; an idle
    row's all-zero table pins every write to the reserved page 0 — so a
    bucket-padded dispatch can never corrupt a live stream's pages."""
    bs, hkv, d = 4, 1, 2
    pool_k = jnp.zeros((3, bs, hkv, d))
    pool_v = jnp.zeros((3, bs, hkv, d))
    live = pool_k[1:]  # pages 1..2 belong to (hypothetical) live streams
    table = jnp.zeros((1, 2), jnp.int32)            # idle row
    k_new = jnp.ones((1, 1, hkv, d))
    positions = jnp.asarray([[37]], jnp.int32)      # far past any extent
    pool_k, pool_v = A.paged_cache_write(pool_k, pool_v, k_new, k_new,
                                         table, positions)
    np.testing.assert_array_equal(np.array(pool_k[1:]), np.array(live))
    assert float(pool_k[0].sum()) != 0.0            # landed in page 0


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    return cfg, params, masks


def test_supports_paged_gates_on_architecture(smoke_model):
    cfg, _, _ = smoke_model
    assert M.supports_paged(cfg)
    assert not M.supports_paged(dataclasses.replace(cfg, sliding_window=16))
    assert not M.supports_paged(dataclasses.replace(cfg, mrope=True))
    assert not M.supports_paged(dataclasses.replace(cfg, family="ssm"))


def test_init_paged_pool_shapes(smoke_model):
    cfg, _, _ = smoke_model
    pool = M.init_paged_pool(cfg, num_blocks=7, block_size=4)
    assert pool["pk"].shape == (cfg.n_layers, 7, 4, cfg.n_kv_heads_padded,
                                cfg.head_dim)
    assert pool["pk"].shape == pool["pv"].shape


def test_paged_generation_bitwise_matches_contiguous(smoke_model):
    """End-to-end identity under maximal adversity: bucket padding (2 live
    streams in an 8-row dispatch), non-contiguous row placement, a prompt
    right-padded past its length, and a pool whose garbage page has been
    written through by the pad rows."""
    cfg, params, masks = smoke_model
    bucket, t, t_short, gen, bs = 8, 8, 6, 5, 4
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, t), 0,
                                 cfg.vocab_size)
    rows = [5, 2]                                   # deliberately scattered
    nb = PG.pages_for(t + gen, bs)
    pool = M.init_paged_pool(cfg, 1 + bucket * nb, bs)
    al = PG.BlockAllocator(1 + bucket * nb)

    table = np.zeros((bucket, nb), np.int32)
    tokens = np.zeros((bucket, t), np.int32)
    lens = np.zeros((bucket,), np.int32)
    for i, row in enumerate(rows):
        table[row] = al.alloc(nb)
        take = t if i == 0 else t_short
        tokens[row, :take] = np.asarray(prompts[i, :take])
        lens[row] = take

    logits, pool = M.paged_prefill_step(
        cfg, params, masks, {"tokens": jnp.asarray(tokens)}, pool,
        jnp.asarray(table), jnp.asarray(lens))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    lengths = jnp.asarray(lens)
    toks = []
    for _ in range(gen):
        toks.append(np.array(cur[:, 0]))
        logits, pool = M.paged_decode_step(
            cfg, params, masks, {"tokens": cur}, pool, jnp.asarray(table),
            lengths)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        lengths = lengths + 1
    gen_toks = np.stack(toks, axis=1)               # (bucket, gen)

    for i, row in enumerate(rows):
        take = t if i == 0 else t_short
        ref = serve.generate(cfg, params, masks, prompts[i:i + 1, :take],
                             gen)
        np.testing.assert_array_equal(gen_toks[row], np.array(ref[0, take:]))
