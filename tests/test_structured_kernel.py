"""Ablation-aware kernels: column-gathered structured matmul + fused COA.

The acceptance criteria made executable:

* the structured Pallas kernel is BIT-identical to the
  ``ops.structured_dense`` reference on every edge case — zero ablation,
  all-but-one-ablated, non-tile-aligned active counts, bf16, batch 1 and
  block-straddling batches;
* the fused condensed-over-active kernel is bit-identical to the pre-fusion
  compose-then-scatter lowering (and therefore token-identical to masked);
* both ops have working backward passes matching the reference gradients;
* tuned blocks stored under the structured/coa tuning keys are consumed by
  the ops wrappers at trace time;
* the fused epilogue removes the standalone scatter op from the lowered
  decode program (HLO dispatch-count assertion via ``launch.hlo_analysis``);
* ``--path auto`` picks structured for ablation-only stacks at the batch
  the cost model predicts, with serving weight bytes below masked, and
  ``StructuredFanIn.estimate_weight_bytes`` scales ~linearly with the
  active fraction.
"""
import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.kernels import ops
from repro.kernels import structured_matmul as sm
from repro.sparse import autotune as AT
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import plan as PLAN


def _active_setup(b, d_in, d_out, a, dtype=jnp.float32, seed=0):
    """(x, w, padded active_index, neuron_active bools) with a random
    size-``a`` surviving set."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, d_in), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (d_in, d_out), jnp.float32).astype(dtype)
    ai = jnp.sort(jax.random.permutation(k3, d_out)[:a]).astype(jnp.int32)
    active = jnp.zeros((d_out,), bool).at[ai].set(True)
    a_pad = sm.padded_active_count(max(a, 1), d_out)
    ai_padded = jnp.pad(ai, (0, a_pad - a), constant_values=d_out)
    return x, w, ai_padded, active


# ---------------------------------------------------------------------------
# structured kernel: bit-identity vs the structured_dense reference
# ---------------------------------------------------------------------------

STRUCT_SHAPES = [
    # (b, d_in, d_out, a)
    (1, 64, 128, 37),      # decode, non-tile-aligned active count
    (4, 64, 128, 128),     # zero ablation (every neuron survives)
    (8, 32, 16, 1),        # all-but-one ablated
    (3, 32, 48, 0),        # fully ablated (output must be exact zeros)
    (130, 96, 257, 5),     # block-straddling batch, non-aligned d_out
    (256, 128, 300, 155),  # general tiled path
]


@pytest.mark.parametrize("b,d_in,d_out,a", STRUCT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_structured_kernel_bit_identical_to_reference(b, d_in, d_out, a, dtype):
    x, w, ai, active = _active_setup(b, d_in, d_out, a, dtype=dtype,
                                     seed=b * 3 + a)
    y = sm.structured_matmul(x, w, ai)
    y_ref = ops.structured_dense(x, w, active)
    assert y.dtype == y_ref.dtype
    np.testing.assert_array_equal(np.array(y), np.array(y_ref))


def test_structured_kernel_forced_blocks_padding_paths():
    """Shapes straddling both block boundaries, blocks forced one-sided and
    both-sided — all bit-identical to the reference."""
    x, w, ai, active = _active_setup(130, 40, 200, 77, seed=11)
    y_ref = ops.structured_dense(x, w, active)
    for kw in ({"block_b": 32, "block_n": 128}, {"block_b": 128},
               {"block_n": 128}):
        y = sm.structured_matmul(x, w, ai, **kw)
        np.testing.assert_array_equal(np.array(y), np.array(y_ref))
    # the decode variant agrees with the general kernel at any batch it fits
    y_dec = sm.structured_matmul_decode(x, w, ai)
    np.testing.assert_array_equal(np.array(y_dec), np.array(y_ref))


def test_structured_linear_grads_match_reference():
    """Custom VJP: dx/dw agree with differentiating the structured_dense
    reference (ablated columns receive zero weight gradient)."""
    x, w, ai, active = _active_setup(6, 24, 40, 13, seed=5)
    f = lambda x, w: jnp.sum(jnp.tanh(ops.structured_linear(x, w, ai)))
    g = lambda x, w: jnp.sum(jnp.tanh(ops.structured_dense(x, w, active)))
    gx1, gw1 = jax.grad(f, (0, 1))(x, w)
    gx2, gw2 = jax.grad(g, (0, 1))(x, w)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), atol=1e-5)
    np.testing.assert_allclose(np.array(gw1), np.array(gw2), atol=1e-5)
    # ablated columns: exact zero gradient
    assert np.all(np.array(gw1)[:, ~np.array(active)] == 0.0)


def test_structured_linear_nd_leading_dims():
    x, w, ai, active = _active_setup(1, 24, 40, 13, seed=7)
    x3 = jax.random.normal(jax.random.PRNGKey(8), (3, 5, 24))
    y = ops.structured_linear_nd(x3, w, ai)
    assert y.shape == (3, 5, 40)
    y2 = ops.structured_linear(x3.reshape(-1, 24), w, ai).reshape(3, 5, 40)
    np.testing.assert_array_equal(np.array(y), np.array(y2))


def test_structured_format_exports_and_applies_gathered_kernel():
    """StructuredFanIn built from an ablation-only mask: active_index sized
    at the realized count (lane-padded), apply exact vs masked."""
    d_in, d_out = 48, 256
    col_active = (jnp.arange(d_out) % 3) != 0
    mask = jnp.broadcast_to(col_active[None, :], (d_in, d_out))
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))
    fmt = F.StructuredFanIn.export_from_dense(w, mask)
    a = int(col_active.sum())
    assert fmt.active_index.shape[-1] == sm.padded_active_count(a, d_out)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, d_in))
    np.testing.assert_array_equal(np.array(fmt.apply(x, w)),
                                  np.array(x @ (w * mask)))
    # legacy instance (pre-active_index pytrees): reference fallback path
    legacy = F.StructuredFanIn(neuron_active=col_active, d_in=d_in)
    assert legacy.tuning_key(1) is None
    np.testing.assert_allclose(np.array(legacy.apply(x, w)),
                               np.array(x @ (w * mask)), atol=1e-6)


# ---------------------------------------------------------------------------
# fused condensed-over-active kernel
# ---------------------------------------------------------------------------

def _coa_setup(b, d_in, d_out, frac, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    k_fan = max(1, d_in // 6)
    mask = topology.random_constant_fan_in_mask(key, d_in, d_out, k_fan)
    if frac:
        cut = d_out - max(1, int(d_out * frac))
        mask = mask & (jnp.arange(d_out) < cut)[None, :]
    w = jax.random.normal(jax.random.fold_in(key, 1), (d_in, d_out),
                          jnp.float32).astype(dtype)
    fmt = F.CondensedOverActive.export_from_dense(w, mask)
    x = jax.random.normal(jax.random.fold_in(key, 2), (b, d_in),
                          jnp.float32).astype(dtype)
    return x, w, mask, fmt


@pytest.mark.parametrize("b,d_in,d_out,frac",
                         [(1, 64, 128, 0.5), (4, 96, 257, 0.25),
                          (130, 64, 96, 0.9), (2, 48, 64, 0.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coa_fused_bit_identical_to_unfused(b, d_in, d_out, frac, dtype):
    x, w, mask, fmt = _coa_setup(b, d_in, d_out, frac, seed=b, dtype=dtype)
    y_fused = ops.condensed_over_active_linear_nd(
        x, fmt.values.astype(dtype), fmt.indices, fmt.out_index, d_out)
    y_old = ops.condensed_over_active_linear_nd_unfused(
        x, fmt.values.astype(dtype), fmt.indices, fmt.out_index, d_out)
    np.testing.assert_array_equal(np.array(y_fused), np.array(y_old))
    if dtype == jnp.float32:
        np.testing.assert_allclose(np.array(y_fused), np.array(x @ (w * mask)),
                                   atol=1e-5)


def test_coa_fused_general_and_decode_variants_agree():
    x, w, mask, fmt = _coa_setup(5, 64, 200, 0.4, seed=3)
    args = (fmt.values, fmt.indices, fmt.out_index, 200)
    y_dec = sm.condensed_over_active_matmul_decode(x, *args)
    y_gen = sm.condensed_over_active_matmul(x, *args, block_b=32, block_n=128)
    np.testing.assert_array_equal(np.array(y_dec), np.array(y_gen))


def test_coa_fused_grads_match_unfused():
    x, w, mask, fmt = _coa_setup(6, 48, 96, 0.5, seed=4)
    f = lambda x, v: jnp.sum(jnp.tanh(ops.condensed_over_active_linear_nd(
        x, v, fmt.indices, fmt.out_index, 96)))
    g = lambda x, v: jnp.sum(jnp.tanh(
        ops.condensed_over_active_linear_nd_unfused(
            x, v, fmt.indices, fmt.out_index, 96)))
    gx1, gv1 = jax.grad(f, (0, 1))(x, fmt.values)
    gx2, gv2 = jax.grad(g, (0, 1))(x, fmt.values)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), atol=1e-5)
    np.testing.assert_allclose(np.array(gv1), np.array(gv2), atol=1e-5)


# ---------------------------------------------------------------------------
# tuned-block consumption (structured + coa key spaces)
# ---------------------------------------------------------------------------

@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    AT.reset_cache_state()
    yield path
    AT.reset_cache_state()


def test_structured_ops_consume_tuned_blocks(tmp_cache, monkeypatch):
    """structured_linear resolves its blocks from the autotune cache under
    the kind="structured" key at trace time."""
    b, d_in, a_pad, d_out = 1, 48, 128, 160
    res = AT.autotune_structured_blocks(b, d_in, a_pad, d_out, reps=2)
    assert res.speedup_vs_default >= 1.0
    assert "/structured-o" in res.key
    seen = {}
    orig_general, orig_decode = sm.structured_matmul, sm.structured_matmul_decode

    def spy_general(x, w, ai, **kw):
        seen.update(kw)
        return orig_general(x, w, ai, **kw)

    def spy_decode(x, w, ai, **kw):
        seen.update(kw, decode=True)
        return orig_decode(x, w, ai, **kw)

    monkeypatch.setattr(sm, "structured_matmul", spy_general)
    monkeypatch.setattr(sm, "structured_matmul_decode", spy_decode)

    x, w, ai, active = _active_setup(b, d_in, d_out, 100, seed=1)
    assert ai.shape[0] == a_pad
    y = ops.structured_linear(x, w, ai)
    np.testing.assert_array_equal(np.array(y),
                                  np.array(ops.structured_dense(x, w, active)))
    assert seen["block_b"] == res.block_b
    assert seen["block_n"] == res.block_n


def test_coa_ops_consume_tuned_blocks(tmp_cache, monkeypatch):
    b, d_in, a, k, d_out = 1, 48, 64, 8, 96   # k = _coa_setup's d_in // 6
    res = AT.autotune_coa_blocks(b, d_in, a, k, d_out, reps=2)
    assert res.speedup_vs_default >= 1.0
    assert "/coa-o" in res.key
    seen = {}
    orig = sm.condensed_over_active_matmul

    def spy(x, v, i, o, d, **kw):
        seen.update(kw)
        return orig(x, v, i, o, d, **kw)

    monkeypatch.setattr(sm, "condensed_over_active_matmul", spy)
    x, w, mask, fmt = _coa_setup(b, d_in, d_out, 0.34, seed=2)
    assert fmt.values.shape == (a, k), "setup must hit the tuned shape"
    y = ops.condensed_over_active_linear_nd(x, fmt.values, fmt.indices,
                                            fmt.out_index, d_out)
    np.testing.assert_allclose(np.array(y), np.array(x @ (w * mask)),
                               atol=1e-5)
    assert seen["block_b"] == res.block_b
    assert seen["block_n"] == res.block_n


# ---------------------------------------------------------------------------
# HLO dispatch-count: the fused epilogue removes the standalone scatter
# ---------------------------------------------------------------------------

def _scatter_count(hlo_text: str) -> int:
    """Standalone-scatter dispatches in an optimized HLO module.

    Counted via launch.hlo_analysis's instruction parse. The CPU backend's
    ScatterExpander rewrites scatter ops into while loops before scheduling,
    so besides literal ``scatter`` ops we also count instructions whose
    op_name metadata traces back to a jnp scatter (the metadata survives the
    expansion; a TPU lowering keeps the scatter op itself)."""
    import re

    from repro.launch import hlo_analysis as HLO
    comps = HLO.parse_hlo(hlo_text)
    return sum(
        1 for c in comps.values() for i in c.instructions
        if i.op == "scatter"
        or re.search(r'op_name="[^"]*scatter[^"]*"', i.attrs))


def test_unfused_coa_lowering_contains_scatter_control():
    """Control for the dispatch-count assertion: the pre-fusion lowering DOES
    contain a standalone scatter op (so a zero count below is meaningful)."""
    x, w, mask, fmt = _coa_setup(2, 32, 64, 0.5, seed=6)
    hlo = jax.jit(
        lambda x, v, i, o: ops.condensed_over_active_linear_nd_unfused(
            x, v, i, o, 64)
    ).lower(x, fmt.values, fmt.indices, fmt.out_index).compile().as_text()
    assert _scatter_count(hlo) >= 1


def test_fused_coa_decode_program_has_no_standalone_scatter():
    """The engine's decode program under a condensed-over-active serving tree
    lowers WITHOUT any scatter the masked program doesn't also have (the
    epilogue's one-hot matmul replaced the y.at[:, out_index].add dispatch)."""
    from repro import configs
    from repro.models import model as M
    from repro.sparse import registry as REG

    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    # ablate a quarter of each stack's neurons so COA has rows to drop
    abl = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        cut = s.d_out - max(1, s.d_out // 4)
        REG.set_path(abl, s.path, m & (jnp.arange(s.d_out) < cut)[None, :])
    tree = COND.export_condensed_over_active(cfg, reg, params, abl)

    batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    cache = M.init_cache(cfg, 2, max_len=8)

    def lower(serving_tree):
        return jax.jit(
            lambda p, m, b, c: M.decode_step(cfg, p, m, b, c)
        ).lower(params, serving_tree, batch, cache).compile().as_text()

    n_coa = _scatter_count(lower(tree))
    n_masked = _scatter_count(lower(abl))
    assert n_coa == n_masked, (
        f"fused COA decode has {n_coa} scatter op(s) vs masked baseline "
        f"{n_masked} — the standalone out_index scatter is back")


# ---------------------------------------------------------------------------
# plan: structured competes (and wins) in auto for ablation-only stacks
# ---------------------------------------------------------------------------

def _ablation_only_masks(reg, masks, frac):
    import repro.sparse.registry as REG
    out = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        cut = s.d_out - max(1, int(s.d_out * frac))
        col = (jnp.arange(s.d_out) < cut)[None, :]
        REG.set_path(out, s.path, jnp.broadcast_to(col, m.shape))
    return out


@pytest.fixture(scope="module")
def wide_ablation_setup():
    """Smoke config with a roofline-ish d_ff so the lane-padded active count
    leaves room for structured to win (the 64/128-wide smoke stacks pad any
    active count up to a full 128 lanes)."""
    from repro import configs
    from repro.models import model as M
    from repro.sparse import registry as REG

    cfg = configs.get_smoke_config("qwen3-1.7b").replace(d_ff=1024)
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    abl_only = _ablation_only_masks(reg, masks, 0.5)
    return cfg, reg, params, abl_only


def test_auto_selects_structured_for_ablation_only_stacks(wide_ablation_setup):
    cfg, reg, params, abl_only = wide_ablation_setup
    plan = PLAN.build_plan(cfg, reg, params, abl_only, batch_size=1,
                           path="auto")
    wide = [s.name for s in reg if s.d_out >= 512]
    assert wide, "setup must contain roofline-width stacks"
    for name in wide:
        assert plan.representation_of(name) == "structured", (
            name, plan.decisions[name].est_s)
    # the structured plan's weight traffic undercuts the masked reference
    serving, masked_ref = plan.weight_bytes()
    assert serving < masked_ref
    # exactness: the planned tree decodes identically to masked (per-stack
    # leaves only chosen among exact representations)
    stats = COND.export_stats(reg, abl_only)
    for name in wide:
        assert stats[name].min_fan_in == [s for s in reg
                                          if s.name == name][0].d_in


def test_auto_structured_crossover_lands_in_predicted_bucket():
    """The cost model predicts a structured->masked crossover batch for an
    ablation-only stack whose scatter epilogue outweighs the column saving
    at large batch; auto flips representation inside the SAME batch bucket
    (the kernel_autotune.py bucket methodology)."""
    import types
    stack = types.SimpleNamespace(name="t", d_in=1024, d_out=1024,
                                  n_replicas=1)
    stats = F.ExportStats(k=1024, max_active=896, active_fraction=0.875,
                          min_fan_in=1024)

    def rep_at(b):
        return PLAN.select_representation(
            stack, batch_size=b, itemsize=4, stats=stats).representation

    assert rep_at(1) == "structured"          # bandwidth-bound decode
    assert rep_at(4096) == "masked"           # MXU wins back at large batch
    # binary-search the model's crossover, then assert the decision flips
    # within that batch's bucket (same-bucket contract as kernel_autotune)
    lo, hi = 1, 4096
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if rep_at(mid) == "masked":
            hi = mid
        else:
            lo = mid
    bucket = AT.batch_bucket(hi)
    assert rep_at(bucket) == "masked"
    prev_bucket_top = max(b for b in AT.BATCH_BUCKETS if b < bucket) \
        if bucket > AT.BATCH_BUCKETS[0] else 1
    assert rep_at(max(prev_bucket_top, lo)) in ("structured", "masked")
    assert rep_at(min(lo, prev_bucket_top)) == "structured"


def test_structured_weight_bytes_scale_linearly_with_active_fraction():
    """estimate_weight_bytes ~ active_fraction at lane-aligned counts (the
    128-lane export padding is the only quantization)."""
    d_in, d_out = 3072, 1024
    full = None
    for a in (128, 256, 512, 768, 1024):
        spec = F.FormatSpec(d_in=d_in, d_out=d_out, n_replicas=1, itemsize=4,
                            k=d_in, max_active=a, active_fraction=a / d_out)
        got = F.StructuredFanIn.estimate_weight_bytes(spec)
        if full is None:
            full = got * d_out / a  # extrapolated full-width bytes
        assert got == pytest.approx(full * a / d_out, rel=1e-6)
    # and the full-width gathered panel undercuts masked (no mask byte read)
    spec1 = F.FormatSpec(d_in=d_in, d_out=d_out, n_replicas=1, itemsize=4,
                         k=d_in, max_active=d_out, active_fraction=1.0)
    assert (F.StructuredFanIn.estimate_weight_bytes(spec1)
            < F.MaskedDense.estimate_weight_bytes(spec1))


def test_auto_still_never_selects_structured_for_fine_grained_masks():
    """min_fan_in < d_in (fine-grained sparsity, even with ablation) keeps
    structured out of the candidate set — it would not be exact."""
    import types
    stack = types.SimpleNamespace(name="t", d_in=1024, d_out=1024,
                                  n_replicas=1)
    stats = F.ExportStats(k=102, max_active=512, active_fraction=0.5,
                          min_fan_in=102)
    for b in (1, 8, 64, 512):
        dec = PLAN.select_representation(stack, batch_size=b, itemsize=4,
                                         stats=stats)
        assert dec.representation != "structured"


# ---------------------------------------------------------------------------
# checkpoint: archives predating active_index rebuild it from restored bools
# ---------------------------------------------------------------------------

class _State(typing.NamedTuple):
    step: jnp.int32
    serve: dict


def test_checkpoint_restore_rebuilds_missing_active_index(tmp_path):
    from repro.train import checkpoint as CKPT

    d_in, d_out = 16, 192
    col_active = (jnp.arange(d_out) % 5) != 0
    mask = jnp.broadcast_to(col_active[None, :], (d_in, d_out))
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))
    fmt = F.StructuredFanIn.export_from_dense(w, mask)

    # archive written by a pre-active_index layout: only neuron_active saved
    legacy = _State(step=jnp.int32(1),
                    serve={"stack": {"neuron_active": fmt.neuron_active}})
    path = CKPT.save(str(tmp_path), legacy)
    assert path

    template = _State(step=jnp.int32(0),
                      serve={"stack": dataclasses.replace(
                          fmt,
                          neuron_active=jnp.zeros_like(fmt.neuron_active),
                          active_index=jnp.zeros_like(fmt.active_index))})
    restored = CKPT.restore(str(tmp_path), 1, template)
    got = restored.serve["stack"]
    np.testing.assert_array_equal(np.array(got.neuron_active),
                                  np.array(fmt.neuron_active))
    # active_index was NOT in the archive: rebuilt from the restored bools,
    # not left at the template's zeros
    np.testing.assert_array_equal(np.array(got.active_index),
                                  np.array(fmt.active_index))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, d_in))
    np.testing.assert_array_equal(np.array(got.apply(x, w)),
                                  np.array(x @ (w * mask)))


def test_checkpoint_rebuild_resizes_when_archive_has_more_actives(tmp_path):
    """The rebuilt active_index is sized from the RESTORED bools' realized
    active count — a template whose vector was sized from sparser masks must
    not silently truncate (and thereby zero) the archive's extra actives."""
    from repro.train import checkpoint as CKPT

    d_in, d_out = 8, 512
    # archive: 384 active columns; template: sized for only 128
    arch_active = jnp.arange(d_out) < 384
    arch_mask = jnp.broadcast_to(arch_active[None, :], (d_in, d_out))
    tmpl_mask = jnp.broadcast_to((jnp.arange(d_out) < 128)[None, :],
                                 (d_in, d_out))
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out))
    tmpl = F.StructuredFanIn.export_from_dense(w, tmpl_mask)
    assert tmpl.active_index.shape[-1] == 128

    legacy = _State(step=jnp.int32(1),
                    serve={"stack": {"neuron_active": arch_active}})
    CKPT.save(str(tmp_path), legacy)
    got = CKPT.restore(str(tmp_path), 1, _State(step=jnp.int32(0),
                                                serve={"stack": tmpl})).serve["stack"]
    assert got.active_index.shape[-1] == sm.padded_active_count(384, d_out)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, d_in))
    np.testing.assert_array_equal(np.array(got.apply(x, w)),
                                  np.array(x @ (w * arch_mask)))
