"""ServingEngine: admission, plan-key grouping, mixed-batch fusion,
token-identity, retire semantics, and live-training refresh.

The acceptance criteria made executable: a mixed-batch submission set lands
in the plan-key groups the cost model predicts (batch bucket x format
signature — shared with the autotune cache keys), a group's requests fuse
into one decode program dispatch per (prompt_len, gen_len) slab, and every
request's greedy tokens are identical to a standalone ``generate`` run —
batching must never change a stream's tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import engine as ENG
from repro.launch import serve
from repro.models import model as M
from repro.sparse import autotune as AT
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    return cfg, reg, params, masks


def _prompts(b, t, seed=1, vocab=512):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, vocab)


# ---------------------------------------------------------------------------
# grouping by plan key
# ---------------------------------------------------------------------------

def test_mixed_batch_submissions_land_in_predicted_groups(smoke_setup):
    """Mixed batch sizes: each request groups under (its batch bucket x the
    format signature at that bucket). On the smoke config the cost model
    picks condensed for small buckets and masked by bucket 512, so the
    B=200 request must NOT share a group with the B<=8 ones."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    r1 = eng.submit(_prompts(1, 8, seed=1, vocab=cfg.vocab_size), 4)
    r2 = eng.submit(_prompts(2, 8, seed=2, vocab=cfg.vocab_size), 4)
    r3 = eng.submit(_prompts(3, 8, seed=3, vocab=cfg.vocab_size), 4)
    r4 = eng.submit(_prompts(200, 8, seed=4, vocab=cfg.vocab_size), 4)

    groups = eng.pending_groups()
    by_id = {rid: key for key, rids in groups.items() for rid in rids}
    # predicted keys: bucket(1)=1, bucket(2)=bucket(3)=8, bucket(200)=512
    assert by_id[r1].batch_bucket == 1
    assert by_id[r2].batch_bucket == 8
    assert by_id[r2] == by_id[r3] == eng.plan_key(3)
    assert by_id[r4].batch_bucket == 512
    assert by_id[r4] != by_id[r2]
    # bucketing is the autotune bucketing — plan keys and kernel-tune cache
    # entries come from the same calibration point
    for rid, key in by_id.items():
        assert key.batch_bucket in AT.BATCH_BUCKETS
    # format signatures: condensed at the decode buckets, masked at 512
    assert all(rep == "condensed" for _, rep in by_id[r2].formats)
    assert all(rep == "masked" for _, rep in by_id[r4].formats)


def test_fixed_path_groups_only_by_bucket(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    for key_batch in (1, 2, 200):
        key = eng.plan_key(key_batch)
        assert all(rep == "condensed" for _, rep in key.formats)
    assert eng.plan_key(2) == eng.plan_key(8)
    assert eng.plan_key(2) != eng.plan_key(1)


def test_abstract_plan_key_matches_engine_grouping(smoke_setup):
    """The dry-run's allocation-free key derivation agrees with the live
    engine whenever no ablation has happened yet (same contract as
    plan_for_shape vs build_plan)."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    for batch in (1, 4, 200):
        key, reps = ENG.abstract_plan_key(cfg, reg, batch)
        assert key == eng.plan_key(batch)
        assert reps == dict(key.formats)


# ---------------------------------------------------------------------------
# execution: fusion + token identity
# ---------------------------------------------------------------------------

def test_group_fuses_same_shape_requests_into_one_slab(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    eng.submit(_prompts(2, 8, seed=1, vocab=cfg.vocab_size), 4)
    eng.submit(_prompts(3, 8, seed=2, vocab=cfg.vocab_size), 4)
    reports = eng.step()
    assert len(reports) == 1
    assert reports[0].n_slabs == 1          # same (T, gen): one dispatch
    assert reports[0].total_batch == 5
    assert sorted(reports[0].request_ids) == [0, 1]


def test_engine_tokens_identical_to_standalone_generate(smoke_setup):
    """Greedy decode is batch-independent: a request fused into a group slab
    must produce exactly the tokens it produces alone — for every path."""
    cfg, reg, params, masks = smoke_setup
    pa = _prompts(2, 8, seed=11, vocab=cfg.vocab_size)
    pb = _prompts(3, 8, seed=12, vocab=cfg.vocab_size)
    for path in ("masked", "condensed", "auto"):
        eng = ENG.ServingEngine(cfg, params, masks, reg, path=path)
        ra = eng.submit(pa, 6)
        rb = eng.submit(pb, 6)
        eng.step()
        tree = serve.build_serving_masks(cfg, reg, params, masks, path,
                                         batch_size=eng.plan_key(2).batch_bucket)
        for rid, prompts in ((ra, pa), (rb, pb)):
            [res] = eng.retire(rid)
            ref = serve.generate(cfg, params, tree, prompts, 6)
            np.testing.assert_array_equal(np.array(res.tokens), np.array(ref))
            assert res.plan_key == eng.plan_key(prompts.shape[0])


def test_engine_matches_pre_redesign_serve_cli_output(smoke_setup):
    """The acceptance criterion: engine-served greedy decode is
    token-identical to the direct prefill+scan-decode path (what serve.py
    executed before the engine existed) for every format."""
    cfg, reg, params, masks = smoke_setup
    prompts = _prompts(2, 8, seed=21, vocab=cfg.vocab_size)
    out_masked = serve.generate(cfg, params, masks, prompts, 6)
    for path in PLAN.PATHS:
        if path == "structured":
            continue  # documented: not output-equivalent for fine masks
        eng = ENG.ServingEngine(cfg, params, masks, reg, path=path)
        rid = eng.submit(prompts, 6)
        eng.step()
        [res] = eng.retire(rid)
        np.testing.assert_array_equal(np.array(res.tokens),
                                      np.array(out_masked))


def test_mixed_shape_requests_in_one_group_decode_correctly(smoke_setup):
    """Different (prompt_len, gen_len) under one plan key: separate slabs,
    shared plan, correct per-request shapes and tokens."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    pa = _prompts(2, 8, seed=31, vocab=cfg.vocab_size)
    pb = _prompts(2, 6, seed=32, vocab=cfg.vocab_size)
    ra = eng.submit(pa, 4)
    rb = eng.submit(pb, 5)
    reports = eng.step()
    assert len(reports) == 1 and reports[0].n_slabs == 2
    tree = serve.build_serving_masks(cfg, reg, params, masks, "condensed")
    [res_a] = eng.retire(ra)
    [res_b] = eng.retire(rb)
    assert res_a.tokens.shape == (2, 8 + 4)
    assert res_b.tokens.shape == (2, 6 + 5)
    np.testing.assert_array_equal(np.array(res_a.tokens),
                                  np.array(serve.generate(cfg, params, tree,
                                                          pa, 4)))
    np.testing.assert_array_equal(np.array(res_b.tokens),
                                  np.array(serve.generate(cfg, params, tree,
                                                          pb, 5)))


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_submit_validates_and_retire_pops(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    with pytest.raises(ValueError):
        eng.submit(jnp.zeros((4,), jnp.int32), 4)     # not (B, T)
    with pytest.raises(ValueError):
        eng.submit(_prompts(1, 4, vocab=cfg.vocab_size), 0)
    with pytest.raises(ValueError):
        ENG.ServingEngine(cfg, params, masks, reg, path="csr")

    rid = eng.submit(_prompts(1, 4, vocab=cfg.vocab_size), 2)
    assert eng.retire(rid) == []                       # not stepped yet
    eng.step()
    assert len(eng.retire(rid)) == 1
    assert eng.retire(rid) == []                       # popped exactly once
    assert eng.retire() == []


def test_plan_cache_is_reused_across_steps(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    for seed in (41, 42):
        eng.submit(_prompts(2, 8, seed=seed, vocab=cfg.vocab_size), 2)
        eng.step()
    key = eng.plan_key(2)
    plan = eng.plan_for(key)
    assert eng.plan_for(key) is plan                   # one plan per key
    assert plan.export_calls == len(reg)               # built exactly once


def test_engine_refresh_keeps_serving_live_training(smoke_setup):
    """engine.refresh propagates trained weights into every cached plan
    (values-only regathers when topology is unchanged) and later steps
    serve the NEW weights."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            mask_versions={s.name: 0 for s in reg})
    prompts = _prompts(2, 8, seed=51, vocab=cfg.vocab_size)
    eng.submit(prompts, 4)
    eng.step()
    eng.retire()

    new_params = jax.tree.map(lambda x: x, params)
    for s in reg:
        w = REG.get_path(new_params, s.path)
        REG.set_path(new_params, s.path, w * 1.25)
    changed = eng.refresh(new_params, masks, {s.name: 0 for s in reg})
    assert all(v == [] for v in changed.values())      # no topology change

    rid = eng.submit(prompts, 4)
    eng.step()
    [res] = eng.retire(rid)
    ref = serve.generate(cfg, new_params, masks, prompts, 4)
    np.testing.assert_array_equal(np.array(res.tokens), np.array(ref))


def test_step_failure_keeps_unexecuted_requests_pending(smoke_setup,
                                                        monkeypatch):
    """An exception mid-step must not silently drop queued work: requests
    whose slab never executed stay pending and a later step serves them."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    ra = eng.submit(_prompts(1, 8, seed=61, vocab=cfg.vocab_size), 3)
    rb = eng.submit(_prompts(2, 8, seed=62, vocab=cfg.vocab_size), 3)

    calls = {"n": 0}
    real = ENG._timed_serve

    def flaky(*args, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected slab failure")
        return real(*args, **kw)

    monkeypatch.setattr(ENG, "_timed_serve", flaky)
    with pytest.raises(RuntimeError, match="injected"):
        eng.step()
    # NEITHER request was served; BOTH are still queued (the failed slab's
    # request included — it produced no result)
    pending = [rid for rids in eng.pending_groups().values() for rid in rids]
    assert sorted(pending) == sorted([ra, rb])
    assert eng.retire() == []

    eng.step()   # retry succeeds
    assert {r.id for r in eng.retire()} == {ra, rb}
