"""ServingEngine: admission, plan-key grouping, mixed-batch fusion,
token-identity, retire semantics, and live-training refresh.

The acceptance criteria made executable: a mixed-batch submission set lands
in the plan-key groups the cost model predicts (batch bucket x format
signature — shared with the autotune cache keys), a group's requests fuse
into one decode program dispatch per (prompt_len, gen_len) slab, and every
request's greedy tokens are identical to a standalone ``generate`` run —
batching must never change a stream's tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import engine as ENG
from repro.launch import serve
from repro.models import model as M
from repro.sparse import autotune as AT
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    return cfg, reg, params, masks


def _prompts(b, t, seed=1, vocab=512):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, vocab)


# ---------------------------------------------------------------------------
# grouping by plan key
# ---------------------------------------------------------------------------

def test_mixed_batch_submissions_land_in_predicted_groups(smoke_setup):
    """Mixed batch sizes: each request groups under (its batch bucket x the
    format signature at that bucket). On the smoke config the cost model
    picks condensed for small buckets and masked by bucket 512, so the
    B=200 request must NOT share a group with the B<=8 ones."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    r1 = eng.submit(_prompts(1, 8, seed=1, vocab=cfg.vocab_size), 4)
    r2 = eng.submit(_prompts(2, 8, seed=2, vocab=cfg.vocab_size), 4)
    r3 = eng.submit(_prompts(3, 8, seed=3, vocab=cfg.vocab_size), 4)
    r4 = eng.submit(_prompts(200, 8, seed=4, vocab=cfg.vocab_size), 4)

    groups = eng.pending_groups()
    by_id = {rid: key for key, rids in groups.items() for rid in rids}
    # predicted keys: bucket(1)=1, bucket(2)=bucket(3)=8, bucket(200)=512
    assert by_id[r1].batch_bucket == 1
    assert by_id[r2].batch_bucket == 8
    assert by_id[r2] == by_id[r3] == eng.plan_key(3)
    assert by_id[r4].batch_bucket == 512
    assert by_id[r4] != by_id[r2]
    # bucketing is the autotune bucketing — plan keys and kernel-tune cache
    # entries come from the same calibration point
    for rid, key in by_id.items():
        assert key.batch_bucket in AT.BATCH_BUCKETS
    # format signatures: condensed at the decode buckets, masked at 512
    assert all(rep == "condensed" for _, rep in by_id[r2].formats)
    assert all(rep == "masked" for _, rep in by_id[r4].formats)


def test_fixed_path_groups_only_by_bucket(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    for key_batch in (1, 2, 200):
        key = eng.plan_key(key_batch)
        assert all(rep == "condensed" for _, rep in key.formats)
    assert eng.plan_key(2) == eng.plan_key(8)
    assert eng.plan_key(2) != eng.plan_key(1)


def test_abstract_plan_key_matches_engine_grouping(smoke_setup):
    """The dry-run's allocation-free key derivation agrees with the live
    engine whenever no ablation has happened yet (same contract as
    plan_for_shape vs build_plan)."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    for batch in (1, 4, 200):
        key, reps = ENG.abstract_plan_key(cfg, reg, batch)
        assert key == eng.plan_key(batch)
        assert reps == dict(key.formats)


# ---------------------------------------------------------------------------
# execution: fusion + token identity
# ---------------------------------------------------------------------------

def test_group_fuses_same_shape_requests_into_one_slab(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    eng.submit(_prompts(2, 8, seed=1, vocab=cfg.vocab_size), 4)
    eng.submit(_prompts(3, 8, seed=2, vocab=cfg.vocab_size), 4)
    reports = eng.step()
    assert len(reports) == 1
    assert reports[0].n_slabs == 1          # same (T, gen): one dispatch
    assert reports[0].total_batch == 5
    assert sorted(reports[0].request_ids) == [0, 1]


def test_engine_tokens_identical_to_standalone_generate(smoke_setup):
    """Greedy decode is batch-independent: a request fused into a group slab
    must produce exactly the tokens it produces alone — for every path."""
    cfg, reg, params, masks = smoke_setup
    pa = _prompts(2, 8, seed=11, vocab=cfg.vocab_size)
    pb = _prompts(3, 8, seed=12, vocab=cfg.vocab_size)
    for path in ("masked", "condensed", "auto"):
        eng = ENG.ServingEngine(cfg, params, masks, reg, path=path)
        ra = eng.submit(pa, 6)
        rb = eng.submit(pb, 6)
        eng.step()
        tree = serve.build_serving_masks(cfg, reg, params, masks, path,
                                         batch_size=eng.plan_key(2).batch_bucket)
        for rid, prompts in ((ra, pa), (rb, pb)):
            [res] = eng.retire(rid)
            ref = serve.generate(cfg, params, tree, prompts, 6)
            np.testing.assert_array_equal(np.array(res.tokens), np.array(ref))
            assert res.plan_key == eng.plan_key(prompts.shape[0])


def test_engine_matches_pre_redesign_serve_cli_output(smoke_setup):
    """The acceptance criterion: engine-served greedy decode is
    token-identical to the direct prefill+scan-decode path (what serve.py
    executed before the engine existed) for every format."""
    cfg, reg, params, masks = smoke_setup
    prompts = _prompts(2, 8, seed=21, vocab=cfg.vocab_size)
    out_masked = serve.generate(cfg, params, masks, prompts, 6)
    for path in PLAN.PATHS:
        if path == "structured":
            continue  # documented: not output-equivalent for fine masks
        eng = ENG.ServingEngine(cfg, params, masks, reg, path=path)
        rid = eng.submit(prompts, 6)
        eng.step()
        [res] = eng.retire(rid)
        np.testing.assert_array_equal(np.array(res.tokens),
                                      np.array(out_masked))


def test_mixed_shape_requests_in_one_group_decode_correctly(smoke_setup):
    """Different (prompt_len, gen_len) under one plan key: ONE bucket-padded
    prefill admits both (prompts padded to the shared prompt bucket), shared
    plan, correct per-request shapes and tokens."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    pa = _prompts(2, 8, seed=31, vocab=cfg.vocab_size)
    pb = _prompts(2, 6, seed=32, vocab=cfg.vocab_size)
    ra = eng.submit(pa, 4)
    rb = eng.submit(pb, 5)
    reports = eng.step()
    assert len(reports) == 1 and reports[0].n_slabs == 1
    tree = serve.build_serving_masks(cfg, reg, params, masks, "condensed")
    [res_a] = eng.retire(ra)
    [res_b] = eng.retire(rb)
    assert res_a.tokens.shape == (2, 8 + 4)
    assert res_b.tokens.shape == (2, 6 + 5)
    np.testing.assert_array_equal(np.array(res_a.tokens),
                                  np.array(serve.generate(cfg, params, tree,
                                                          pa, 4)))
    np.testing.assert_array_equal(np.array(res_b.tokens),
                                  np.array(serve.generate(cfg, params, tree,
                                                          pb, 5)))


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_submit_validates_and_retire_pops(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    with pytest.raises(ValueError):
        eng.submit(jnp.zeros((4,), jnp.int32), 4)     # not (B, T)
    with pytest.raises(ValueError):
        eng.submit(_prompts(1, 4, vocab=cfg.vocab_size), 0)
    with pytest.raises(ValueError):
        ENG.ServingEngine(cfg, params, masks, reg, path="csr")

    rid = eng.submit(_prompts(1, 4, vocab=cfg.vocab_size), 2)
    assert eng.retire(rid) == []                       # not stepped yet
    eng.step()
    assert len(eng.retire(rid)) == 1
    assert eng.retire(rid) == []                       # popped exactly once
    assert eng.retire() == []


def test_plan_cache_is_reused_across_steps(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    for seed in (41, 42):
        eng.submit(_prompts(2, 8, seed=seed, vocab=cfg.vocab_size), 2)
        eng.step()
    key = eng.plan_key(2)
    plan = eng.plan_for(key)
    assert eng.plan_for(key) is plan                   # one plan per key
    assert plan.export_calls == len(reg)               # built exactly once


def test_engine_refresh_keeps_serving_live_training(smoke_setup):
    """engine.refresh propagates trained weights into every cached plan
    (values-only regathers when topology is unchanged) and later steps
    serve the NEW weights."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            mask_versions={s.name: 0 for s in reg})
    prompts = _prompts(2, 8, seed=51, vocab=cfg.vocab_size)
    eng.submit(prompts, 4)
    eng.step()
    eng.retire()

    new_params = jax.tree.map(lambda x: x, params)
    for s in reg:
        w = REG.get_path(new_params, s.path)
        REG.set_path(new_params, s.path, w * 1.25)
    changed = eng.refresh(new_params, masks, {s.name: 0 for s in reg})
    assert all(v == [] for v in changed.values())      # no topology change

    rid = eng.submit(prompts, 4)
    eng.step()
    [res] = eng.retire(rid)
    ref = serve.generate(cfg, new_params, masks, prompts, 4)
    np.testing.assert_array_equal(np.array(res.tokens), np.array(ref))


# ---------------------------------------------------------------------------
# continuous batching: compile economy, mid-flight admission, cold flags
# ---------------------------------------------------------------------------

def test_adversarial_mix_compiles_one_prefill_and_one_decode(smoke_setup):
    """The tentpole acceptance criterion: requests with adversarially varied
    (batch, prompt_len) inside one bucket share ONE compiled prefill program
    (bucket x prompt bucket) and ONE decode program (bucket x chunk) —
    asserted via the jit cache-miss counters."""
    cfg, reg, params, masks = smoke_setup
    # unique block_size so this test's program shapes are fresh regardless
    # of what other tests already compiled into the module-level caches
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            block_size=4, gen_chunk=8)
    if ENG._jit_entries(ENG._paged_prefill) == -1:
        pytest.skip("jit cache introspection unavailable on this jax")

    n_pre = ENG._jit_entries(ENG._paged_prefill)
    n_dec = ENG._jit_entries(ENG._paged_decode_chunk)
    for b, t, seed in ((2, 8, 71), (3, 6, 72), (2, 5, 73)):
        eng.submit(_prompts(b, t, seed=seed, vocab=cfg.vocab_size), 6)
    eng.step()
    assert ENG._jit_entries(ENG._paged_prefill) - n_pre == 1
    assert ENG._jit_entries(ENG._paged_decode_chunk) - n_dec == 1

    # a second adversarial wave reuses both programs: zero new compiles,
    # and (warm=True default) nothing rode a compile in its timed window
    n_pre = ENG._jit_entries(ENG._paged_prefill)
    n_dec = ENG._jit_entries(ENG._paged_decode_chunk)
    for b, t, seed in ((3, 8, 75), (3, 3, 76), (2, 4, 77)):
        eng.submit(_prompts(b, t, seed=seed, vocab=cfg.vocab_size), 6)
    eng.step()
    assert ENG._jit_entries(ENG._paged_prefill) - n_pre == 0
    assert ENG._jit_entries(ENG._paged_decode_chunk) - n_dec == 0
    results = eng.retire()
    assert len(results) == 6
    assert not any(r.cold for r in results)


def test_mid_generation_admission_and_early_retirement_identity(smoke_setup):
    """A stream admitted into a RUNNING generation, and one retired while
    others continue, each produce exactly their standalone tokens."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            gen_chunk=2)
    pa = _prompts(2, 8, seed=81, vocab=cfg.vocab_size)
    pb = _prompts(2, 6, seed=82, vocab=cfg.vocab_size)
    ra = eng.submit(pa, 8)
    eng.step(max_chunks=1)              # ra admitted, 2/8 tokens decoded
    assert eng.retire() == []
    rb = eng.submit(pb, 3)              # joins mid-generation of ra
    eng.step(max_chunks=1)
    for _ in range(8):
        if not eng._runners[eng.plan_key(2)].active:
            break
        eng.step(max_chunks=1)          # rb retires early, ra continues
    tree = serve.build_serving_masks(cfg, reg, params, masks, "condensed",
                                     batch_size=eng.plan_key(2).batch_bucket)
    [res_a] = eng.retire(ra)
    [res_b] = eng.retire(rb)
    np.testing.assert_array_equal(
        np.array(res_a.tokens), np.array(serve.generate(cfg, params, tree,
                                                        pa, 8)))
    np.testing.assert_array_equal(
        np.array(res_b.tokens), np.array(serve.generate(cfg, params, tree,
                                                        pb, 3)))


def test_submit_validation_rejects_malformed_tokens(smoke_setup):
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="auto")
    with pytest.raises(ValueError, match="integer token ids"):
        eng.submit(jnp.zeros((1, 4), jnp.float32), 2)
    with pytest.raises(ValueError, match="out of range"):
        eng.submit(jnp.full((1, 4), cfg.vocab_size, jnp.int32), 2)
    with pytest.raises(ValueError, match="out of range"):
        eng.submit(jnp.full((1, 4), -1, jnp.int32), 2)
    with pytest.raises(ValueError, match="both dims"):
        eng.submit(jnp.zeros((0, 4), jnp.int32), 2)
    # valid int64 input is cast, not rejected
    rid = eng.submit(np.zeros((1, 4), np.int64), 2)
    assert eng._pending[-1].prompts.dtype == jnp.int32
    assert eng._pending[-1].id == rid


def test_cold_flag_marks_unwarmed_first_dispatch(smoke_setup):
    """warm=False: the first request through a fresh program signature is
    flagged cold (its timings include the XLA compile); the next request
    through the same signature is not."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            block_size=5, gen_chunk=3, warm=False)
    if ENG._jit_entries(ENG._paged_prefill) == -1:
        pytest.skip("jit cache introspection unavailable on this jax")
    r1 = eng.submit(_prompts(2, 8, seed=91, vocab=cfg.vocab_size), 3)
    eng.step()
    [res1] = eng.retire(r1)
    assert res1.cold
    r2 = eng.submit(_prompts(2, 8, seed=92, vocab=cfg.vocab_size), 3)
    eng.step()
    [res2] = eng.retire(r2)
    assert not res2.cold


def test_legacy_path_splits_slabs_at_bucket_boundary(smoke_setup,
                                                     monkeypatch):
    """The original overflow bug, pinned: same-(T, gen) requests totaling
    more streams than the bucket must NOT fuse into one oversized slab —
    the plan (and its tuned kernels) is calibrated at the bucket."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed",
                            paged=False)
    batches = []
    real = ENG._timed_serve

    def spy(cfg_, params_, tree_, prompts, gen_len):
        batches.append(prompts.shape[0])
        return real(cfg_, params_, tree_, prompts, gen_len)

    monkeypatch.setattr(ENG, "_timed_serve", spy)
    prompts = [_prompts(3, 8, seed=s, vocab=cfg.vocab_size)
               for s in (101, 102, 103)]
    rids = [eng.submit(p, 4) for p in prompts]
    [report] = eng.step()               # 9 streams in a bucket-8 group
    assert report.key.batch_bucket == 8
    assert report.n_slabs == 2          # split, not one 9-stream slab
    assert all(b <= report.key.batch_bucket for b in batches)
    assert sum(batches) == 9
    tree = serve.build_serving_masks(cfg, reg, params, masks, "condensed",
                                     batch_size=8)
    for rid, p in zip(rids, prompts):
        [res] = eng.retire(rid)
        np.testing.assert_array_equal(
            np.array(res.tokens),
            np.array(serve.generate(cfg, params, tree, p, 4)))


def test_step_failure_keeps_unexecuted_requests_pending(smoke_setup,
                                                        monkeypatch):
    """An exception mid-step must not silently drop queued work: requests
    whose slab never executed stay pending and a later step serves them."""
    cfg, reg, params, masks = smoke_setup
    eng = ENG.ServingEngine(cfg, params, masks, reg, path="condensed")
    ra = eng.submit(_prompts(1, 8, seed=61, vocab=cfg.vocab_size), 3)
    rb = eng.submit(_prompts(2, 8, seed=62, vocab=cfg.vocab_size), 3)

    calls = {"n": 0}
    real = ENG._paged_prefill_dispatch

    def flaky(*args, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected slab failure")
        return real(*args, **kw)

    monkeypatch.setattr(ENG, "_paged_prefill_dispatch", flaky)
    with pytest.raises(RuntimeError, match="injected"):
        eng.step()
    # NEITHER request was served; BOTH are still queued (the failed slab's
    # request included — it produced no result)
    pending = [rid for rids in eng.pending_groups().values() for rid in rids]
    assert sorted(pending) == sorted([ra, rb])
    assert eng.retire() == []

    eng.step()   # retry succeeds
    assert {r.id for r in eng.retire()} == {ra, rb}
