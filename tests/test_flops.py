"""FLOPs accounting (paper Table 5 methodology)."""
import pytest

from repro.core import flops as F


def test_sparse_vs_dense_ratio():
    layers = [F.LinearCost("a", 1024, 1024, density=0.1),
              F.LinearCost("b", 1024, 4096, density=0.1)]
    assert F.sparse_vs_dense_ratio(layers) == pytest.approx(0.1)


def test_training_is_3x_inference():
    layers = [F.LinearCost("a", 512, 512, density=0.2)]
    inf = F.inference_flops(layers, tokens=1000)
    tr = F.training_flops(layers, tokens_per_step=1000, steps=1)
    assert tr == pytest.approx(3 * inf)


def test_table5_shape():
    """Reproduce the *structure* of Table 5: inference FLOPs scale ~(1-s)."""
    def model(density):
        return [F.LinearCost(f"l{i}", 2048, 2048, density=density) for i in range(24)]
    dense = F.inference_flops(model(1.0), 1)
    for s, expected in [(0.8, 0.2), (0.9, 0.1), (0.95, 0.05), (0.99, 0.01)]:
        ratio = F.inference_flops(model(1 - s), 1) / dense
        assert ratio == pytest.approx(expected, rel=1e-6)


def test_moe_token_scale():
    # top-8 of 32 experts: each token hits 8/32 of expert params
    l = F.LinearCost("e", 1024, 512, density=1.0, n_replicas=32, tokens_scale=8 / 32)
    per_token = l.fwd_flops_per_token()
    assert per_token == pytest.approx(2 * 1024 * 512 * 8 / 32)
