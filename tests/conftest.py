import os
import sys
import tempfile

# Tests run on the single host CPU device (the 512-device forcing is ONLY in
# repro.launch.dryrun, which must never be imported here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Hermetic autotune cache: the kernel-block/profile cache is PERSISTENT by
# design (~/.cache/repro/autotune.json), but tests must neither read a
# developer's tuned entries (block-shape resolution would differ from a clean
# checkout) nor pollute them. Tests that exercise the cache itself repoint
# this again via monkeypatch + autotune.reset_cache_state().
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro-autotune-test-"), "autotune.json")

import jax

jax.config.update("jax_enable_x64", False)
