import os
import sys

# Tests run on the single host CPU device (the 512-device forcing is ONLY in
# repro.launch.dryrun, which must never be imported here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
