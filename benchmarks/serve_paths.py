"""Serving-path decode throughput: all four representations + the auto plan.

Reproduces the *shape* of the paper's Fig. 6/7 claim (real-world inference
acceleration from constant fan-in sparsity) on the smoke LM: for each batch
size in {1, 32, 256}, run the jitted lax.scan greedy-decode loop through each
serving representation (masked / condensed / structured /
condensed_over_active) plus the cost-model ``auto`` plan, and report
tokens/second. The auto rows also record which representation the plan chose
per stack — the expected trajectory is condensed at B=1 flipping to masked by
B=256 (paper Sec. 4.4 crossover).

Besides the CSV rows, ``main`` emits machine-readable
``BENCH_serve_paths.json`` so the perf trajectory is tracked across PRs.

CPU caveat (same as condensed_bench): the Pallas kernel runs in interpret
mode here, so absolute condensed timings do not transfer to the TPU/GPU
target — the analytic weight-bytes ratio in the derived column is the
quantity that does (decode is bandwidth-bound). The ratio is each plan's
per-step weight traffic relative to the MASKED serving path (dense weights +
bool mask), so masked == 1.0 by definition and an auto plan that resolves
every stack to masked also reports exactly 1.0.
"""
import argparse
import json

import jax

from repro import configs
from repro.launch import serve
from repro.models import model as M
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG

BATCHES = (1, 32, 256)
PROMPT_LEN = 8
GEN_LEN = 8


def run(batches=BATCHES, arch: str = "qwen3-1.7b", results: list | None = None):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]

    rows = []
    for batch in batches:
        prompts = jax.random.randint(key, (batch, PROMPT_LEN), 0, cfg.vocab_size)
        for path in PLAN.PATHS:
            if path == "masked":
                sm, reps, ratio = masks, {s.name: "masked" for s in reg}, 1.0
            else:
                plan = serve.build_plan(cfg, reg, params, masks, path,
                                        batch_size=batch)
                sm = plan.serving_tree
                reps = {n: d.representation for n, d in plan.decisions.items()}
                sb, db = plan.weight_bytes()
                ratio = sb / db
            # compile (prefill jit + decode-loop jit), then one timed pass
            serve.serve_once(cfg, params, sm, prompts, GEN_LEN, path, quiet=True)
            _, tok_s = serve.serve_once(cfg, params, sm, prompts, GEN_LEN, path,
                                        quiet=True)
            # decode-only per-token cost (prefill excluded — the claim under
            # benchmark is decode throughput, and interpret-mode prefill would
            # otherwise dominate the condensed column)
            rows.append((f"serve_paths/{path}/b{batch}",
                         1e6 / tok_s,
                         f"tok_s={tok_s:.1f};weight_bytes_ratio={ratio:.3f}"))
            if results is not None:
                results.append({
                    "arch": arch, "batch": batch, "path": path,
                    "tok_s": round(tok_s, 2),
                    "us_per_tok": round(1e6 / tok_s, 2),
                    "weight_bytes_ratio": round(ratio, 4),
                    "representations": reps,
                })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batches", default=",".join(map(str, BATCHES)))
    ap.add_argument("--out", default="BENCH_serve_paths.json",
                    help="machine-readable results (perf trajectory across PRs)")
    args = ap.parse_args(argv)
    batches = tuple(int(b) for b in args.batches.split(","))

    results: list = []
    rows = run(batches=batches, arch=args.arch, results=results)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        payload = {
            "benchmark": "serve_paths",
            "arch": args.arch,
            "prompt_len": PROMPT_LEN,
            "gen_len": GEN_LEN,
            "backend": jax.default_backend(),
            "pallas_interpret_note": "condensed timings are interpret-mode on "
                                     "CPU; weight_bytes_ratio is the "
                                     "hardware-transferable quantity",
            "rows": results,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"[serve_paths] wrote {args.out} ({len(results)} rows)")


if __name__ == "__main__":
    main()
