"""Serving-path decode throughput: masked vs condensed vs structured.

Reproduces the *shape* of the paper's Fig. 6/7 claim (real-world inference
acceleration from constant fan-in sparsity) on the smoke LM: for each batch
size in {1, 32, 256}, run the jitted lax.scan greedy-decode loop through each
serving representation and report tokens/second.

CPU caveat (same as condensed_bench): the Pallas kernel runs in interpret
mode here, so absolute condensed timings do not transfer to the TPU/GPU
target — the analytic weight-bytes ratio printed in the derived column is the
quantity that does (decode is bandwidth-bound).
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import serve
from repro.models import model as M
from repro.sparse import condensed as COND
from repro.sparse import registry as REG

BATCHES = (1, 32, 256)
PROMPT_LEN = 8
GEN_LEN = 8


def run(batches=BATCHES, arch: str = "qwen3-1.7b"):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    cond_bytes, dense_bytes = COND.condensed_bytes(cfg, reg)

    rows = []
    for batch in batches:
        prompts = jax.random.randint(key, (batch, PROMPT_LEN), 0, cfg.vocab_size)
        for path in serve.PATHS:
            sm = serve.build_serving_masks(cfg, reg, params, masks, path)
            # compile (prefill jit + decode-loop jit), then one timed pass
            serve.serve_once(cfg, params, sm, prompts, GEN_LEN, path, quiet=True)
            _, tok_s = serve.serve_once(cfg, params, sm, prompts, GEN_LEN, path,
                                        quiet=True)
            ratio = {"masked": 1.0, "structured": 1.0,
                     "condensed": cond_bytes / dense_bytes}[path]
            # decode-only per-token cost (prefill excluded — the claim under
            # benchmark is decode throughput, and interpret-mode prefill would
            # otherwise dominate the condensed column)
            rows.append((f"serve_paths/{path}/b{batch}",
                         1e6 / tok_s,
                         f"tok_s={tok_s:.1f};weight_bytes_ratio={ratio:.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
