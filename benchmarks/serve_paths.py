"""Serving-path decode throughput: all four formats + the auto plan, driven
through the programmatic ``ServingEngine`` API.

Reproduces the *shape* of the paper's Fig. 6/7 claim (real-world inference
acceleration from constant fan-in sparsity) on the smoke LM: for each batch
size in {1, 32, 256}, submit one request per serving path (masked /
condensed / structured / condensed_over_active / auto) to a
``repro.launch.engine.ServingEngine`` and report decode tokens/second from
the engine's own timings. The auto rows also record which FORMAT the plan
chose per stack — the expected trajectory is condensed at B=1 flipping to
masked by B=256 (paper Sec. 4.4 crossover) — and which hardware profile
priced the decision (``--profile measured`` calibrates the cost model on
this machine via ``plan.HardwareProfile.measure()``, including the
two-point gather calibration, instead of the v5e-like defaults).

Timing discipline: ``--warmup`` un-timed passes absorb jit compilation and
dispatch-cache warming, then ``us_per_tok`` / ``tok_s`` are the MEDIAN of
``--reps`` timed passes (a single timed pass can fold compile/dispatch
jitter into the trajectory JSON).

Besides the CSV rows, ``main`` emits machine-readable
``BENCH_serve_paths.json`` (``schema_version`` stamped — v2 renamed the
per-row representation record to ``formats``; v3 added per-row
``predicted_us_per_tok`` from the plan's cost model and the high-ablation
sweep) so the perf trajectory — and the COST MODEL's pricing fidelity
against it — is tracked across PRs.

Pricing-fidelity column: ``predicted_us_per_tok`` is the cost model's
estimate for the row's chosen per-stack representations at the plan's batch
bucket, summed over the SPARSE stacks only (attention/norm/embedding math is
not priced), so it is a tracking signal for relative drift across PRs, not
an absolute latency prediction.

High-ablation sweep (``--ablations``): each listed fraction re-runs every
(path, batch) cell with that fraction of output neurons ablated on top of
the constant fan-in masks — the structured rows then exercise the
column-gathered Pallas kernel and the condensed_over_active rows the fused
scatter-epilogue kernel with genuinely dropped rows.

CPU caveat (same as condensed_bench): the Pallas kernel runs in interpret
mode here, so absolute condensed timings do not transfer to the TPU/GPU
target — the analytic weight-bytes ratio in the derived column is the
quantity that does (decode is bandwidth-bound). The ratio is each plan's
per-step weight traffic relative to the MASKED serving path (dense weights +
bool mask), so masked == 1.0 by definition and an auto plan that resolves
every stack to masked also reports exactly 1.0.
"""
import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.engine import ServingEngine
from repro.models import model as M
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG

# v4: scheduler rows (path="scheduler") — Poisson-arrival trace through the
# paged continuous-batching engine with p50/p99 end-to-end latency, plus the
# padded-vs-exact full-bucket throughput comparison. The per-path format
# rows keep running on the legacy exact-shape slab engine (paged=False) so
# their us_per_tok stays comparable across PRs.
# v5: every row records ``values_dtype`` (existing rows: "f32" — all v4
# fields are unchanged, so v4 consumers keep parsing byte-identically), and
# the default sweep adds quantized condensed rows (int8 at B=1 and B=256,
# kind="quantized") measuring greedy token agreement vs the f32 condensed
# engine plus the values-stream byte ratio both PRICED
# (formats.Condensed.estimate_values_bytes) and MEASURED (device array
# nbytes of the exported values+scales).
# v6: kind="tp_crossover" rows — the collective-priced cost model's
# PREDICTED batch where a tensor-parallel sharded condensed stack stops
# beating the replicated path (plan.tp_crossover_batch, at the arch's FULL
# production dims so the prediction is about real stacks, not the smoke
# model). Pure cost-model arithmetic: measured timings stay single-device.
# v7: kind="sync" row — the live train->serve stream's price: full-snapshot
# vs values-only vs topology delta bytes over the file channel
# (delta_vs_snapshot is the wire-traffic ratio continuous sync saves), and
# the p50/p99 per-decode-chunk latency of a subscribed engine with a
# topology delta landing MID-STREAM vs an undisturbed baseline (the cost of
# draining + donated adoption at a chunk boundary).
# v8: kind="speculative" rows — self-draft speculative decoding over the
# paged engine (the ablated subnetwork drafts, the full network verifies):
# measured acceptance rate vs draft-ablation fraction, full-network
# dispatches per token (< 1.0 whenever anything is accepted; 1/(gamma+1) at
# perfect acceptance), us/tok vs the non-speculative baseline, and the
# bitwise token-identity check. Acceptance and dispatches/token are the
# hardware-transferable quantities here (CPU interpret-mode timings are not).
SCHEMA_VERSION = 8

BATCHES = (1, 32, 256)
ABLATIONS = (0.0, 0.5)
PROMPT_LEN = 8
GEN_LEN = 8
WARMUP = 2
REPS = 3


def _ablate_masks(reg, masks, frac: float):
    """Zero the last ``frac`` of each stack's output columns on top of the
    constant fan-in masks (SRigL-style neuron ablation)."""
    if not frac:
        return masks
    out = {}
    for s in reg:
        m = REG.get_path(masks, s.path)
        cut = s.d_out - max(1, int(s.d_out * frac))
        REG.set_path(out, s.path, m & (jnp.arange(s.d_out) < cut)[None, :])
    return out


def _masked_predicted_us_per_tok(reg, stats, bucket: int, itemsize: int,
                                 profile) -> float:
    """Cost-model us/token for the all-masked fast path (the one path served
    without building a Plan; every other row reads its plan's own est_s so
    the recorded prediction is EXACTLY what the plan priced)."""
    total = sum(
        PLAN.stack_costs(s, batch_size=bucket, itemsize=itemsize,
                         k=max(stats[s.name].k, 1),
                         active_fraction=stats[s.name].active_fraction,
                         profile=profile)["masked"]
        for s in reg)
    return total * 1e6 / max(bucket, 1)


def run(batches=BATCHES, arch: str = "qwen3-1.7b", results: list | None = None,
        profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
        warmup: int = WARMUP, reps: int = REPS, ablations=ABLATIONS):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    base_masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    itemsize = jnp.dtype(cfg.param_dtype).itemsize

    rows = []
    for ablation in ablations:
        masks = _ablate_masks(reg, base_masks, ablation)
        stats = COND.export_stats(reg, masks)
        tag = f"/abl{ablation:g}" if ablation else ""
        for batch in batches:
            prompts = jax.random.randint(key, (batch, PROMPT_LEN), 0,
                                         cfg.vocab_size)
            for path in PLAN.PATHS:
                # legacy exact-shape engine: these rows track FORMAT decode
                # throughput across PRs — the scheduler's padding/paging
                # overheads are measured separately by run_scheduler
                engine = ServingEngine(cfg, params, masks, reg, path=path,
                                       profile=profile, paged=False)
                pkey = engine.plan_key(batch)
                if path == "masked":
                    formats_chosen = {s.name: "masked" for s in reg}
                    ratio = 1.0
                    predicted = _masked_predicted_us_per_tok(
                        reg, stats, pkey.batch_bucket, itemsize, profile)
                else:
                    plan = engine.plan_for(pkey)
                    formats_chosen = {n: d.representation
                                      for n, d in plan.decisions.items()}
                    sb, db = plan.weight_bytes()
                    ratio = sb / db
                    # the plan's OWN cost table (what the auto decision was
                    # actually priced with), summed over the sparse stacks
                    predicted = sum(
                        d.est_s[d.representation]
                        for d in plan.decisions.values()
                    ) * 1e6 / max(pkey.batch_bucket, 1)

                def timed_pass():
                    rid = engine.submit(prompts, GEN_LEN)
                    engine.step()
                    [res] = engine.retire(rid)
                    return res.tok_s

                # warmup passes absorb jit compile + dispatch-cache effects...
                for _ in range(max(warmup, 1)):
                    timed_pass()
                # ...then report the median of the timed passes
                toks = [timed_pass() for _ in range(max(reps, 1))]
                tok_s = statistics.median(toks)
                # decode-only per-token cost (prefill excluded — the claim
                # under benchmark is decode throughput, and interpret-mode
                # prefill would otherwise dominate the condensed column)
                rows.append((f"serve_paths/{path}/b{batch}{tag}",
                             1e6 / tok_s,
                             f"tok_s={tok_s:.1f};weight_bytes_ratio={ratio:.3f};"
                             f"pred_us={predicted:.2f}"))
                if results is not None:
                    results.append({
                        "arch": arch, "batch": batch, "path": path,
                        "ablation": ablation,
                        "plan_key_bucket": pkey.batch_bucket,
                        "tok_s": round(tok_s, 2),
                        "us_per_tok": round(1e6 / tok_s, 2),
                        # cost-model estimate at the BUCKET over the sparse
                        # stacks only — a pricing-fidelity tracking signal,
                        # not an absolute latency prediction
                        "predicted_us_per_tok": round(predicted, 6),
                        "tok_s_spread": [round(t, 2) for t in sorted(toks)],
                        "weight_bytes_ratio": round(ratio, 4),
                        "formats": formats_chosen,
                        # the profile only prices the auto rows' decisions,
                        # but is recorded on every row for a self-describing
                        # artifact
                        "profile": profile.name,
                        "values_dtype": "f32",
                    })
    rows += _quantized_rows(cfg, reg, params, base_masks, batches,
                            profile=profile, warmup=warmup, reps=reps,
                            arch=arch, key=key, results=results)
    return rows


# int8 condensed joins the default sweep at the decode end (B=1) and the
# MXU end (B=256) of the batch range — the two points the crossover claim
# is anchored at
QUANT_BATCHES = (1, 256)


def _quantized_rows(cfg, reg, params, masks, batches, *, profile, warmup,
                    reps, arch, key, results):
    """Quantized condensed rows: int8 decode vs the f32 condensed engine.

    Measures what the tentpole claims rather than assuming it: greedy token
    agreement over the generated tokens (int8 engine vs f32 engine, same
    prompts), and the values-stream byte ratio both priced
    (``estimate_values_bytes``) and measured (``values.nbytes`` +
    ``scales.nbytes`` of the exported leaves). The measured ratio exceeds
    the large-k asymptote ``(k+4)/(4k)`` on tiny smoke stacks (the f32
    scales row amortizes over few weights) — the row records the stacks'
    realized k so the artifact is self-interpreting.
    """
    q_batches = [b for b in QUANT_BATCHES if b in batches] or [min(batches)]
    stats = COND.export_stats(reg, masks)
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    rows = []
    for batch in q_batches:
        prompts = jax.random.randint(key, (batch, PROMPT_LEN), 0,
                                     cfg.vocab_size)
        engines = {
            "f32": ServingEngine(cfg, params, masks, reg, path="condensed",
                                 profile=profile, paged=False),
            "int8": ServingEngine(cfg, params, masks, reg, path="condensed",
                                  profile=profile, paged=False,
                                  values_dtype="int8"),
        }
        plans = {vd: e.plan_for(e.plan_key(batch))
                 for vd, e in engines.items()}
        serving = {vd: p.weight_bytes()[0] for vd, p in plans.items()}
        masked_ref = plans["f32"].weight_bytes()[1]
        priced = {vd: 0 for vd in engines}
        measured = {vd: 0 for vd in engines}
        for s in reg:
            for vd in engines:
                spec = F.spec_for_stack(s, stats[s.name], itemsize,
                                        None if vd == "f32" else vd)
                priced[vd] += F.Condensed.estimate_values_bytes(spec)
                leaf = REG.get_path(plans[vd].serving_tree, s.path)
                measured[vd] += leaf.values.nbytes
                if leaf.scales is not None:
                    measured[vd] += leaf.scales.nbytes

        def timed_pass(eng):
            rid = eng.submit(prompts, GEN_LEN)
            eng.step()
            [res] = eng.retire(rid)
            return res

        for eng in engines.values():
            for _ in range(max(warmup, 1)):
                timed_pass(eng)
        f32_res = [timed_pass(engines["f32"]) for _ in range(max(reps, 1))]
        q_res = [timed_pass(engines["int8"]) for _ in range(max(reps, 1))]
        tok_s = statistics.median(r.tok_s for r in q_res)
        gen_f = np.asarray(f32_res[-1].tokens[:, -GEN_LEN:])
        gen_q = np.asarray(q_res[-1].tokens[:, -GEN_LEN:])
        agreement = float(np.mean(gen_f == gen_q))
        vals_priced = priced["int8"] / max(priced["f32"], 1)
        vals_meas = measured["int8"] / max(measured["f32"], 1)
        ks = sorted({stats[s.name].k for s in reg})
        rows.append((f"serve_paths/condensed_int8/b{batch}", 1e6 / tok_s,
                     f"tok_s={tok_s:.1f};values_bytes_vs_f32={vals_meas:.3f};"
                     f"token_agreement={agreement:.3f}"))
        if results is not None:
            results.append({
                "arch": arch, "batch": batch, "path": "condensed",
                "kind": "quantized", "ablation": 0.0,
                "plan_key_bucket": engines["int8"].plan_key(batch).batch_bucket,
                "values_dtype": "int8",
                "tok_s": round(tok_s, 2),
                "us_per_tok": round(1e6 / tok_s, 2),
                "weight_bytes_ratio": round(serving["int8"]
                                            / max(masked_ref, 1), 4),
                "weight_bytes_ratio_vs_f32": round(serving["int8"]
                                                   / max(serving["f32"], 1), 4),
                "values_bytes_ratio_priced": round(vals_priced, 4),
                "values_bytes_ratio_measured": round(vals_meas, 4),
                "token_agreement_vs_f32": round(agreement, 4),
                "stack_fan_ins": ks,
                "profile": profile.name,
            })
    return rows


# speculative sweep: draft-ablation fractions on top of the target plan.
# 0.0 is the identity draft (acceptance is 1.0 by construction — the
# dispatches/token floor 1/(gamma+1) and the bitwise plumbing check);
# higher fractions trade acceptance for cheaper draft steps.
SPEC_ABLATIONS = (0.0, 0.25, 0.5)
SPEC_GAMMA = 3


def run_speculative(arch: str = "qwen3-1.7b", *, req_batch: int = 2,
                    gen_len: int = 24, gamma: int = SPEC_GAMMA,
                    draft_ablations=SPEC_ABLATIONS, warmup: int = WARMUP,
                    reps: int = REPS, seed: int = 0,
                    results: list | None = None):
    """Self-draft speculative decoding rows (schema v8).

    For each draft-ablation fraction, a paged ``--path structured`` engine
    decodes speculatively (``SpecConfig(gamma, fraction, force=True)`` —
    the column-subset draft genuinely runs fewer weight columns) against a
    non-speculative baseline engine on the same prompts. Records the
    MEASURED acceptance rate (draft/target agreement per drafted token),
    full-network dispatches per token (the quantity speculation exists to
    shrink — 1.0 for plain decode), median us/tok for both engines, the
    cost model's accept/decline pricing, and the bitwise token-identity
    bit. Random-init smoke weights make acceptance at nonzero fractions
    near-floor — the 0.0 row pins the protocol ceiling (acceptance 1.0,
    dispatches/token ~ 1/(gamma+1)) and real checkpoints land in between.
    """
    from repro.launch.speculative import SpecConfig
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(seed)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (req_batch, PROMPT_LEN)).astype(np.int32)
    rows = []

    def run_pass(eng):
        rid = eng.submit(prompts, gen_len)
        eng.step()
        [res] = eng.retire(rid)
        return res

    base_eng = ServingEngine(cfg, params, masks, reg, path="structured")
    for _ in range(max(warmup, 1)):
        run_pass(base_eng)
    base_res = [run_pass(base_eng) for _ in range(max(reps, 1))]
    base_tok_s = statistics.median(r.tok_s for r in base_res)
    base_tokens = np.asarray(base_res[-1].tokens)

    for frac in draft_ablations:
        sc = SpecConfig(gamma=gamma, draft_ablation=frac, force=True)
        eng = ServingEngine(cfg, params, masks, reg, path="structured",
                            speculative=sc)
        for _ in range(max(warmup, 1)):
            run_pass(eng)
        res = [run_pass(eng) for _ in range(max(reps, 1))]
        tok_s = statistics.median(r.tok_s for r in res)
        last = res[-1]
        bitwise = bool(np.array_equal(np.asarray(last.tokens), base_tokens))
        s = last.spec
        est = eng.spec_estimate_for(last.plan_key)
        rows.append((
            f"serve_paths/speculative/abl{frac:g}_g{gamma}", 1e6 / tok_s,
            f"acceptance={s['acceptance_rate']:.3f};"
            f"dispatches_per_tok={s['full_dispatches_per_token']:.3f};"
            f"bitwise={bitwise}"))
        if results is not None:
            results.append({
                "arch": arch, "path": "structured", "kind": "speculative",
                "req_batch": req_batch, "gen_len": gen_len,
                "gamma": gamma, "draft_ablation": frac,
                "acceptance_rate": round(s["acceptance_rate"], 4),
                "full_dispatches_per_token":
                    round(s["full_dispatches_per_token"], 4),
                "rounds": s["rounds"],
                "drafted": s["drafted"],
                "matched": s["matched"],
                "tok_s": round(tok_s, 2),
                "us_per_tok": round(1e6 / tok_s, 2),
                "baseline_tok_s": round(base_tok_s, 2),
                "baseline_us_per_tok": round(1e6 / base_tok_s, 2),
                "speedup_vs_baseline": round(tok_s / base_tok_s, 4),
                # the cost model's accept/decline pricing for this key (at
                # its ASSUMED acceptance, not the measured one above)
                "priced_worthwhile": bool(est.worthwhile),
                "priced_spec_us_per_tok": round(est.spec_s_per_token * 1e6,
                                                2),
                "priced_base_us_per_tok": round(est.base_s_per_token * 1e6,
                                                2),
                "bitwise_identical": bitwise,
            })
    return rows


def run_tp_crossover(arch: str = "qwen3-1.7b", *, tp: int = 4,
                     profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
                     results: list | None = None):
    """Predicted TP-vs-replicated crossover batch per sparse stack (v6).

    Pure cost-model rows: for each sparse stack at the arch's FULL production
    dims, ``plan.tp_crossover_batch`` doubles the batch until the collective-
    priced sharded estimate (shard-local gather + per-layer all-gather over
    the interconnect) loses to the best replicated path. ``crossover=1``
    means the collective outweighs the sharding win even at decode batch 1
    (the stack should stay replicated on a TP mesh); ``crossover=None``
    means sharding wins through the whole swept range. No mesh, no timing —
    this is the decision surface ``--path auto`` serves under TP, recorded
    so pricing drift across PRs is visible in the trajectory artifact.
    """
    from repro.core import distributions as D
    cfg = configs.get_config(arch)           # full dims, not the smoke model
    reg = REG.build_registry(cfg)
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    rows = []
    for s in reg:
        if s.d_out % tp:
            continue
        k = D.fan_in_from_density(s.d_in, s.density)
        stats = F.ExportStats(k=k, max_active=s.d_out, active_fraction=1.0,
                              min_fan_in=k)
        cross = PLAN.tp_crossover_batch(s, itemsize=itemsize, stats=stats,
                                        tp=tp, profile=profile)
        rows.append((f"serve_paths/tp_crossover/{s.name}/tp{tp}", 0.0,
                     f"crossover_batch={cross};k={k};d_out={s.d_out}"))
        if results is not None:
            results.append({
                "arch": arch, "path": "auto", "kind": "tp_crossover",
                "stack": s.name, "tp": tp,
                "d_in": s.d_in, "d_out": s.d_out, "k": k,
                # first batch where the replicated path wins; None = sharded
                # condensed wins through the whole swept range
                "crossover_batch": cross,
                "profile": profile.name,
                "ici_bytes_per_s": profile.ici_bytes_per_s,
            })
    return rows


def run_scheduler(arch: str = "qwen3-1.7b", *, n_requests: int = 24,
                  rate: float = 4.0, req_batch: int = 2, gen_len: int = 16,
                  gen_chunk: int = 8, reps: int = REPS, seed: int = 0,
                  results: list | None = None):
    """SLA benchmark for the continuous-batching scheduler.

    Drives a seeded Poisson arrival trace (``rate`` requests/s, ``req_batch``
    streams each) through the paged engine with an event loop stepping ONE
    decode chunk at a time — requests join at chunk boundaries and retire
    mid-generation, exactly the serving regime. Reports p50/p99 end-to-end
    latency (completion minus ARRIVAL, so queueing waits count against the
    scheduler) and aggregate decode throughput.

    Also measures the tentpole's price directly: full-bucket throughput of
    bucket-PADDED slabs (several small requests admitted into one padded
    dispatch) vs one exact-shape slab at the same total batch on the legacy
    engine — ``padded_vs_exact`` is the ratio (>= 0.9 expected: padding work
    on rows the masks discard is bandwidth the bucket already paid for).
    Runs ``--path masked`` so the numbers isolate SCHEDULING, not formats.
    """
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(seed)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    rng = np.random.default_rng(seed)
    rows = []

    # -- Poisson trace ------------------------------------------------------
    engine = ServingEngine(cfg, params, masks, reg, path="masked",
                           gen_chunk=gen_chunk)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    prompts = [rng.integers(0, cfg.vocab_size,
                            (req_batch, PROMPT_LEN)).astype(np.int32)
               for _ in range(n_requests)]
    # warm every program signature the trace will hit (one throwaway
    # request), so the measured latencies are scheduling + compute only
    warm_rid = engine.submit(prompts[0], gen_len)
    engine.step()
    engine.retire(warm_rid)

    arrival_of: dict[int, float] = {}
    latencies: list[float] = []
    submitted = n_done = 0
    t0 = time.perf_counter()
    while n_done < n_requests:
        now = time.perf_counter() - t0
        while submitted < n_requests and arrivals[submitted] <= now:
            rid = engine.submit(prompts[submitted], gen_len)
            arrival_of[rid] = arrivals[submitted]
            submitted += 1
        busy = any(r.active for r in engine._runners.values())
        if not engine._pending and not busy:
            if submitted < n_requests:      # idle until the next arrival
                time.sleep(max(arrivals[submitted] - now, 0.0))
            continue
        engine.step(max_chunks=1)
        now = time.perf_counter() - t0
        for res in engine.retire():
            latencies.append(now - arrival_of[res.id])
            n_done += 1
    makespan = time.perf_counter() - t0
    p50, p99 = (float(x) for x in np.percentile(latencies, [50, 99]))
    trace_tok_s = n_requests * req_batch * gen_len / makespan
    rows.append((f"serve_paths/scheduler/poisson_r{rate:g}", p50 * 1e3,
                 f"p99_ms={p99 * 1e3:.1f};tok_s={trace_tok_s:.1f};"
                 f"n={n_requests}"))

    # -- padded vs exact at a full bucket -----------------------------------
    bucket = engine.plan_key(req_batch).batch_bucket
    n_fill = bucket // req_batch
    fill_prompts = [rng.integers(0, cfg.vocab_size,
                                 (req_batch, PROMPT_LEN)).astype(np.int32)
                    for _ in range(n_fill)]
    big_prompt = np.concatenate(fill_prompts, axis=0)

    def padded_pass():
        rids = [engine.submit(p, gen_len) for p in fill_prompts]
        engine.step()
        res = [engine.retire(r)[0] for r in rids]
        return sum(r.tok_s for r in res)    # same dispatches: tok_s sums

    legacy = ServingEngine(cfg, params, masks, reg, path="masked",
                           paged=False)

    def exact_pass():
        rid = legacy.submit(big_prompt, gen_len)
        legacy.step()
        [res] = legacy.retire(rid)
        return res.tok_s

    for _ in range(max(WARMUP, 1)):
        padded_pass(), exact_pass()
    padded = statistics.median([padded_pass() for _ in range(max(reps, 1))])
    exact = statistics.median([exact_pass() for _ in range(max(reps, 1))])
    ratio = padded / exact
    rows.append((f"serve_paths/scheduler/padded_vs_exact_b{bucket}",
                 1e6 / padded,
                 f"padded_tok_s={padded:.1f};exact_tok_s={exact:.1f};"
                 f"ratio={ratio:.3f}"))

    if results is not None:
        results.append({
            "arch": arch, "path": "scheduler", "kind": "poisson_trace",
            "rate_per_s": rate, "n_requests": n_requests,
            "req_batch": req_batch, "gen_len": gen_len,
            "gen_chunk": gen_chunk, "plan_key_bucket": bucket,
            "p50_latency_ms": round(p50 * 1e3, 2),
            "p99_latency_ms": round(p99 * 1e3, 2),
            "tok_s": round(trace_tok_s, 2),
            "makespan_s": round(makespan, 3),
        })
        results.append({
            "arch": arch, "path": "scheduler", "kind": "padded_vs_exact",
            "plan_key_bucket": bucket, "req_batch": req_batch,
            "gen_len": gen_len,
            "padded_tok_s": round(padded, 2),
            "exact_tok_s": round(exact, 2),
            "padded_vs_exact": round(ratio, 4),
        })
    return rows


def run_sync(arch: str = "qwen3-1.7b", *, req_batch: int = 2,
             gen_len: int = 32, gen_chunk: int = 4, seed: int = 0,
             results: list | None = None):
    """The live train->serve sync stream's price (repro.sync, schema v7).

    Publishes a snapshot + one values-only + one topology delta over the
    FILE channel and records their wire sizes, then measures per-chunk
    decode latency on a subscribed engine twice: an undisturbed baseline
    run, and a run where a topology delta lands mid-stream (published after
    the second chunk, drained + donation-adopted at the next boundary). The
    p99 delta between the two runs is the mid-stream update's cost.
    """
    import tempfile

    from repro.sync import DirChannel, Publisher, Subscriber, \
        engine_from_snapshot

    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(seed)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    versions = {s.name: 0 for s in reg}
    rng = np.random.default_rng(seed)
    rows = []

    def evolve(params, masks, versions, *, rewire):
        params = jax.tree.map(
            lambda x: x * 1.001 if jnp.issubdtype(x.dtype, jnp.floating)
            else x, params)
        if rewire:
            s = reg[0]
            masks = jax.tree.map(lambda x: x, masks)
            REG.set_path(masks, s.path,
                         jnp.roll(REG.get_path(masks, s.path), 1, axis=-2))
            versions = dict(versions)
            versions[s.name] += 1
        return params, masks, versions

    with tempfile.TemporaryDirectory(prefix="repro-sync-bench-") as tmp:
        pub = Publisher(cfg, reg, DirChannel(tmp), path="condensed",
                        batch_size=req_batch, arch=arch)
        snap = pub.publish(params=params, masks=masks,
                           mask_versions=versions)
        params, masks, versions = evolve(params, masks, versions,
                                         rewire=False)
        vals = pub.publish(params=params, masks=masks,
                           mask_versions=versions)
        params, masks, versions = evolve(params, masks, versions,
                                         rewire=True)
        topo = pub.publish(params=params, masks=masks,
                           mask_versions=versions)

        sub = Subscriber(DirChannel(tmp).subscribe("bench"), name="bench")
        sub.wait_for_bootstrap(timeout=10.0)
        engine = engine_from_snapshot(cfg, sub, registry=reg,
                                      gen_chunk=gen_chunk)
        prompts = rng.integers(0, cfg.vocab_size,
                               (req_batch, PROMPT_LEN)).astype(np.int32)

        def chunk_latencies(publish_mid: bool):
            nonlocal params, masks, versions
            rid = engine.submit(prompts, gen_len)
            lats, chunks = [], 0
            while True:
                # after the first step (prefill + chunk 1): even the smoke
                # grid's short generations get a genuine mid-stream update
                if publish_mid and chunks == 1:
                    params, masks, versions = evolve(
                        params, masks, versions, rewire=True)
                    pub.publish(params=params, masks=masks,
                                mask_versions=versions)
                t0 = time.perf_counter()
                engine.step(max_chunks=1)
                lats.append(time.perf_counter() - t0)
                chunks += 1
                if engine.retire(rid):
                    break
            return lats[1:]          # drop the prefill+first-chunk step

        chunk_latencies(False)       # warm every program signature
        base = chunk_latencies(False)
        mid = chunk_latencies(True)

    b50, b99 = (float(x) for x in np.percentile(base, [50, 99]))
    m50, m99 = (float(x) for x in np.percentile(mid, [50, 99]))
    ratio = topo["bytes"] / max(snap["bytes"], 1)
    rows.append(("serve_paths/sync/delta_vs_snapshot", ratio * 100,
                 f"snapshot_B={snap['bytes']};values_delta_B={vals['bytes']};"
                 f"topology_delta_B={topo['bytes']};"
                 f"midstream_p99_ms={m99 * 1e3:.1f};"
                 f"baseline_p99_ms={b99 * 1e3:.1f}"))
    if results is not None:
        results.append({
            "arch": arch, "path": "condensed", "kind": "sync",
            "req_batch": req_batch, "gen_len": gen_len,
            "gen_chunk": gen_chunk,
            "snapshot_bytes": snap["bytes"],
            "values_delta_bytes": vals["bytes"],
            "topology_delta_bytes": topo["bytes"],
            "delta_vs_snapshot": round(ratio, 4),
            "values_delta_vs_snapshot": round(
                vals["bytes"] / max(snap["bytes"], 1), 4),
            "chunk_p50_ms_baseline": round(b50 * 1e3, 3),
            "chunk_p99_ms_baseline": round(b99 * 1e3, 3),
            "chunk_p50_ms_midstream_update": round(m50 * 1e3, 3),
            "chunk_p99_ms_midstream_update": round(m99 * 1e3, 3),
            "final_generation": pub.generation,
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batches", default=",".join(map(str, BATCHES)))
    ap.add_argument("--warmup", type=int, default=WARMUP,
                    help="un-timed passes per (path, batch) before timing")
    ap.add_argument("--reps", type=int, default=REPS,
                    help="timed passes per (path, batch); median reported")
    ap.add_argument("--profile", choices=("default", "measured"),
                    default="default",
                    help="hardware profile pricing the auto plan: 'measured' "
                         "calibrates on this machine (HardwareProfile.measure)")
    ap.add_argument("--ablations", default=",".join(map(str, ABLATIONS)),
                    help="comma-separated ablated-neuron fractions; each "
                         "re-runs the path x batch grid (0.5 exercises the "
                         "gathered structured and fused COA kernels)")
    ap.add_argument("--trace-requests", type=int, default=24,
                    help="Poisson-trace length for the scheduler SLA rows")
    ap.add_argument("--trace-rate", type=float, default=4.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--tp", type=int, default=4,
                    help="shard count for the predicted TP-vs-replicated "
                         "crossover rows (cost-model only, no mesh needed)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small grid, one rep, short trace "
                         "(same artifact contract as the full run)")
    ap.add_argument("--out", default="BENCH_serve_paths.json",
                    help="machine-readable results (perf trajectory across PRs)")
    args = ap.parse_args(argv)
    batches = tuple(int(b) for b in args.batches.split(","))
    ablations = tuple(float(a) for a in args.ablations.split(","))
    trace_n, gen_len = args.trace_requests, 16
    if args.smoke:
        batches = tuple(b for b in batches if b <= 32) or (1,)
        ablations = (0.0,)
        args.warmup, args.reps = 1, 1
        trace_n, gen_len = 8, 8
    profile = (PLAN.HardwareProfile.measure()
               if args.profile == "measured" else PLAN.DEFAULT_PROFILE)

    results: list = []
    rows = run(batches=batches, arch=args.arch, results=results,
               profile=profile, warmup=args.warmup, reps=args.reps,
               ablations=ablations)
    rows += run_scheduler(arch=args.arch, n_requests=trace_n,
                          rate=args.trace_rate, gen_len=gen_len,
                          reps=args.reps, results=results)
    rows += run_speculative(arch=args.arch, gen_len=gen_len,
                            warmup=args.warmup, reps=args.reps,
                            draft_ablations=(SPEC_ABLATIONS[:2] if args.smoke
                                             else SPEC_ABLATIONS),
                            results=results)
    rows += run_tp_crossover(arch=args.arch, tp=args.tp, profile=profile,
                             results=results)
    rows += run_sync(arch=args.arch, gen_len=gen_len, results=results)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        payload = {
            "benchmark": "serve_paths",
            "schema_version": SCHEMA_VERSION,
            "arch": args.arch,
            "prompt_len": PROMPT_LEN,
            "gen_len": GEN_LEN,
            "warmup": args.warmup,
            "reps": args.reps,
            "ablations": list(ablations),
            "profile": profile.name,
            "backend": jax.default_backend(),
            "pallas_interpret_note": "condensed timings are interpret-mode on "
                                     "CPU; weight_bytes_ratio is the "
                                     "hardware-transferable quantity",
            "rows": results,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"[serve_paths] wrote {args.out} ({len(results)} rows)")


if __name__ == "__main__":
    main()
