"""Paper Fig. 4 / App. I: wall-clock timings of the condensed representation.

Compares, on the paper's own benchmark layer (ViT-B/16 final MLP linear,
3072 -> 768) at several sparsities:

  dense        x @ W                       (jit, XLA CPU)
  unstructured x @ (mask * W)  masked-dense (the CSR stand-in available in XLA)
  structured   ablated-neuron column drop (Fig. 4 'structured')
  condensed    Pallas constant fan-in kernel (interpret mode on CPU)

interpret-mode Pallas timings are NOT meaningful wall-clock — on this CPU
container the kernel runs as a python interpreter loop. We therefore ALSO
report the analytic byte ratio (weight bytes touched vs dense), which is the
quantity that transfers to the TPU target (decode is bandwidth-bound).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import topology
from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6  # median us


def run(batch: int = 1):
    d_in, n_out = 3072, 768  # the paper's ViT-B/16 benchmark layer
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (batch, d_in))
    w_dense = jax.random.normal(jax.random.fold_in(key, 1), (d_in, n_out))

    dense_fn = jax.jit(lambda x, w: x @ w)
    t_dense = _time(dense_fn, x, w_dense)
    rows = [(f"condensed/dense/b{batch}", t_dense, "bytes_ratio=1.00")]

    for s in (0.8, 0.9, 0.95, 0.99):
        k = max(1, round((1 - s) * d_in))
        mask = topology.random_constant_fan_in_mask(
            jax.random.fold_in(key, 2), d_in, n_out, k)
        w = w_dense * mask
        vals, idx = topology.dense_to_condensed(w, mask, k)
        # ~30% of neurons ablated at high sparsity (paper Fig. 3b shape)
        active = (jnp.arange(n_out) % 10) < (7 if s >= 0.95 else 9)

        masked_fn = jax.jit(lambda x, w, m: x @ (w * m))
        t_unstruct = _time(masked_fn, x, w_dense, mask)
        struct_fn = jax.jit(ops.structured_dense)
        t_struct = _time(struct_fn, x, w, active)
        cond_fn = jax.jit(lambda x, v, i: ref.condensed_matmul_ref(x, v, i))
        t_cond_ref = _time(cond_fn, x, vals, idx)

        dense_bytes = d_in * n_out * 4
        cond_bytes = n_out * k * (4 + 4)  # values + indices
        rows += [
            (f"condensed/unstructured@{int(s*100)}/b{batch}", t_unstruct,
             f"bytes_ratio={1.0 + 0.25:.2f}"),  # mask bytes on top of dense
            (f"condensed/structured@{int(s*100)}/b{batch}", t_struct,
             f"bytes_ratio={float(jnp.mean(active)):.2f}"),
            (f"condensed/condensed@{int(s*100)}/b{batch}", t_cond_ref,
             f"bytes_ratio={cond_bytes/dense_bytes:.3f} "
             f"speedup_vs_dense={t_dense/t_cond_ref:.2f}x"),
        ]
    return rows
