"""Paper Fig. 8 / App. E: sensitivity of SRigL to the gamma_sal threshold."""
import time



def run(steps: int = 60):
    rows = []
    for gamma in (0.0, 0.3, 0.9):
        t0 = time.perf_counter()
        import dataclasses
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.core.schedule import DSTSchedule
        from repro.data.pipeline import SyntheticLM
        from repro.sparse import registry as REG
        from repro.train.state import init_train_state
        from repro.train.trainer import make_dst_step, make_train_step

        cfg = configs.get_smoke_config("qwen3-1.7b")
        cfg = cfg.replace(sparsity=dataclasses.replace(
            cfg.sparsity, method="srigl", sparsity=0.9, delta_t=10,
            gamma_sal=gamma))
        reg = REG.build_registry(cfg)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
        dst = jax.jit(make_dst_step(cfg, reg))
        sched = DSTSchedule(delta_t=10)
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, batch_size=8, seed=1)
        losses = []
        for i in range(steps):
            b = jax.tree.map(jnp.asarray, data.batch(i))
            state, m = step(state, b)
            if bool(sched.is_update_step(i + 1)):
                state = dst(state, b)
            losses.append(float(m["loss"]))
        frac = min(float(jnp.mean(a.astype(jnp.float32)))
                   for a in jax.tree.leaves(state.neuron_active))
        rows.append((f"gamma_sweep/gamma{gamma}", (time.perf_counter() - t0) * 1e6,
                     f"final_loss={sum(losses[-10:])/10:.4f} min_active_frac={frac:.3f}"))
    return rows
