"""Paper Fig. 3b: fraction of active neurons after DST training vs sparsity.

RigL (unstructured) implicitly ablates neurons at high sparsity; SRigL makes
the same structure explicit via gamma_sal. Both effects must show up.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.schedule import DSTSchedule
from repro.data.pipeline import SyntheticLM
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def active_fraction(method: str, sparsity: float, gamma: float = 0.5,
                    steps: int = 40) -> float:
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(d_ff=256, sparsity=dataclasses.replace(
        cfg.sparsity, method=method, sparsity=sparsity, delta_t=5,
        gamma_sal=gamma))
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg))
    sched = DSTSchedule(delta_t=5)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8, seed=0)
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        state, _ = step(state, b)
        if bool(sched.is_update_step(i + 1)):
            state = dst(state, b)
    if method == "srigl":
        fracs = [float(jnp.mean(a.astype(jnp.float32)))
                 for a in jax.tree.leaves(state.neuron_active)]
    else:  # implicit ablation: neurons whose column is all-zero
        fracs = []
        for s in reg:
            m = np.array(REG.get_path(state.masks, s.path))
            m2 = m.reshape(-1, *m.shape[-2:])
            fracs.append(float((m2.sum(1) > 0).mean()))
    return float(np.mean(fracs))


def run(steps: int = 40):
    rows = []
    for s in (0.9, 0.97):
        for method in ("rigl", "srigl"):
            t0 = time.perf_counter()
            frac = active_fraction(method, s, steps=steps)
            rows.append((f"ablation/{method}@{int(s*100)}",
                         (time.perf_counter() - t0) * 1e6,
                         f"active_neuron_frac={frac:.3f}"))
    return rows
