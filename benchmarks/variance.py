"""Paper Fig. 1b: output-norm variance — theory vs simulation, 3 ensembles."""
import time

import jax

from repro.core import theory


def run(n: int = 64, n_samples: int = 2000):
    rows = []
    for k in (2, 4, 8, 16, 32):
        for kind, fn in [("bernoulli", theory.var_bernoulli),
                         ("const_per_layer", theory.var_const_per_layer),
                         ("const_fan_in", theory.var_const_fan_in)]:
            t0 = time.perf_counter()
            sim = theory.simulate_output_norm_var(
                jax.random.PRNGKey(k), n, k, kind, n_samples)
            dt = (time.perf_counter() - t0) * 1e6
            th = fn(n, k)
            rows.append((f"variance/{kind}/k{k}", dt,
                         f"theory={th:.4f} sim={sim:.4f} err={abs(sim-th)/th:.3f}"))
    # the paper's claim: constant fan-in strictly smallest at every k
    ok = all(theory.var_const_fan_in(n, k) < theory.var_bernoulli(n, k)
             for k in (2, 4, 8, 16, 32))
    rows.append(("variance/const_fan_in_smallest", 0.0, f"claim_holds={ok}"))
    return rows
