"""Paper Tables 1-3 proxy: generalization of SRigL vs baselines on a small LM.

The paper's accuracy claims (CIFAR/ImageNet-scale) are reproduced in *shape*:
at matched sparsity, final loss ordering should be

    dense <= srigl(w/ ablation) ~ rigl  <  srigl(w/o ablation at 99%)  <  set

and SRigL-with-ablation must close the gap to RigL at very high sparsity
(Table 2's 99% row), which is the paper's central empirical claim.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.schedule import DSTSchedule
from repro.data.pipeline import SyntheticLM
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_dst_step, make_train_step


def train_one(method: str, sparsity: float, ablation: bool = True,
              steps: int = 80, seed: int = 0) -> float:
    cfg = configs.get_smoke_config("qwen3-1.7b")
    cfg = cfg.replace(sparsity=dataclasses.replace(
        cfg.sparsity, method=method, sparsity=sparsity, ablation=ablation,
        delta_t=10, gamma_sal=0.3))
    reg = REG.build_registry(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, reg, lambda s: jnp.float32(3e-3)))
    dst = jax.jit(make_dst_step(cfg, reg)) if reg else None
    sched = DSTSchedule(delta_t=10, total_steps=getattr(cfg, "total_steps", 100_000))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, batch_size=8, seed=1)
    last = []
    for i in range(steps):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        state, m = step(state, b)
        if dst is not None and bool(sched.is_update_step(i + 1)):
            state = dst(state, b)
        last.append(float(m["loss"]))
    return sum(last[-10:]) / 10


def run(steps: int = 80):
    rows = []
    t0 = time.perf_counter()
    dense = train_one("dense", 0.0, steps=steps)
    rows.append(("accuracy/dense", (time.perf_counter() - t0) * 1e6,
                 f"final_loss={dense:.4f}"))
    for s in (0.8, 0.95):
        results = {}
        for label, method, abl in [("srigl", "srigl", True),
                                   ("srigl_noabl", "srigl", False),
                                   ("rigl", "rigl", True),
                                   ("set", "set", True)]:
            t0 = time.perf_counter()
            loss = train_one(method, s, ablation=abl, steps=steps)
            results[label] = loss
            rows.append((f"accuracy/{label}@{int(s*100)}",
                         (time.perf_counter() - t0) * 1e6,
                         f"final_loss={loss:.4f}"))
        # paper-shape checks
        gap = results["srigl"] - results["rigl"]
        rows.append((f"accuracy/srigl_vs_rigl@{int(s*100)}", 0.0,
                     f"loss_gap={gap:+.4f} (claim: ~0)"))
    return rows
