"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  variance        — Fig. 1b  output-norm variance theory vs simulation
  flops_table     — Table 5  sparse vs dense training/inference FLOPs
  condensed_bench — Fig. 4   condensed vs dense/unstructured/structured layer
  ablation_bench  — Fig. 3b  active-neuron fraction, RigL vs SRigL
  serve_paths     — Fig. 6/7 masked vs condensed vs structured decode tok/s
  kernel_autotune — tuned-vs-default kernel blocks + calibrated crossover
  accuracy        — Tables 1-3 proxy: method ordering on a small LM
  gamma_sweep     — Fig. 8   gamma_sal sensitivity
  roofline        — §Roofline aggregation of dry-run results (if present)

Besides the CSV, the harness writes a combined ``BENCH_summary.json``
(``--out``; empty string disables): ONE row per suite with its status,
row count, headline metric (the first CSV row — each suite leads with its
signature number) and the suite module's own ``SCHEMA_VERSION`` where it
defines one — so the cross-PR perf trajectory is machine-readable from a
single artifact instead of scattered across per-suite files.

Use --quick to cut the training-based benchmarks' budgets; --only <name>.
"""
import argparse
import importlib
import json
import sys
import traceback

SUMMARY_SCHEMA_VERSION = 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_summary.json",
                    help="combined machine-readable summary (one row per "
                         "suite); empty string disables")
    args = ap.parse_args(argv)

    steps = 30 if args.quick else 80
    # (suite name, entry module, runner taking the imported module) — modules
    # import lazily per suite so one broken import SKIPS that suite (with a
    # note) instead of aborting the whole run
    suites = [
        ("variance", "variance",
         lambda m: m.run(n_samples=500 if args.quick else 2000)),
        ("flops_table", "flops_table", lambda m: m.run()),
        ("condensed_bench", "condensed_bench",
         lambda m: m.run(batch=1) + m.run(batch=256)),
        ("serve_paths", "serve_paths",
         lambda m: m.run(batches=(1, 32) if args.quick else (1, 32, 256))),
        ("kernel_autotune", "kernel_autotune", lambda m: m.run(smoke=True)),
        ("ablation_bench", "ablation_bench",
         lambda m: m.run(steps=min(steps, 40))),
        ("accuracy", "accuracy", lambda m: m.run(steps=steps)),
        ("gamma_sweep", "gamma_sweep",
         lambda m: m.run(steps=min(steps, 60))),
        ("roofline", "roofline", lambda m: m.run()),
    ]

    print("name,us_per_call,derived")
    failures = 0
    skipped = []
    summary_rows = []
    for name, module, fn in suites:
        if args.only and args.only != name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{module}")
        except Exception as e:  # noqa: BLE001 — skip the suite, keep the run
            skipped.append(name)
            print(f"{name},0.0,SKIPPED(import failed: "
                  f"{type(e).__name__}: {str(e)[:120]})")
            summary_rows.append({"suite": name, "status": "skipped",
                                 "n_rows": 0, "schema_version": None,
                                 "headline": None,
                                 "note": f"import failed: {type(e).__name__}"})
            continue
        schema = getattr(mod, "SCHEMA_VERSION", None)
        try:
            rows = list(fn(mod))
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
            head = rows[0] if rows else None
            summary_rows.append({
                "suite": name, "status": "ok", "n_rows": len(rows),
                "schema_version": schema,
                # each suite leads with its signature metric — the headline
                # is that first CSV row, verbatim
                "headline": ({"name": head[0], "us_per_call": round(head[1], 3),
                              "derived": head[2]} if head else None),
            })
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
            summary_rows.append({"suite": name, "status": "failed",
                                 "n_rows": 0, "schema_version": schema,
                                 "headline": None})
    if skipped:
        print(f"# skipped (import failures, not counted as suite failures): "
              f"{', '.join(skipped)}")
    if args.out:
        payload = {"benchmark": "summary",
                   "schema_version": SUMMARY_SCHEMA_VERSION,
                   "quick": bool(args.quick),
                   "only": args.only or None,
                   "suites": summary_rows}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {args.out} ({len(summary_rows)} suite rows)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
