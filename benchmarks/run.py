"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  variance        — Fig. 1b  output-norm variance theory vs simulation
  flops_table     — Table 5  sparse vs dense training/inference FLOPs
  condensed_bench — Fig. 4   condensed vs dense/unstructured/structured layer
  ablation_bench  — Fig. 3b  active-neuron fraction, RigL vs SRigL
  serve_paths     — Fig. 6/7 masked vs condensed vs structured decode tok/s
  kernel_autotune — tuned-vs-default kernel blocks + calibrated crossover
  accuracy        — Tables 1-3 proxy: method ordering on a small LM
  gamma_sweep     — Fig. 8   gamma_sal sensitivity
  roofline        — §Roofline aggregation of dry-run results (if present)

Use --quick to cut the training-based benchmarks' budgets; --only <name>.
"""
import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks import (accuracy, ablation_bench, condensed_bench,
                            flops_table, gamma_sweep, kernel_autotune,
                            roofline, serve_paths, variance)

    steps = 30 if args.quick else 80
    suites = [
        ("variance", lambda: variance.run(n_samples=500 if args.quick else 2000)),
        ("flops_table", flops_table.run),
        ("condensed_bench", lambda: condensed_bench.run(batch=1)
                                    + condensed_bench.run(batch=256)),
        ("serve_paths", lambda: serve_paths.run(
            batches=(1, 32) if args.quick else (1, 32, 256))),
        ("kernel_autotune", lambda: kernel_autotune.run(smoke=True)),
        ("ablation_bench", lambda: ablation_bench.run(steps=min(steps, 40))),
        ("accuracy", lambda: accuracy.run(steps=steps)),
        ("gamma_sweep", lambda: gamma_sweep.run(steps=min(steps, 60))),
        ("roofline", roofline.run),
    ]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
