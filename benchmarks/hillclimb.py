"""§Perf per-pair hillclimb driver.

Selected pairs (from the baseline roofline table):
  1. qwen3-1.7b  x decode_32k — most representative of the paper (serving
     with sparse weights): masked-dense vs condensed representation, and the
     batch-size crossover the paper's Fig. 4 predicts.
  2. mamba2-130m x prefill_32k — worst compute/roofline fraction.
  3. mistral-large-123b x train_4k — most collective-bound cell.

Each entry re-measures under the v2 HLO meter (dus-rooted fusion fix) so
before/after are comparable. Run:  python -m benchmarks.hillclimb [--pair N]
"""
import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", type=int, default=0, help="0 = all")
    ap.add_argument("--out", default="results_hillclimb.jsonl")
    args = ap.parse_args(argv)

    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.launch import dryrun as DR

    # custom online-ish decode shape for the pair-1 crossover experiment
    configs.SHAPES["decode_32k_b16"] = ShapeConfig("decode_32k_b16", 32_768, 16,
                                                   "decode")

    runs = []
    if args.pair in (0, 1):
        runs += [
            ("p1.base", "qwen3-1.7b", "decode_32k", "serve", {}),
            ("p1.condensed", "qwen3-1.7b", "decode_32k", "serve_cond", {}),
            ("p1.b16.base", "qwen3-1.7b", "decode_32k_b16", "serve", {}),
            ("p1.b16.condensed", "qwen3-1.7b", "decode_32k_b16", "serve_cond", {}),
        ]
    if args.pair in (0, 2):
        runs += [
            ("p2.base", "mamba2-130m", "prefill_32k", "serve", {}),
            ("p2.chunk512", "mamba2-130m", "prefill_32k", "serve",
             {"ssd_chunk": 512}),
            ("p2.chunk1024", "mamba2-130m", "prefill_32k", "serve",
             {"ssd_chunk": 1024}),
        ]
    if args.pair in (0, 3):
        runs += [
            ("p3.base", "mistral-large-123b", "train_4k", "train", {}),
            ("p3.bigchunks", "mistral-large-123b", "train_4k", "train",
             {"ce_chunk": 2048, "attn_q_chunk": 2048, "attn_kv_chunk": 2048}),
        ]

    for label, arch, shape, prog, over in runs:
        cfg = configs.get_config(arch)
        if over:
            cfg = cfg.replace(**over)
        try:
            r = DR.run_cell(arch, shape, False, program=prog, cfg=cfg)
            r["label"] = label
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")
            t = r["roofline"]
            print(f"[hillclimb] {label}: comp={t['compute_s']*1e3:.1f}ms "
                  f"mem={t['memory_s']*1e3:.1f}ms coll={t['collective_s']*1e3:.1f}ms "
                  f"peak={r['peak_bytes']/2**30:.1f}GB", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"[hillclimb] {label} FAILED: {e}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
