"""§Roofline aggregation: dry-run JSONL -> per-cell roofline table (markdown).

MODEL_FLOPS definitions (per device, per step):
  train  : 6 * N_active * tokens / chips   (8 * N_active with block remat —
           we report the 6N number as "useful" per the assignment)
  prefill: 2 * N_active * tokens / chips
  decode : 2 * N_active * batch  / chips
MoE archs use N_active = attention + top-k expert params actually routed.
"""
from __future__ import annotations

import json
import os

from repro import configs


def param_counts(cfg):
    """(total, active-per-token) parameter counts, embedding excluded."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    attn = d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
    if cfg.family == "ssm":
        blk = 2 * d * cfg.d_inner + d * (2 * cfg.ssm_state + cfg.ssm_n_heads) \
            + cfg.d_inner * d
        total = active = L * blk
    elif cfg.family == "hybrid":
        ssm_blk = 2 * d * cfg.d_inner + d * (2 * cfg.ssm_state + cfg.ssm_n_heads) \
            + cfg.d_inner * d
        shared = attn + 3 * d * ff
        total = active = L * ssm_blk + shared * (L // cfg.hybrid_attn_every)
    elif cfg.is_moe:
        expert = 3 * d * ff
        total = L * (attn + cfg.n_experts * expert + d * cfg.n_experts)
        active = L * (attn + cfg.top_k_experts * expert)
    else:
        total = active = L * (attn + 3 * d * ff)
    return total, active


def model_flops(cfg, shape, chips: int) -> float:
    total, active = param_counts(cfg)
    # sparse layers carry (1 - sparsity) of their weights; QKV stays dense.
    density = 1.0 - cfg.sparsity.sparsity if cfg.sparsity.method != "dense" else 1.0
    # approximate: non-QKV block params are sparse (paper recipe)
    sparse_frac = 0.75
    eff = active * (sparse_frac * density + (1 - sparse_frac))
    if shape.kind == "train":
        return 6.0 * eff * shape.tokens / chips
    if shape.kind == "prefill":
        return 2.0 * eff * shape.tokens / chips
    return 2.0 * eff * shape.global_batch / chips  # decode: 1 new token/stream


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | prog | compute | memory | collective | dominant | peak GB | MODEL/HLO |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"], r.get("program", ""))):
        cfg = configs.get_config(r["arch"])
        shape = configs.SHAPES[r["shape"]]
        mf = model_flops(cfg, shape, r["chips"])
        ratio = mf / r["flops_per_device"] if r["flops_per_device"] else 0.0
        t = r["roofline"]
        out.append(
            "| {arch} | {shape} | {mesh} | {prog} | {c:.1f} ms | {m:.1f} ms | {k:.1f} ms "
            "| {dom} | {pk:.1f} | {ratio:.2f} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                prog=r.get("program", "auto"),
                c=t["compute_s"] * 1e3, m=t["memory_s"] * 1e3,
                k=t["collective_s"] * 1e3,
                dom=r["dominant"].replace("_s", ""),
                pk=r["peak_bytes"] / 2**30, ratio=ratio))
    return "\n".join(out)


def run(path: str = "results_singlepod.jsonl"):
    if not os.path.exists(path):
        return [("roofline/missing", 0.0, f"no {path}; run launch.dryrun first")]
    rows = load(path)
    md = to_markdown(rows)
    out_path = os.path.splitext(path)[0] + "_roofline.md"
    with open(out_path, "w") as f:
        f.write(md + "\n")
    worst = min(
        (r for r in rows if r.get("program") in ("auto", None)),
        key=lambda r: (r["roofline"]["compute_s"]
                       / max(sum(r["roofline"].values()), 1e-12)))
    return [("roofline/cells", 0.0, f"n={len(rows)} table={out_path}"),
            ("roofline/worst_fraction", 0.0,
             f"{worst['arch']}x{worst['shape']} dominant={worst['dominant']}")]
