"""Kernel autotuning + hardware calibration: default-vs-tuned blocks and
predicted-vs-measured auto-crossover, on THIS machine.

Two claims under benchmark, both feeding ``BENCH_kernel_autotune.json``:

1. **Tuned blocks never lose to the 128x128 default.** For each decode-ish
   shape, ``repro.sparse.autotune`` times every VMEM-budget candidate block
   shape (plus the decode-specialized variant and the legacy 128x128
   baseline) and reports the winner. The winner is the argmin of the SAME
   measured table the default sits in, so ``speedup_vs_default >= 1.0`` is
   the no-regression contract, and anything above it is real tuning win.
   On CPU the kernel runs in Pallas interpret mode — those timings are
   labeled (``pallas_interpret``) and do not transfer to TPU/GPU, but the
   RANKING of block shapes on the interpreter tracks the padding/tiling
   work each shape does.

2. **The calibrated cost model predicts the serving crossover.** The
   ``--path auto`` plan picks masked vs condensed per stack from a roofline
   over ``HardwareProfile`` rates. ``HardwareProfile.measure()`` replaces
   the v5e-ish constants with rates microbenchmarked here (HBM stream,
   dense matmul, gather-MAC in its XLA formulation — the same primitive the
   CPU serving path executes). The benchmark then times the two paths
   directly over a batch sweep and checks the measured crossover batch
   lands in the same ``autotune.BATCH_BUCKETS`` bucket as the calibrated
   prediction — the end-to-end validation that plan decisions on this
   machine are driven by this machine.

Usage:
  PYTHONPATH=src:. python benchmarks/kernel_autotune.py [--smoke] \
      [--out BENCH_kernel_autotune.json]
"""
from __future__ import annotations

import argparse
import json
import types

# payload schema, picked up by benchmarks/run.py for the combined summary.
# v1: first stamped version (tuned rows + crossover rows + profiles); the
# unstamped payloads that predate it surface as schema_version null.
SCHEMA_VERSION = 1

import jax
import jax.numpy as jnp

from repro.core import topology
from repro.kernels import condensed_matmul as cm
from repro.kernels import ref
from repro.sparse import autotune as AT
from repro.sparse import plan as PLAN

# (name, d_in, n_out, k): the paper's ViT-B/16 benchmark layer at 90% / 95%
# sparsity, plus a transformer MLP-ish decode shape.
FULL_SHAPES = [
    ("vit_b16_mlp@90", 3072, 768, 307),
    ("vit_b16_mlp@95", 3072, 768, 154),
    ("mlp_4k@90", 4096, 1024, 410),
]
# smoke-config-sized stacks (qwen3-1.7b --smoke w_gate / w_down at ~90%)
SMOKE_SHAPES = [
    ("smoke_w_gate", 64, 128, 13),
    ("smoke_w_down", 128, 64, 26),
]

# quantized decode smoke: ONE int8 shape tuned under the wint8 key space
# (the dequant-fused kernel streams 1-byte values + a per-neuron f32 scale
# row; its block rankings are tuned and cached separately from the float
# keys). Same tuned>=default no-regression contract as the float rows —
# these rows join the exit-code check.
SMOKE_QUANT_SHAPES = [
    ("smoke_w_gate@int8", 64, 128, 13),
]
FULL_QUANT_SHAPES = [
    ("vit_b16_mlp@90int8", 3072, 768, 307),
]

# Structured (column-gathered) kernel shapes: (name, d_in, a_pad, d_out) —
# the ablation-only Fig. 4 point, a_pad = lane-padded surviving columns.
# Same tuned>=default contract as the condensed shapes, under the
# kind="structured" tuning keys.
FULL_STRUCT_SHAPES = [
    ("vit_b16_mlp@abl50", 3072, 384, 768),
    ("mlp_4k@abl75", 4096, 256, 1024),
]
SMOKE_STRUCT_SHAPES = [
    ("smoke_struct_gate", 64, 128, 256),
]

# Crossover-validation shapes must sit in the ROOFLINE regime the cost model
# describes: big enough that per-dispatch overhead is negligible against the
# byte/FLOP terms. The smoke-config stack shapes (64x128) are NOT — a tiny
# matmul is dispatch-bound and the model would be validated against noise —
# so smoke mode uses a smaller-but-still-roofline MLP shape instead. The
# crossover suite sticks to the ~90%-sparsity family: its crossover lands
# mid-bucket on the reference container, whereas the 95%-sparsity point's
# crossover sits right on a bucket edge (pred/meas straddle it under
# ordinary timing jitter), so vit@95 is block-TUNED above but not used as a
# crossover probe.
FULL_CROSSOVER_SHAPES = [
    ("vit_b16_mlp@90", 3072, 768, 307),
    ("mlp_2k@90", 2048, 768, 205),
    ("mlp_4k@90", 4096, 1024, 410),
]
SMOKE_CROSSOVER_SHAPES = [
    ("mlp_1k@90", 1024, 512, 102),
]

DECODE_BATCHES = (1, 8)

# batch sweep for the measured crossover (geometric, ~sqrt(2) steps so the
# measured crossover is located to well under one BATCH_BUCKETS bucket)
SWEEP = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256,
         384, 512, 768, 1024, 1536, 2048)


_time_us = AT._time_us  # best-of-reps (noise-robust on shared hosts)


def _tune_row(name, b, res, **geometry) -> dict:
    return {
        "shape": name, "batch": b, "bucket": AT.batch_bucket(b), **geometry,
        "default_us": round(res.default_us, 2),
        "tuned_us": round(res.us, 2),
        "tuned_block_b": res.block_b,   # null -> decode variant
        "tuned_block_n": res.block_n,
        "speedup_vs_default": round(res.speedup_vs_default, 3),
        "interpret": res.interpret,
        "table_us": {kk: round(v, 2) for kk, v in res.table.items()},
    }


def tune_rows(shapes, batches, reps: int) -> list[dict]:
    rows = []
    for name, d_in, n_out, k in shapes:
        for b in batches:
            res = AT.autotune_blocks(b, d_in, n_out, k, reps=reps)
            rows.append(_tune_row(name, b, res, kind="condensed", d_in=d_in,
                                  n_out=n_out, k=k))
    return rows


def quantized_tune_rows(shapes, batches, reps: int,
                        values_dtype: str = "int8") -> list[dict]:
    """Tuned-vs-default rows for the dequant-fused condensed kernel: the
    tuner quantizes its synthetic operands and times every candidate with
    the fused scale epilogue, persisting winners under the ``w<dtype>``
    tuning keys the serving engine looks up at trace time."""
    rows = []
    for name, d_in, n_out, k in shapes:
        for b in batches:
            res = AT.autotune_blocks(b, d_in, n_out, k, reps=reps,
                                     values_dtype=values_dtype)
            rows.append(_tune_row(name, b, res, kind="condensed", d_in=d_in,
                                  n_out=n_out, k=k, values_dtype=values_dtype))
    return rows


def structured_tune_rows(shapes, batches, reps: int) -> list[dict]:
    """Tuned-vs-default rows for the column-gathered structured kernel
    (kind="structured" cache keys; winner is the argmin of the same table
    the untimed VMEM-budget default sits in)."""
    rows = []
    for name, d_in, a_pad, d_out in shapes:
        for b in batches:
            res = AT.autotune_structured_blocks(b, d_in, a_pad, d_out,
                                                reps=reps)
            rows.append(_tune_row(name, b, res, kind="structured", d_in=d_in,
                                  n_out=a_pad, d_out=d_out))
    return rows


def predicted_crossover_batch(d_in: int, n_out: int, k: int,
                              profile: PLAN.HardwareProfile,
                              itemsize: int = 4) -> int:
    """Smallest batch where the cost model prices masked <= condensed
    (binary search over the monotone masked-wins frontier)."""
    stack = types.SimpleNamespace(n_replicas=1, d_in=d_in, d_out=n_out)

    def masked_wins(b: int) -> bool:
        costs = PLAN.stack_costs(stack, batch_size=b, itemsize=itemsize, k=k,
                                 active_fraction=1.0, profile=profile)
        return costs["masked"] <= costs["condensed"]

    lo, hi = 1, SWEEP[-1]
    if masked_wins(lo):
        return lo
    if not masked_wins(hi):
        return hi + 1   # no crossover inside the sweep
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if masked_wins(mid):
            hi = mid
        else:
            lo = mid
    return hi


def measured_crossover_batch(d_in: int, n_out: int, k: int, *,
                             reps: int = 5, seed: int = 0) -> tuple[int, list]:
    """Time the two serving primitives over the batch sweep and return the
    first CONFIRMED batch where the masked-dense step is at least as fast as
    the condensed gather (masked must also win at the next sweep point, so a
    single noisy flip cannot fake a crossover), plus the per-batch table.
    The sweep stops one point after confirmation. The gather is timed in its
    XLA (jnp.take) formulation — what the serving path executes on CPU, and
    what HardwareProfile.measure's gather rate is calibrated on."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d_in, n_out), jnp.float32)
    mask = topology.random_constant_fan_in_mask(
        jax.random.fold_in(key, 1), d_in, n_out, k)
    vals, idx = topology.dense_to_condensed(w * mask, mask, k)
    masked_fn = jax.jit(lambda x, w, m: x @ (w * m))
    gather_fn = jax.jit(ref.condensed_matmul_ref)

    table, candidate = [], None
    for b in SWEEP:
        x = jax.random.normal(jax.random.fold_in(key, b), (b, d_in))
        t_m = _time_us(masked_fn, x, w, mask, reps=reps)
        t_c = _time_us(gather_fn, x, vals, idx, reps=reps)
        table.append({"batch": b, "masked_us": round(t_m, 2),
                      "condensed_us": round(t_c, 2)})
        if t_m <= t_c:
            if candidate is not None:
                return candidate, table      # confirmed at two points
            candidate = b
        else:
            candidate = None
    # a candidate set at the last sweep point was never confirmed by a
    # second win — per the contract above it does not count as a crossover
    return SWEEP[-1] + 1, table


def crossover_rows(shapes, reps: int, retries: int = 2) -> list[dict]:
    """Per shape: calibrate a FRESH profile immediately before the sweep,
    predict the crossover from it, then measure. On shared/throttled hosts
    the machine's effective rates drift minute to minute; calibrating right
    next to the sweep keeps prediction and measurement sampling the same
    machine state. A same-bucket miss triggers a complete fresh
    calibrate+sweep attempt (up to ``retries`` more, recorded in the row) —
    the claim under test is calibration TRANSFER across shapes and batch,
    not host quietness during one particular minute."""
    rows = []
    for name, d_in, n_out, k in shapes:
        row = None
        for attempt in range(1, retries + 2):
            prof = PLAN.HardwareProfile.measure(use_cache=False, save=False)
            pred_default = predicted_crossover_batch(d_in, n_out, k,
                                                     PLAN.DEFAULT_PROFILE)
            pred_measured = predicted_crossover_batch(d_in, n_out, k, prof)
            meas, table = measured_crossover_batch(d_in, n_out, k, reps=reps,
                                                   seed=attempt - 1)
            # Bucket landing with an edge tolerance: ceiling-bucketing has a
            # cliff at each edge, so a pred/meas pair like 33-vs-32 (3%
            # apart, finer than the sweep's own ~1.5x grid resolution) must
            # not score as a miss. Pairs within 1.5x count as the same
            # landing (recorded); genuine misses (e.g. 17 vs 64) still fail.
            ratio = max(pred_measured, meas) / max(min(pred_measured, meas), 1)
            within_tol = ratio <= 1.5
            row = {
                "shape": name, "d_in": d_in, "n_out": n_out, "k": k,
                "predicted_crossover_default_profile": pred_default,
                "predicted_crossover_measured_profile": pred_measured,
                "measured_crossover": meas,
                "predicted_bucket": AT.batch_bucket(pred_measured),
                "measured_bucket": AT.batch_bucket(meas),
                "same_bucket": (AT.batch_bucket(pred_measured)
                                == AT.batch_bucket(meas)) or within_tol,
                "pred_meas_ratio": round(ratio, 3),
                "edge_tolerance_applied": within_tol and (
                    AT.batch_bucket(pred_measured) != AT.batch_bucket(meas)),
                "attempts": attempt,
                "profile_at_sweep": {
                    "hbm_bytes_per_s": prof.hbm_bytes_per_s,
                    "mxu_flops_per_s": prof.mxu_flops_per_s,
                    "gather_flops_per_s": prof.gather_flops_per_s,
                    "gather_flops_per_s_large": prof.gather_flops_per_s_large,
                },
                "sweep_us": table,
            }
            if row["same_bucket"]:
                break
        rows.append(row)
    return rows


def run(smoke: bool = True, reps: int = 0):
    """benchmarks.run harness entry: CSV rows only (no JSON artifact)."""
    shapes = SMOKE_SHAPES if smoke else FULL_SHAPES
    sshapes = SMOKE_STRUCT_SHAPES if smoke else FULL_STRUCT_SHAPES
    qshapes = SMOKE_QUANT_SHAPES if smoke else FULL_QUANT_SHAPES
    xshapes = SMOKE_CROSSOVER_SHAPES if smoke else FULL_CROSSOVER_SHAPES
    reps = reps or (3 if smoke else 5)
    rows = []
    for r in (tune_rows(shapes, DECODE_BATCHES, reps)
              + structured_tune_rows(sshapes, DECODE_BATCHES, reps)
              + quantized_tune_rows(qshapes, DECODE_BATCHES[:1], reps)):
        blk = ("decode" if r["tuned_block_b"] is None
               else str(r["tuned_block_b"])) + f"x{r['tuned_block_n']}"
        rows.append((f"kernel_autotune/{r['kind']}/{r['shape']}/b{r['batch']}",
                     r["tuned_us"],
                     f"blocks={blk};default_us={r['default_us']:.1f};"
                     f"speedup={r['speedup_vs_default']:.2f}x"))
    for r in crossover_rows(xshapes, reps):
        rows.append((f"kernel_autotune/crossover/{r['shape']}", 0.0,
                     f"pred={r['predicted_crossover_measured_profile']};"
                     f"meas={r['measured_crossover']};"
                     f"same_bucket={r['same_bucket']}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + few reps (CI per-PR tracking)")
    ap.add_argument("--reps", type=int, default=0,
                    help="timed repetitions per candidate (0 = auto)")
    ap.add_argument("--out", default="BENCH_kernel_autotune.json")
    args = ap.parse_args(argv)

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    sshapes = SMOKE_STRUCT_SHAPES if args.smoke else FULL_STRUCT_SHAPES
    qshapes = SMOKE_QUANT_SHAPES if args.smoke else FULL_QUANT_SHAPES
    xshapes = SMOKE_CROSSOVER_SHAPES if args.smoke else FULL_CROSSOVER_SHAPES
    reps = args.reps or (3 if args.smoke else 5)
    backend = jax.default_backend()

    print(f"[kernel_autotune] backend={backend} "
          f"interpret={cm.default_interpret()}")
    tuned = (tune_rows(shapes, DECODE_BATCHES, reps)
             + structured_tune_rows(sshapes, DECODE_BATCHES, reps)
             + quantized_tune_rows(qshapes, DECODE_BATCHES[:1], reps))
    for r in tuned:
        blk = ("decode" if r["tuned_block_b"] is None
               else str(r["tuned_block_b"])) + f"x{r['tuned_block_n']}"
        print(f"kernel_autotune/{r['kind']}/{r['shape']}/b{r['batch']},"
              f"{r['tuned_us']:.1f},"
              f"blocks={blk};default_us={r['default_us']:.1f};"
              f"speedup={r['speedup_vs_default']:.2f}x")

    measured = PLAN.HardwareProfile.measure(use_cache=False)
    print(f"[kernel_autotune] measured profile: "
          f"hbm {measured.hbm_bytes_per_s / 1e9:.2f} GB/s, "
          f"matmul {measured.mxu_flops_per_s / 1e9:.2f} GFLOP/s, "
          f"gather {measured.gather_flops_per_s / 1e9:.2f}->"
          f"{(measured.gather_flops_per_s_large or 0) / 1e9:.2f} GFLOP/s "
          f"(b={measured.gather_small_batch}->{measured.gather_large_batch})")

    crossings = crossover_rows(xshapes, reps)
    for r in crossings:
        print(f"kernel_autotune/crossover/{r['shape']},0.0,"
              f"pred={r['predicted_crossover_measured_profile']};"
              f"meas={r['measured_crossover']};"
              f"same_bucket={r['same_bucket']} (attempts={r['attempts']})")

    payload = {
        "benchmark": "kernel_autotune",
        "schema_version": SCHEMA_VERSION,
        "backend": backend,
        "pallas_interpret": tuned[0]["interpret"] if tuned else None,
        "interpret_note": "interpret-mode (CPU) timings do not transfer to "
                          "TPU/GPU; block RANKINGS and the crossover "
                          "methodology do",
        "batch_buckets": list(AT.BATCH_BUCKETS),
        "smoke": args.smoke,
        "reps": reps,
        "autotune_cache": AT.cache_path(),
        "profiles": {
            "default": {
                "name": PLAN.DEFAULT_PROFILE.name,
                "hbm_bytes_per_s": PLAN.DEFAULT_PROFILE.hbm_bytes_per_s,
                "mxu_flops_per_s": PLAN.DEFAULT_PROFILE.mxu_flops_per_s,
                "gather_flops_per_s": PLAN.DEFAULT_PROFILE.gather_flops_per_s,
            },
            "measured": {
                "name": measured.name,
                "hbm_bytes_per_s": measured.hbm_bytes_per_s,
                "mxu_flops_per_s": measured.mxu_flops_per_s,
                "gather_flops_per_s": measured.gather_flops_per_s,
                "gather_flops_per_s_large": measured.gather_flops_per_s_large,
                "gather_batch_points": [measured.gather_small_batch,
                                        measured.gather_large_batch],
            },
        },
        "tuned_blocks": tuned,
        "crossover": crossings,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    ok_blocks = all(r["speedup_vs_default"] >= 1.0 for r in tuned)
    ok_bucket = all(r["same_bucket"] for r in crossings)
    print(f"[kernel_autotune] wrote {args.out} "
          f"(tuned>=default: {ok_blocks}; crossover same-bucket: {ok_bucket})")
    return 0 if (ok_blocks and ok_bucket) else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
