"""Paper Table 5: training / inference FLOPs of the sparse model vs dense.

Computed with the paper's methodology (core/flops.py) over the qwen3-1.7b
linear layers under the ERK distribution actually solved by the registry.
"""
import time

import dataclasses

from repro import configs
from repro.core import flops as F
from repro.sparse import registry as REG


def _layers(cfg):
    reg = REG.build_registry(cfg)
    out = []
    for s in reg:
        out.append(F.LinearCost(s.name, s.d_in, s.d_out, density=s.density,
                                n_replicas=s.n_replicas))
    # dense (never-sparsified) layers: QKV + embeddings head
    out.append(F.LinearCost("qkv", cfg.d_model,
                            cfg.q_dim + 2 * cfg.kv_dim, 1.0,
                            n_replicas=cfg.n_layers))
    out.append(F.LinearCost("lm_head", cfg.d_model, cfg.vocab_size, 1.0))
    return out


def run():
    rows = []
    base = configs.get_config("qwen3-1.7b")
    tokens = 4096 * 256          # one train_4k step
    steps = 10_000
    dense_cfg = base.replace(sparsity=dataclasses.replace(base.sparsity,
                                                          sparsity=0.0))
    dense_layers = [dataclasses.replace(l, density=1.0) for l in _layers(dense_cfg)]
    dense_inf = F.inference_flops(dense_layers, 1)
    dense_train = F.training_flops(dense_layers, tokens, steps)
    rows.append(("flops/dense", 0.0,
                 f"train={dense_train:.3e} inference_per_token={dense_inf:.3e}"))
    for s in (0.8, 0.9, 0.95, 0.99):
        t0 = time.perf_counter()
        cfg = base.replace(sparsity=dataclasses.replace(base.sparsity, sparsity=s))
        layers = _layers(cfg)
        inf = F.inference_flops(layers, 1)
        train = F.training_flops(layers, tokens, steps)
        rows.append((f"flops/sparsity{int(s*100)}",
                     (time.perf_counter() - t0) * 1e6,
                     f"train={train:.3e} inf_per_tok={inf:.3e} "
                     f"ratio_vs_dense={inf/dense_inf:.3f}"))
    return rows
