"""Pallas TPU kernels for the perf-critical condensed sparse ops."""
from repro.kernels.ops import (  # noqa: F401
    condensed_linear,
    condensed_linear_nd,
    structured_dense,
)
