"""Ablation-aware Pallas kernels: column-gathered structured matmul and the
fused condensed-over-active scatter epilogue.

Both kernels execute the neuron-ablation half of the paper's Fig. 4 serving
story so that the ablated fraction converts into REAL byte/FLOP savings
instead of a masked-out dense pass:

* ``structured_matmul`` — the "structured" Fig. 4 point. The surviving
  output columns of the dense weight are gathered through a precomputed
  ``active_index`` int32 vector (surviving column ids, padded to the 128-lane
  tile with the out-of-range sentinel ``d_out``), the matmul runs over ONLY
  those ``a_pad`` columns on the MXU, and a fused one-hot scatter epilogue
  writes each compact column back to its dense position — ablated neurons
  are exact zeros written in-kernel, never a separate XLA scatter dispatch.
  Per-step HBM weight bytes and MXU matmul FLOPs are ``a_pad / d_out`` of
  the dense path. The column gather itself (``jnp.take`` along the lane
  axis) happens once per compiled program: the weight and ``active_index``
  are loop-invariant in the decode ``lax.scan``, so XLA hoists the gather
  out of the token loop and every decode step streams only the compact
  ``(d_in, a_pad)`` panel.
* ``condensed_over_active_matmul`` — the combined Fig. 4 point, fused. The
  condensed constant fan-in gather (same VMEM-local formulation as
  ``condensed_matmul``) runs over the ``a <= d_out`` surviving rows and the
  SAME one-hot epilogue scatters each row through ``out_index`` into the
  dense output layout inside the kernel. This replaces the previous
  compose-then-scatter lowering (``y.at[:, out_index].add``) that wrote the
  compact activations to HBM and re-read them in a separate scatter op —
  one full activation round trip per layer on the decode hot path.

Scatter epilogue (shared): for an index tile ``ai`` (compact position ->
dense column, padding == ``d_out``) the kernel builds the one-hot selection
matrix ``sel[t, c] = (ai[t] == c)`` and accumulates ``y_tile @ sel`` into a
``(B_blk, d_out)`` output block that stays resident across the compact-tile
grid dimension (innermost, same accumulation pattern as the dw kernel in
condensed_matmul). This is the Mosaic-friendly scatter formulation: an MXU
matmul instead of a data-dependent store. Exactness: each dense column is
hit by exactly one compact slot (export guarantees unique indices), a
one-hot dot passes the value through bit-exactly (v * 1.0 + exact zeros),
and padding slots (``ai == d_out``) match no column, so they are dropped
exactly like the old ``mode="drop"`` scatter.

VMEM budgets (words; ``d_in`` and ``d_out`` are structurally unblocked —
the gather needs the whole activation row, the scatter the whole output
row):

    structured: B_blk*d_in + d_in*N_blk + N_blk + B_blk*N_blk
                + N_blk*d_out + B_blk*d_out
    coa fused:  B_blk*d_in + N_blk*k*2 + N_blk + B_blk*N_blk
                + N_blk*d_out + B_blk*d_out

checked against the same per-backend cap as ``condensed_matmul``
(``vmem_budget_bytes``). The ``N_blk*d_out`` one-hot tile is the dominant
term at large ``d_out``; the budget shrinks the blocks accordingly, and the
(8, 128) minimum is kept even over budget (documented stance shared with
``condensed_matmul._aligned_candidates``). Decode shapes (B <=
``SMALL_BATCH_MAX``) use specialized variants that stage the sublane-padded
batch whole. ``repro.sparse.autotune`` runs the timed block search under the
``kind="structured"`` tuning keys.

Validated bit-identical against ``kernels.ops.structured_dense`` (structured)
and token-identical to the masked path (COA) in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import condensed_matmul as cm

LANE = cm.LANE
SUBLANE = cm.SUBLANE
SMALL_BATCH_MAX = cm.SMALL_BATCH_MAX
_ceil_to = cm._ceil_to


def padded_active_count(a: int, d_out: int) -> int:
    """Exported ``active_index`` length: the realized active-column count
    rounded up to the 128-lane tile (the gather axis is the lane dimension),
    capped at the padded dense width — padding past ``d_out`` buys nothing.
    Accepts float ``a`` (the cost model prices fractional row counts)."""
    return min(_ceil_to(int(max(a, 1)), LANE), _ceil_to(int(max(d_out, 1)), LANE))


# ---------------------------------------------------------------------------
# VMEM budget formulas / block candidates
# ---------------------------------------------------------------------------


def structured_vmem_words(block_b: int, block_n: int, d_in: int,
                          d_out: int) -> int:
    """x tile + gathered-weight tile + index tile + compact-y tile + one-hot
    tile + resident (B_blk, d_out) output block."""
    return (block_b * d_in + d_in * block_n + block_n + block_b * block_n
            + block_n * d_out + block_b * d_out)


def coa_vmem_words(block_b: int, block_n: int, d_in: int, k: int,
                   d_out: int) -> int:
    """x tile + (values + indices) tiles + out_index tile + compact-y tile +
    one-hot tile + resident output block."""
    return (block_b * d_in + block_n * k * 2 + block_n + block_b * block_n
            + block_n * d_out + block_b * d_out)


def structured_block_candidates(b: int, d_in: int, a: int, d_out: int, *,
                                backend: str | None = None) -> list[tuple[int, int]]:
    """8x128-aligned shapes fitting structured_vmem_words; ``a`` is the
    compact row count the grid tiles over (condensed_matmul's enumeration,
    including its keep-the-minimum-over-budget stance, adapted via a words
    lambda)."""
    return cm._aligned_candidates(
        lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
        b, 0, a, 0, backend)


def coa_block_candidates(b: int, d_in: int, a: int, k: int, d_out: int, *,
                         backend: str | None = None) -> list[tuple[int, int]]:
    """8x128-aligned shapes fitting coa_vmem_words over the ``a`` surviving
    rows (see structured_block_candidates)."""
    return cm._aligned_candidates(
        lambda bb, bn, _d, _k: coa_vmem_words(bb, bn, d_in, k, d_out),
        b, 0, a, 0, backend)


def default_structured_blocks(b: int, d_in: int, a: int, d_out: int, *,
                              backend: str | None = None) -> tuple[int, int]:
    return cm.pick_default_blocks(
        structured_block_candidates(b, d_in, a, d_out, backend=backend), b, a)


def default_coa_blocks(b: int, d_in: int, a: int, k: int, d_out: int, *,
                       backend: str | None = None) -> tuple[int, int]:
    return cm.pick_default_blocks(
        coa_block_candidates(b, d_in, a, k, d_out, backend=backend), b, a)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def _onehot_scatter(y: jax.Array, idx_row: jax.Array, d_out: int) -> jax.Array:
    """Scatter a compact (B_blk, N_blk) tile to dense columns via a one-hot
    MXU matmul. ``idx_row``: (1, N_blk) int32 dense positions; out-of-range
    entries (== d_out) match no column and are dropped exactly. Exact: each
    surviving value is multiplied by 1.0 and summed with exact zeros."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx_row.shape[1], d_out), 1)
    sel = (idx_row.T == cols).astype(jnp.float32)        # (N_blk, d_out)
    return jnp.dot(y, sel, preferred_element_type=jnp.float32)


def _structured_kernel(x_ref, w_ref, ai_ref, out_ref, *, grid_axis: int):
    """One compact-column tile of the gathered structured matmul.

    x_ref  : (B_blk, d_in)    VMEM
    w_ref  : (d_in, N_blk)    VMEM — pre-gathered surviving columns
    ai_ref : (1, N_blk)       VMEM int32 — dense position of each column
    out_ref: (B_blk, d_out)   VMEM — resident across the compact-tile axis
    """
    j = pl.program_id(grid_axis)
    y = jnp.dot(x_ref[...].astype(jnp.float32),
                w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)      # (B_blk, N_blk)
    contrib = _onehot_scatter(y, ai_ref[...], out_ref.shape[-1])

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(j != 0)
    def _accumulate():
        out_ref[...] = out_ref[...] + contrib.astype(out_ref.dtype)


def _coa_kernel(x_ref, w_ref, idx_ref, oi_ref, out_ref, *, grid_axis: int):
    """One surviving-row tile of the fused condensed-over-active matmul:
    the condensed VMEM-local gather-reduce followed by the scatter epilogue.

    x_ref  : (B_blk, d_in)  w_ref/idx_ref : (N_blk, k)  oi_ref : (1, N_blk)
    out_ref: (B_blk, d_out) resident across the row-tile axis.
    """
    j = pl.program_id(grid_axis)
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    idx = idx_ref[...]
    n_blk, k = idx.shape
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    y = jnp.sum(gathered * w[None], axis=-1)             # (B_blk, N_blk) f32
    contrib = _onehot_scatter(y, oi_ref[...], out_ref.shape[-1])

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(j != 0)
    def _accumulate():
        out_ref[...] = out_ref[...] + contrib.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers — structured
# ---------------------------------------------------------------------------


def _gather_columns(w: jax.Array, active_index: jax.Array) -> jax.Array:
    """(d_in, a) panel of surviving columns. Padding entries clip to the last
    column — their (garbage but finite) products are dropped by the all-zero
    one-hot row at scatter time, so no masking multiply is needed."""
    d_out = w.shape[-1]
    return jnp.take(w, jnp.minimum(active_index, d_out - 1), axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def _structured_tiled(x, w, active_index, *, block_b: int, block_n: int,
                      interpret: bool):
    """General gathered matmul: grid (batch tiles, compact-column tiles)."""
    b, d_in = x.shape
    d_out = w.shape[-1]
    a = active_index.shape[0]
    bp, ap = _ceil_to(max(b, 1), block_b), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wa = jnp.pad(_gather_columns(w, active_index), ((0, 0), (0, ap - a)))
    aip = jnp.pad(active_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out).reshape(1, ap)

    out = pl.pallas_call(
        functools.partial(_structured_kernel, grid_axis=1),
        grid=(bp // block_b, ap // block_n),
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
        interpret=interpret,
    )(xp, wa, aip)
    return out[:b]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _structured_decode(x, w, active_index, *, block_n: int, interpret: bool):
    """Decode-specialized variant: sublane-padded batch staged whole, grid
    over compact-column tiles only."""
    b, d_in = x.shape
    d_out = w.shape[-1]
    a = active_index.shape[0]
    bp, ap = _ceil_to(max(b, 1), SUBLANE), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wa = jnp.pad(_gather_columns(w, active_index), ((0, 0), (0, ap - a)))
    aip = jnp.pad(active_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out).reshape(1, ap)

    out = pl.pallas_call(
        functools.partial(_structured_kernel, grid_axis=0),
        grid=(ap // block_n,),
        in_specs=[
            pl.BlockSpec((bp, d_in), lambda j: (0, 0)),
            pl.BlockSpec((d_in, block_n), lambda j: (0, j)),
            pl.BlockSpec((1, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, d_out), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
        interpret=interpret,
    )(xp, wa, aip)
    return out[:b]


def structured_matmul(
    x: jax.Array,
    w: jax.Array,
    active_index: jax.Array,
    *,
    block_b: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Column-gathered structured matmul. x: (B, d_in), w: (d_in, d_out),
    active_index: (a,) int32 surviving-column ids (out-of-range == padding).
    Returns (B, d_out) with ablated columns exact zeros.

    ``block_b=None`` routes decode shapes (B <= SMALL_BATCH_MAX) to the
    decode-specialized variant; otherwise the VMEM-budget default applies
    (``repro.sparse.autotune`` supplies timed choices through
    ``kernels.ops.structured_linear``). Bit-identical to
    ``kernels.ops.structured_dense`` for any active set.
    """
    b, d_in = x.shape
    d_out = w.shape[-1]
    a = active_index.shape[0]
    if interpret is None:
        interpret = cm.default_interpret()
    if block_b is None and b <= SMALL_BATCH_MAX:
        return structured_matmul_decode(x, w, active_index, block_n=block_n,
                                        interpret=interpret)
    if block_b is None and block_n is None:
        block_b, block_n = default_structured_blocks(b, d_in, a, d_out)
    elif block_b is None:
        block_b = cm._fit_block_b(
            lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
            block_n, b, d_in, 0, cap=128)
    elif block_n is None:
        block_n = cm._fit_block_n(
            lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
            block_b, a, d_in, 0, cap=128)
    return _structured_tiled(x, w, active_index, block_b=block_b,
                             block_n=block_n, interpret=interpret)


def structured_matmul_decode(
    x: jax.Array,
    w: jax.Array,
    active_index: jax.Array,
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode-specialized structured matmul (batch staged whole). Bit-
    identical to the general variant: the d_in contraction and the one-hot
    scatter are independent of how the batch axis is padded or tiled."""
    b, d_in = x.shape
    d_out = w.shape[-1]
    a = active_index.shape[0]
    if interpret is None:
        interpret = cm.default_interpret()
    if block_n is None:
        _, block_n = default_structured_blocks(min(b, SMALL_BATCH_MAX), d_in,
                                               a, d_out)
    return _structured_decode(x, w, active_index, block_n=block_n,
                              interpret=interpret)


# ---------------------------------------------------------------------------
# pallas_call wrappers — condensed-over-active, fused epilogue
# ---------------------------------------------------------------------------


def _coa_pad(values, indices, out_index, d_out: int, ap: int):
    a = values.shape[0]
    vp = jnp.pad(values, ((0, ap - a), (0, 0)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, ap - a), (0, 0)))
    oip = jnp.pad(out_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out).reshape(1, ap)
    return vp, ip, oip


@functools.partial(jax.jit, static_argnames=("d_out", "block_b", "block_n",
                                             "interpret"))
def _coa_tiled(x, values, indices, out_index, *, d_out: int, block_b: int,
               block_n: int, interpret: bool):
    b, d_in = x.shape
    a, k = values.shape
    bp, ap = _ceil_to(max(b, 1), block_b), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    vp, ip, oip = _coa_pad(values, indices, out_index, d_out, ap)

    out = pl.pallas_call(
        functools.partial(_coa_kernel, grid_axis=1),
        grid=(bp // block_b, ap // block_n),
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
        interpret=interpret,
    )(xp, vp, ip, oip)
    return out[:b]


@functools.partial(jax.jit, static_argnames=("d_out", "block_n", "interpret"))
def _coa_decode(x, values, indices, out_index, *, d_out: int, block_n: int,
                interpret: bool):
    b, d_in = x.shape
    a, k = values.shape
    bp, ap = _ceil_to(max(b, 1), SUBLANE), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    vp, ip, oip = _coa_pad(values, indices, out_index, d_out, ap)

    out = pl.pallas_call(
        functools.partial(_coa_kernel, grid_axis=0),
        grid=(ap // block_n,),
        in_specs=[
            pl.BlockSpec((bp, d_in), lambda j: (0, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, d_out), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
        interpret=interpret,
    )(xp, vp, ip, oip)
    return out[:b]


def condensed_over_active_matmul(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    out_index: jax.Array,
    d_out: int,
    *,
    block_b: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused condensed-over-active matmul: the condensed gather runs over the
    ``a <= d_out`` surviving rows and the output block is written through
    ``out_index`` directly (ablated rows zero-filled in-kernel). Token-
    identical to the old compose-then-scatter lowering — the same f32
    accumulation, the same single downcast, the same drop semantics for
    out-of-range padding rows — without the separate scatter dispatch or the
    compact-activation HBM round trip.
    """
    b, d_in = x.shape
    a, k = values.shape
    if interpret is None:
        interpret = cm.default_interpret()
    if block_b is None and b <= SMALL_BATCH_MAX:
        return condensed_over_active_matmul_decode(
            x, values, indices, out_index, d_out, block_n=block_n,
            interpret=interpret)
    if block_b is None and block_n is None:
        block_b, block_n = default_coa_blocks(b, d_in, a, k, d_out)
    elif block_b is None:
        block_b = cm._fit_block_b(
            lambda bb, bn, _d, _k: coa_vmem_words(bb, bn, d_in, k, d_out),
            block_n, b, d_in, k, cap=128)
    elif block_n is None:
        block_n = cm._fit_block_n(
            lambda bb, bn, _d, _k: coa_vmem_words(bb, bn, d_in, k, d_out),
            block_b, a, d_in, k, cap=128)
    return _coa_tiled(x, values, indices, out_index, d_out=d_out,
                      block_b=block_b, block_n=block_n, interpret=interpret)


def condensed_over_active_matmul_decode(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    out_index: jax.Array,
    d_out: int,
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode-specialized fused COA matmul (batch staged whole)."""
    b, d_in = x.shape
    a, k = values.shape
    if interpret is None:
        interpret = cm.default_interpret()
    if block_n is None:
        _, block_n = default_coa_blocks(min(b, SMALL_BATCH_MAX), d_in, a, k,
                                        d_out)
    return _coa_decode(x, values, indices, out_index, d_out=d_out,
                       block_n=block_n, interpret=interpret)
