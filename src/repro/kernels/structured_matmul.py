"""Ablation-aware Pallas kernels: column-gathered structured matmul and the
fused condensed-over-active scatter epilogue.

Both kernels execute the neuron-ablation half of the paper's Fig. 4 serving
story so that the ablated fraction converts into REAL byte/FLOP savings
instead of a masked-out dense pass:

* ``structured_matmul`` — the "structured" Fig. 4 point. The surviving
  output columns of the dense weight are gathered through a precomputed
  ``active_index`` int32 vector (surviving column ids, padded to the 128-lane
  tile with the out-of-range sentinel ``d_out``), the matmul runs over ONLY
  those ``a_pad`` columns on the MXU, and a fused one-hot scatter epilogue
  writes each compact column back to its dense position — ablated neurons
  are exact zeros written in-kernel, never a separate XLA scatter dispatch.
  Per-step HBM weight bytes and MXU matmul FLOPs are ``a_pad / d_out`` of
  the dense path. The column gather itself (``jnp.take`` along the lane
  axis) happens once per compiled program: the weight and ``active_index``
  are loop-invariant in the decode ``lax.scan``, so XLA hoists the gather
  out of the token loop and every decode step streams only the compact
  ``(d_in, a_pad)`` panel. The gather is tagged with
  ``jax.named_scope("hoisted_column_gather")`` so HLO tests can count it;
  the scalar-prefetch decode variant below removes it entirely.
* ``structured_matmul_pregathered`` — same kernels, but the caller supplies
  the compact ``(d_in, a_pad)`` panel directly (e.g. dequantized from
  int8/fp8 quantized storage, where no dense ``d_in x d_out`` weight exists
  to gather from). No gather pass appears in the program at all.
* ``condensed_over_active_matmul`` — the combined Fig. 4 point, fused. The
  condensed constant fan-in gather (same VMEM-local formulation as
  ``condensed_matmul``) runs over the ``a <= d_out`` surviving rows and the
  SAME one-hot epilogue scatters each row through ``out_index`` into the
  dense output layout inside the kernel. This replaces the previous
  compose-then-scatter lowering (``y.at[:, out_index].add``) that wrote the
  compact activations to HBM and re-read them in a separate scatter op —
  one full activation round trip per layer on the decode hot path. With
  ``scales`` (per-row f32), ``values`` are int8/fp8 codes and the
  dequantize fuses into the kernel (one multiply per compact row output,
  after the k-reduction — exact, the scale is constant over a row's
  fan-in), so the weight stream shrinks to ~1 byte/elem.

Scatter epilogue (shared): for an index tile ``ai`` (compact position ->
dense column, padding == ``d_out``) the kernel builds the one-hot selection
matrix ``sel[t, c] = (ai[t] == c)`` and accumulates ``y_tile @ sel`` into a
``(B_blk, d_out)`` output block that stays resident across the compact-tile
grid dimension (innermost, same accumulation pattern as the dw kernel in
condensed_matmul). This is the Mosaic-friendly scatter formulation: an MXU
matmul instead of a data-dependent store. Exactness: each dense column is
hit by exactly one compact slot (export guarantees unique indices), a
one-hot dot passes the value through bit-exactly (v * 1.0 + exact zeros),
and padding slots (``ai == d_out``) match no column, so they are dropped
exactly like the old ``mode="drop"`` scatter.

Out-blocked epilogue (``block_o``): the default kernels keep the full
``(B_blk, d_out)`` output block and ``(N_blk, d_out)`` one-hot tile resident
in VMEM — fine to ``d_out ~ 8k``, not beyond. Passing ``block_o`` (a
128-multiple) adds a ``d_out`` tile axis to the grid: the one-hot is built
against tile-local columns (``iota + o * block_o``) and only a
``(B_blk, block_o)`` output block + ``(N_blk, block_o)`` one-hot tile stay
resident. Cost: each compact tile's ``y`` is recomputed once per ``d_out``
tile (the compact->dense mapping is data-dependent, so every (o, j) pair
must be visited) — a FLOP-for-VMEM trade that only pays off when ``d_out``
does not fit; bit-identical to the unblocked epilogue (each dense column
still matched by exactly one (o, j) one-hot hit).

Scalar-prefetch decode variant (``prefetch_gather``): the decode-scan gather
hoist above still costs one XLA gather pass per compiled program plus an
HBM round trip for the ``(d_in, a_pad)`` panel. The prefetch variant
(``pltpu.PrefetchScalarGridSpec``) instead prefetches ``active_index`` as a
scalar operand, stages the FULL dense ``(d_in, d_out)`` weight in VMEM, and
performs the column gather inside the kernel per compact tile — no XLA
gather pass, no intermediate panel buffer. The price is full-weight VMEM
residency (``d_in * d_out`` words), so it is gated on the VMEM budget and
applies to decode shapes; enable via ``prefetch_gather=True`` or
``REPRO_PREFETCH_GATHER=1``.

VMEM budgets (words; ``d_in`` is structurally unblocked — the gather needs
the whole activation row; ``d_out`` is unblocked only when ``block_o`` is
not used):

    structured: B_blk*d_in + d_in*N_blk + N_blk + B_blk*N_blk
                + N_blk*O_blk + B_blk*O_blk          (O_blk = block_o or d_out)
    coa fused:  B_blk*d_in + N_blk*k*2 + N_blk + B_blk*N_blk
                + N_blk*O_blk + B_blk*O_blk
    prefetch:   B_pad*d_in + d_in*d_out + N_blk + B_pad*d_out + N_blk*d_out

checked against the same per-backend cap as ``condensed_matmul``
(``vmem_budget_bytes`` — 16 MiB/core published v5e figure, halved for
double-buffering headroom, overridable via ``REPRO_VMEM_CAP_BYTES``; see
that module's docstring for the Mosaic scoped-VMEM-limit cross-check).
Quantized tiles are charged at 4 B/elem like everything else — conservative
for 1-byte codes, so a block that fits at f32 always fits quantized. The
``N_blk*d_out`` one-hot tile is the dominant term at large ``d_out``; the
budget shrinks the blocks accordingly, and the (8, 128) minimum is kept
even over budget (documented stance shared with
``condensed_matmul._aligned_candidates``). Decode shapes (B <=
``SMALL_BATCH_MAX``) use specialized variants that stage the sublane-padded
batch whole. ``repro.sparse.autotune`` runs the timed block search under the
``kind="structured"`` tuning keys.

Validated bit-identical against ``kernels.ops.structured_dense`` (structured)
and token-identical to the masked path (COA) in interpret mode on CPU.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific grid specs (scalar prefetch); present on CPU jaxlib too
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - exotic builds
    pltpu = None

from repro.kernels import condensed_matmul as cm

LANE = cm.LANE
SUBLANE = cm.SUBLANE
SMALL_BATCH_MAX = cm.SMALL_BATCH_MAX
_ceil_to = cm._ceil_to


def padded_active_count(a: int, d_out: int) -> int:
    """Exported ``active_index`` length: the realized active-column count
    rounded up to the 128-lane tile (the gather axis is the lane dimension),
    capped at the padded dense width — padding past ``d_out`` buys nothing.
    Accepts float ``a`` (the cost model prices fractional row counts)."""
    return min(_ceil_to(int(max(a, 1)), LANE), _ceil_to(int(max(d_out, 1)), LANE))


# ---------------------------------------------------------------------------
# VMEM budget formulas / block candidates
# ---------------------------------------------------------------------------


def structured_vmem_words(block_b: int, block_n: int, d_in: int,
                          d_out: int, block_o: int | None = None) -> int:
    """x tile + gathered-weight tile + index tile + compact-y tile + one-hot
    tile + resident output block (``block_o`` tiles the last two)."""
    o_blk = min(block_o or d_out, d_out)
    return (block_b * d_in + d_in * block_n + block_n + block_b * block_n
            + block_n * o_blk + block_b * o_blk)


def coa_vmem_words(block_b: int, block_n: int, d_in: int, k: int,
                   d_out: int, block_o: int | None = None) -> int:
    """x tile + (values + indices) tiles + out_index tile + compact-y tile +
    one-hot tile + resident output block (``block_o`` tiles the last two)."""
    o_blk = min(block_o or d_out, d_out)
    return (block_b * d_in + block_n * k * 2 + block_n + block_b * block_n
            + block_n * o_blk + block_b * o_blk)


def prefetch_vmem_words(b_pad: int, block_n: int, d_in: int,
                        d_out: int) -> int:
    """Scalar-prefetch decode working set: whole batch + FULL dense weight +
    compact-y tile + resident dense output block + one-hot tile."""
    return (b_pad * d_in + d_in * d_out + b_pad * block_n
            + b_pad * d_out + block_n * d_out)


def structured_block_candidates(b: int, d_in: int, a: int, d_out: int, *,
                                backend: str | None = None) -> list[tuple[int, int]]:
    """8x128-aligned shapes fitting structured_vmem_words; ``a`` is the
    compact row count the grid tiles over (condensed_matmul's enumeration,
    including its keep-the-minimum-over-budget stance, adapted via a words
    lambda)."""
    return cm._aligned_candidates(
        lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
        b, 0, a, 0, backend)


def coa_block_candidates(b: int, d_in: int, a: int, k: int, d_out: int, *,
                         backend: str | None = None) -> list[tuple[int, int]]:
    """8x128-aligned shapes fitting coa_vmem_words over the ``a`` surviving
    rows (see structured_block_candidates)."""
    return cm._aligned_candidates(
        lambda bb, bn, _d, _k: coa_vmem_words(bb, bn, d_in, k, d_out),
        b, 0, a, 0, backend)


def default_structured_blocks(b: int, d_in: int, a: int, d_out: int, *,
                              backend: str | None = None) -> tuple[int, int]:
    return cm.pick_default_blocks(
        structured_block_candidates(b, d_in, a, d_out, backend=backend), b, a)


def default_coa_blocks(b: int, d_in: int, a: int, k: int, d_out: int, *,
                       backend: str | None = None) -> tuple[int, int]:
    return cm.pick_default_blocks(
        coa_block_candidates(b, d_in, a, k, d_out, backend=backend), b, a)


def _prefetch_default() -> bool:
    return os.environ.get("REPRO_PREFETCH_GATHER", "0") != "0"


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def _onehot_scatter(y: jax.Array, idx_row: jax.Array, d_out: int,
                    col_offset=0) -> jax.Array:
    """Scatter a compact (B_blk, N_blk) tile to dense columns via a one-hot
    MXU matmul. ``idx_row``: (1, N_blk) int32 dense positions; out-of-range
    entries (== d_out) match no column and are dropped exactly. Exact: each
    surviving value is multiplied by 1.0 and summed with exact zeros.
    ``col_offset`` shifts the column window for out-blocked epilogues (the
    tile then covers dense columns [col_offset, col_offset + width))."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx_row.shape[1], d_out), 1)
    sel = (idx_row.T == cols + col_offset).astype(jnp.float32)  # (N_blk, O)
    return jnp.dot(y, sel, preferred_element_type=jnp.float32)


def _structured_kernel(x_ref, w_ref, ai_ref, out_ref, *, grid_axis: int,
                       o_axis: int | None = None, block_o: int | None = None):
    """One compact-column tile of the gathered structured matmul.

    x_ref  : (B_blk, d_in)    VMEM
    w_ref  : (d_in, N_blk)    VMEM — pre-gathered surviving columns
    ai_ref : (1, N_blk)       VMEM int32 — dense position of each column
    out_ref: (B_blk, d_out)   VMEM — resident across the compact-tile axis
             ((B_blk, block_o) when the epilogue is out-blocked; the one-hot
             then selects only this tile's column window)
    """
    j = pl.program_id(grid_axis)
    y = jnp.dot(x_ref[...].astype(jnp.float32),
                w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)      # (B_blk, N_blk)
    offset = 0 if o_axis is None else pl.program_id(o_axis) * block_o
    contrib = _onehot_scatter(y, ai_ref[...], out_ref.shape[-1], offset)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(j != 0)
    def _accumulate():
        out_ref[...] = out_ref[...] + contrib.astype(out_ref.dtype)


def _structured_prefetch_kernel(ai_ref, x_ref, w_ref, out_ref, *,
                                block_n: int):
    """Scalar-prefetch decode kernel: ``ai_ref`` is the PREFETCHED compact
    index vector (whole (a_pad,) int32, SMEM), ``w_ref`` the FULL dense
    (d_in, d_out) weight staged in VMEM. The column gather runs in-kernel
    per compact tile — no XLA gather pass, no (d_in, a_pad) panel buffer.

    x_ref  : (B_pad, d_in)   VMEM, whole sublane-padded batch
    out_ref: (B_pad, d_out)  VMEM, resident across the grid
    """
    j = pl.program_id(0)
    d_out = out_ref.shape[-1]
    idx = jax.lax.dynamic_slice(ai_ref[...], (j * block_n,), (block_n,))
    # padding entries (== d_out) clip to the last column; their (finite)
    # products are dropped by the all-zero one-hot row at scatter time
    wg = jnp.take(w_ref[...].astype(jnp.float32),
                  jnp.minimum(idx, d_out - 1), axis=1)   # (d_in, N_blk)
    y = jnp.dot(x_ref[...].astype(jnp.float32), wg,
                preferred_element_type=jnp.float32)
    contrib = _onehot_scatter(y, idx.reshape(1, block_n), d_out)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(j != 0)
    def _accumulate():
        out_ref[...] = out_ref[...] + contrib.astype(out_ref.dtype)


def _coa_kernel(x_ref, w_ref, idx_ref, oi_ref, *rest, grid_axis: int,
                scaled: bool = False, o_axis: int | None = None,
                block_o: int | None = None):
    """One surviving-row tile of the fused condensed-over-active matmul:
    the condensed VMEM-local gather-reduce followed by the scatter epilogue.

    x_ref  : (B_blk, d_in)  w_ref/idx_ref : (N_blk, k)  oi_ref : (1, N_blk)
    out_ref: (B_blk, d_out) resident across the row-tile axis ((B_blk,
    block_o) when out-blocked). ``scaled`` inserts a (1, N_blk) per-row f32
    scale tile before the output ref: ``w_ref`` then holds int8/fp8 codes
    and the dequantize multiply fuses here, after the k-reduction (exact —
    the scale is constant over a row's fan-in).
    """
    scale_ref = rest[0] if scaled else None
    out_ref = rest[-1]
    j = pl.program_id(grid_axis)
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    idx = idx_ref[...]
    n_blk, k = idx.shape
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    y = jnp.sum(gathered * w[None], axis=-1)             # (B_blk, N_blk) f32
    if scaled:
        y = y * scale_ref[...].astype(jnp.float32)       # (1, N_blk) bcast
    offset = 0 if o_axis is None else pl.program_id(o_axis) * block_o
    contrib = _onehot_scatter(y, oi_ref[...], out_ref.shape[-1], offset)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(j != 0)
    def _accumulate():
        out_ref[...] = out_ref[...] + contrib.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers — structured
# ---------------------------------------------------------------------------


def _gather_columns(w: jax.Array, active_index: jax.Array) -> jax.Array:
    """(d_in, a) panel of surviving columns. Padding entries clip to the last
    column — their (garbage but finite) products are dropped by the all-zero
    one-hot row at scatter time, so no masking multiply is needed.

    Wrapped in ``jax.named_scope("hoisted_column_gather")``: this is the ONE
    XLA gather pass the decode scan hoists (loop-invariant operands), and
    the scope tag is what the HLO dispatch-count tests — and the assertion
    that the scalar-prefetch variant removes it — key on."""
    with jax.named_scope("hoisted_column_gather"):
        d_out = w.shape[-1]
        return jnp.take(w, jnp.minimum(active_index, d_out - 1), axis=1)


@functools.partial(jax.jit, static_argnames=("d_out", "block_b", "block_n",
                                             "block_o", "interpret"))
def _structured_tiled(x, wa, active_index, *, d_out: int, block_b: int,
                      block_n: int, block_o: int | None, interpret: bool):
    """General gathered matmul over a PRE-GATHERED (d_in, a) panel: grid
    (batch tiles, compact-column tiles), plus a d_out tile axis when the
    epilogue is out-blocked."""
    b, d_in = x.shape
    a = active_index.shape[0]
    bp, ap = _ceil_to(max(b, 1), block_b), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wap = jnp.pad(wa, ((0, 0), (0, ap - a)))
    aip = jnp.pad(active_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out).reshape(1, ap)

    if block_o is None:
        out = pl.pallas_call(
            functools.partial(_structured_kernel, grid_axis=1),
            grid=(bp // block_b, ap // block_n),
            in_specs=[
                pl.BlockSpec((block_b, d_in), lambda i, j: (i, 0)),
                pl.BlockSpec((d_in, block_n), lambda i, j: (0, j)),
                pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((block_b, d_out), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
            interpret=interpret,
        )(xp, wap, aip)
        return out[:b]

    dop = _ceil_to(d_out, block_o)
    out = pl.pallas_call(
        functools.partial(_structured_kernel, grid_axis=2, o_axis=1,
                          block_o=block_o),
        grid=(bp // block_b, dop // block_o, ap // block_n),
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i, o, j: (i, 0)),
            pl.BlockSpec((d_in, block_n), lambda i, o, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, o, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, o, j: (i, o)),
        out_shape=jax.ShapeDtypeStruct((bp, dop), x.dtype),
        interpret=interpret,
    )(xp, wap, aip)
    return out[:b, :d_out]


@functools.partial(jax.jit, static_argnames=("d_out", "block_n", "block_o",
                                             "interpret"))
def _structured_decode(x, wa, active_index, *, d_out: int, block_n: int,
                       block_o: int | None, interpret: bool):
    """Decode-specialized variant: sublane-padded batch staged whole, grid
    over compact-column tiles only (plus a d_out tile axis when
    out-blocked)."""
    b, d_in = x.shape
    a = active_index.shape[0]
    bp, ap = _ceil_to(max(b, 1), SUBLANE), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wap = jnp.pad(wa, ((0, 0), (0, ap - a)))
    aip = jnp.pad(active_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out).reshape(1, ap)

    if block_o is None:
        out = pl.pallas_call(
            functools.partial(_structured_kernel, grid_axis=0),
            grid=(ap // block_n,),
            in_specs=[
                pl.BlockSpec((bp, d_in), lambda j: (0, 0)),
                pl.BlockSpec((d_in, block_n), lambda j: (0, j)),
                pl.BlockSpec((1, block_n), lambda j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bp, d_out), lambda j: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
            interpret=interpret,
        )(xp, wap, aip)
        return out[:b]

    dop = _ceil_to(d_out, block_o)
    out = pl.pallas_call(
        functools.partial(_structured_kernel, grid_axis=1, o_axis=0,
                          block_o=block_o),
        grid=(dop // block_o, ap // block_n),
        in_specs=[
            pl.BlockSpec((bp, d_in), lambda o, j: (0, 0)),
            pl.BlockSpec((d_in, block_n), lambda o, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda o, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, block_o), lambda o, j: (0, o)),
        out_shape=jax.ShapeDtypeStruct((bp, dop), x.dtype),
        interpret=interpret,
    )(xp, wap, aip)
    return out[:b, :d_out]


@functools.partial(jax.jit, static_argnames=("d_out", "block_n", "interpret"))
def _structured_prefetch_decode(x, w, active_index, *, d_out: int,
                                block_n: int, interpret: bool):
    """Scalar-prefetch decode: active_index prefetched scalar, FULL dense
    weight staged in VMEM, gather in-kernel (see module docstring)."""
    b, d_in = x.shape
    a = active_index.shape[0]
    bp, ap = _ceil_to(max(b, 1), SUBLANE), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    aip = jnp.pad(active_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ap // block_n,),
        in_specs=[
            pl.BlockSpec((bp, d_in), lambda j, ai: (0, 0)),
            pl.BlockSpec((d_in, d_out), lambda j, ai: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, d_out), lambda j, ai: (0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_structured_prefetch_kernel, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
        interpret=interpret,
    )(aip, xp, w)
    return out[:b]


def structured_matmul(
    x: jax.Array,
    w: jax.Array,
    active_index: jax.Array,
    *,
    block_b: int | None = None,
    block_n: int | None = None,
    block_o: int | None = None,
    interpret: bool | None = None,
    prefetch_gather: bool | None = None,
) -> jax.Array:
    """Column-gathered structured matmul. x: (B, d_in), w: (d_in, d_out),
    active_index: (a,) int32 surviving-column ids (out-of-range == padding).
    Returns (B, d_out) with ablated columns exact zeros.

    ``block_b=None`` routes decode shapes (B <= SMALL_BATCH_MAX) to the
    decode-specialized variant; otherwise the VMEM-budget default applies
    (``repro.sparse.autotune`` supplies timed choices through
    ``kernels.ops.structured_linear``). ``block_o`` tiles the scatter
    epilogue over d_out (see module docstring); ``prefetch_gather`` selects
    the scalar-prefetch decode variant. Bit-identical to
    ``kernels.ops.structured_dense`` for any active set.
    """
    b, d_in = x.shape
    d_out = w.shape[-1]
    a = active_index.shape[0]
    if interpret is None:
        interpret = cm.default_interpret()
    if block_b is None and b <= SMALL_BATCH_MAX:
        return structured_matmul_decode(
            x, w, active_index, block_n=block_n, block_o=block_o,
            interpret=interpret, prefetch_gather=prefetch_gather)
    if block_b is None and block_n is None:
        block_b, block_n = default_structured_blocks(b, d_in, a, d_out)
    elif block_b is None:
        block_b = cm._fit_block_b(
            lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
            block_n, b, d_in, 0, cap=128)
    elif block_n is None:
        block_n = cm._fit_block_n(
            lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
            block_b, a, d_in, 0, cap=128)
    wa = _gather_columns(w, active_index)
    return _structured_tiled(x, wa, active_index, d_out=d_out,
                             block_b=block_b, block_n=block_n,
                             block_o=block_o, interpret=interpret)


def structured_matmul_decode(
    x: jax.Array,
    w: jax.Array,
    active_index: jax.Array,
    *,
    block_n: int | None = None,
    block_o: int | None = None,
    interpret: bool | None = None,
    prefetch_gather: bool | None = None,
) -> jax.Array:
    """Decode-specialized structured matmul (batch staged whole). Bit-
    identical to the general variant: the d_in contraction and the one-hot
    scatter are independent of how the batch axis is padded or tiled.

    ``prefetch_gather=True`` forces the scalar-prefetch variant (caller
    takes responsibility for VMEM); ``None`` consults
    ``REPRO_PREFETCH_GATHER`` and additionally gates on the VMEM budget —
    full-weight residency is the variant's price (see prefetch_vmem_words).
    """
    b, d_in = x.shape
    d_out = w.shape[-1]
    a = active_index.shape[0]
    if interpret is None:
        interpret = cm.default_interpret()
    if block_n is None:
        _, block_n = default_structured_blocks(min(b, SMALL_BATCH_MAX), d_in,
                                               a, d_out)
    use_prefetch = prefetch_gather
    if use_prefetch is None and pltpu is not None and block_o is None:
        bp = _ceil_to(max(b, 1), SUBLANE)
        fits = (prefetch_vmem_words(bp, block_n, d_in, d_out) * cm._WORD
                <= cm.vmem_budget_bytes())
        use_prefetch = _prefetch_default() and fits
    if use_prefetch:
        return _structured_prefetch_decode(x, w, active_index, d_out=d_out,
                                           block_n=block_n,
                                           interpret=interpret)
    wa = _gather_columns(w, active_index)
    return _structured_decode(x, wa, active_index, d_out=d_out,
                              block_n=block_n, block_o=block_o,
                              interpret=interpret)


def structured_matmul_pregathered(
    x: jax.Array,
    panel: jax.Array,
    active_index: jax.Array,
    d_out: int,
    *,
    block_b: int | None = None,
    block_n: int | None = None,
    block_o: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Structured matmul over a caller-supplied compact panel.

    ``panel``: (d_in, a) surviving columns, already gathered — the entry
    point for quantized StructuredFanIn storage, where the compact panel IS
    the stored representation (dequantized in XLA) and no dense weight
    exists to gather from. Same kernels, no gather pass in the program.
    """
    b, d_in = x.shape
    a = active_index.shape[0]
    if interpret is None:
        interpret = cm.default_interpret()
    if block_b is None and b <= SMALL_BATCH_MAX:
        if block_n is None:
            _, block_n = default_structured_blocks(min(b, SMALL_BATCH_MAX),
                                                   d_in, a, d_out)
        return _structured_decode(x, panel, active_index, d_out=d_out,
                                  block_n=block_n, block_o=block_o,
                                  interpret=interpret)
    if block_b is None and block_n is None:
        block_b, block_n = default_structured_blocks(b, d_in, a, d_out)
    elif block_b is None:
        block_b = cm._fit_block_b(
            lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
            block_n, b, d_in, 0, cap=128)
    elif block_n is None:
        block_n = cm._fit_block_n(
            lambda bb, bn, _d, _k: structured_vmem_words(bb, bn, d_in, d_out),
            block_b, a, d_in, 0, cap=128)
    return _structured_tiled(x, panel, active_index, d_out=d_out,
                             block_b=block_b, block_n=block_n,
                             block_o=block_o, interpret=interpret)


# ---------------------------------------------------------------------------
# pallas_call wrappers — condensed-over-active, fused epilogue
# ---------------------------------------------------------------------------


def _coa_pad(values, indices, out_index, d_out: int, ap: int):
    a = values.shape[0]
    vp = jnp.pad(values, ((0, ap - a), (0, 0)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, ap - a), (0, 0)))
    oip = jnp.pad(out_index.astype(jnp.int32), (0, ap - a),
                  constant_values=d_out).reshape(1, ap)
    return vp, ip, oip


@functools.partial(jax.jit, static_argnames=("d_out", "block_b", "block_n",
                                             "block_o", "interpret"))
def _coa_tiled(x, values, indices, out_index, scales=None, *, d_out: int,
               block_b: int, block_n: int, block_o: int | None,
               interpret: bool):
    b, d_in = x.shape
    a, k = values.shape
    bp, ap = _ceil_to(max(b, 1), block_b), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    vp, ip, oip = _coa_pad(values, indices, out_index, d_out, ap)

    scaled = scales is not None
    operands = [xp, vp, ip, oip]
    if scaled:
        operands.append(jnp.pad(scales.astype(jnp.float32),
                                (0, ap - a)).reshape(1, ap))

    if block_o is None:
        in_specs = [
            pl.BlockSpec((block_b, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ]
        if scaled:
            in_specs.append(pl.BlockSpec((1, block_n), lambda i, j: (0, j)))
        out = pl.pallas_call(
            functools.partial(_coa_kernel, grid_axis=1, scaled=scaled),
            grid=(bp // block_b, ap // block_n),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_b, d_out), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
            interpret=interpret,
        )(*operands)
        return out[:b]

    dop = _ceil_to(d_out, block_o)
    in_specs = [
        pl.BlockSpec((block_b, d_in), lambda i, o, j: (i, 0)),
        pl.BlockSpec((block_n, k), lambda i, o, j: (j, 0)),
        pl.BlockSpec((block_n, k), lambda i, o, j: (j, 0)),
        pl.BlockSpec((1, block_n), lambda i, o, j: (0, j)),
    ]
    if scaled:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, o, j: (0, j)))
    out = pl.pallas_call(
        functools.partial(_coa_kernel, grid_axis=2, scaled=scaled, o_axis=1,
                          block_o=block_o),
        grid=(bp // block_b, dop // block_o, ap // block_n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, o, j: (i, o)),
        out_shape=jax.ShapeDtypeStruct((bp, dop), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:b, :d_out]


@functools.partial(jax.jit, static_argnames=("d_out", "block_n", "block_o",
                                             "interpret"))
def _coa_decode(x, values, indices, out_index, scales=None, *, d_out: int,
                block_n: int, block_o: int | None, interpret: bool):
    b, d_in = x.shape
    a, k = values.shape
    bp, ap = _ceil_to(max(b, 1), SUBLANE), _ceil_to(max(a, 1), block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    vp, ip, oip = _coa_pad(values, indices, out_index, d_out, ap)

    scaled = scales is not None
    operands = [xp, vp, ip, oip]
    if scaled:
        operands.append(jnp.pad(scales.astype(jnp.float32),
                                (0, ap - a)).reshape(1, ap))

    if block_o is None:
        in_specs = [
            pl.BlockSpec((bp, d_in), lambda j: (0, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda j: (0, j)),
        ]
        if scaled:
            in_specs.append(pl.BlockSpec((1, block_n), lambda j: (0, j)))
        out = pl.pallas_call(
            functools.partial(_coa_kernel, grid_axis=0, scaled=scaled),
            grid=(ap // block_n,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bp, d_out), lambda j: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, d_out), x.dtype),
            interpret=interpret,
        )(*operands)
        return out[:b]

    dop = _ceil_to(d_out, block_o)
    in_specs = [
        pl.BlockSpec((bp, d_in), lambda o, j: (0, 0)),
        pl.BlockSpec((block_n, k), lambda o, j: (j, 0)),
        pl.BlockSpec((block_n, k), lambda o, j: (j, 0)),
        pl.BlockSpec((1, block_n), lambda o, j: (0, j)),
    ]
    if scaled:
        in_specs.append(pl.BlockSpec((1, block_n), lambda o, j: (0, j)))
    out = pl.pallas_call(
        functools.partial(_coa_kernel, grid_axis=1, scaled=scaled, o_axis=0,
                          block_o=block_o),
        grid=(dop // block_o, ap // block_n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bp, block_o), lambda o, j: (0, o)),
        out_shape=jax.ShapeDtypeStruct((bp, dop), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:b, :d_out]


def condensed_over_active_matmul(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    out_index: jax.Array,
    d_out: int,
    *,
    scales: jax.Array | None = None,
    block_b: int | None = None,
    block_n: int | None = None,
    block_o: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused condensed-over-active matmul: the condensed gather runs over the
    ``a <= d_out`` surviving rows and the output block is written through
    ``out_index`` directly (ablated rows zero-filled in-kernel). Token-
    identical to the old compose-then-scatter lowering — the same f32
    accumulation, the same single downcast, the same drop semantics for
    out-of-range padding rows — without the separate scatter dispatch or the
    compact-activation HBM round trip.

    ``scales`` (shape (a,), f32) marks ``values`` as int8/fp8 codes; the
    dequantize fuses into the kernel. ``block_o`` tiles the scatter
    epilogue over d_out (see module docstring).
    """
    b, d_in = x.shape
    a, k = values.shape
    if interpret is None:
        interpret = cm.default_interpret()
    if block_b is None and b <= SMALL_BATCH_MAX:
        return condensed_over_active_matmul_decode(
            x, values, indices, out_index, d_out, scales=scales,
            block_n=block_n, block_o=block_o, interpret=interpret)
    if block_b is None and block_n is None:
        block_b, block_n = default_coa_blocks(b, d_in, a, k, d_out)
    elif block_b is None:
        block_b = cm._fit_block_b(
            lambda bb, bn, _d, _k: coa_vmem_words(bb, bn, d_in, k, d_out),
            block_n, b, d_in, k, cap=128)
    elif block_n is None:
        block_n = cm._fit_block_n(
            lambda bb, bn, _d, _k: coa_vmem_words(bb, bn, d_in, k, d_out),
            block_b, a, d_in, k, cap=128)
    return _coa_tiled(x, values, indices, out_index, scales, d_out=d_out,
                      block_b=block_b, block_n=block_n, block_o=block_o,
                      interpret=interpret)


def condensed_over_active_matmul_decode(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    out_index: jax.Array,
    d_out: int,
    *,
    scales: jax.Array | None = None,
    block_n: int | None = None,
    block_o: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode-specialized fused COA matmul (batch staged whole)."""
    b, d_in = x.shape
    a, k = values.shape
    if interpret is None:
        interpret = cm.default_interpret()
    if block_n is None:
        _, block_n = default_coa_blocks(min(b, SMALL_BATCH_MAX), d_in, a, k,
                                        d_out)
    return _coa_decode(x, values, indices, out_index, scales, d_out=d_out,
                       block_n=block_n, block_o=block_o, interpret=interpret)
