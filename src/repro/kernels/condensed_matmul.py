"""Pallas TPU kernel: condensed constant fan-in matmul (forward + dw).

TPU adaptation of the paper's Alg. 1 (a CPU loop / CUDA gather kernel):

* The whole input-feature axis of the current batch tile is staged in VMEM
  (``x_tile: (B_blk, d_in)``) so the per-neuron gathers are VMEM-local — the
  TPU analogue of CUDA shared-memory gathers. HBM traffic for the weights is
  exactly ``2 * n_out * k`` words (values + indices): sparsity converts
  directly into HBM-byte savings, which is what matters for the bandwidth-
  bound decode/online-inference shapes this kernel targets.
* Grid is (batch tiles x neuron tiles); each grid step gathers
  ``x_tile[:, idx_tile]`` -> (B_blk, N_blk, k) on the VPU and reduces over k.
* Block sizes default to MXU/VPU-aligned multiples (8 sublanes x 128 lanes);
  ``d_in`` is NOT blocked (constant fan-in indices may reference any input
  feature), so VMEM budget is ``B_blk*d_in + N_blk*k*2 + B_blk*N_blk`` words
  — callers pick ``B_blk`` so this fits (~16 MiB/core VMEM on v5e).

Validated against ``ref.condensed_matmul_ref`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, w_ref, idx_ref, out_ref):
    """One (B_blk, N_blk) output tile.

    x_ref   : (B_blk, d_in)    VMEM
    w_ref   : (N_blk, k)       VMEM
    idx_ref : (N_blk, k)       VMEM (int32)
    out_ref : (B_blk, N_blk)   VMEM
    """
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    idx = idx_ref[...]
    n_blk, k = idx.shape
    # VMEM-local gather: (B_blk, N_blk * k) -> (B_blk, N_blk, k)
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    acc = jnp.sum(gathered * w[None], axis=-1)  # f32 accumulate
    out_ref[...] = acc.astype(out_ref.dtype)


def _dw_kernel(dy_ref, x_ref, idx_ref, dw_ref):
    """dw tile: dw[n, k] = sum_b dy[b, n] * x[b, idx[n, k]].

    dy_ref : (B, N_blk), x_ref : (B, d_in), idx_ref : (N_blk, k).
    Full batch is reduced in one grid step (grid over neuron tiles only).
    """
    dy = dy_ref[...].astype(jnp.float32)
    x = x_ref[...]
    idx = idx_ref[...]
    n_blk, k = idx.shape
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    dw_ref[...] = jnp.einsum("bn,bnk->nk", dy, gathered).astype(dw_ref.dtype)


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def condensed_matmul(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    *,
    block_b: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Forward condensed matmul via pallas_call. Shapes as in ref.py."""
    b, d_in = x.shape
    n_out, k = values.shape
    bp, np_ = _ceil_to(max(b, 1), block_b), _ceil_to(n_out, block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wp = jnp.pad(values, ((0, np_ - n_out), (0, 0)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, np_ - n_out), (0, 0)))

    out = pl.pallas_call(
        _fwd_kernel,
        grid=(bp // block_b, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, ip)
    return out[:b, :n_out]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def condensed_matmul_dw(
    dy: jax.Array,
    x: jax.Array,
    indices: jax.Array,
    *,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Backward-wrt-values kernel. dy: (B, n_out), x: (B, d_in) -> (n_out, k)."""
    b, d_in = x.shape
    n_out, k = indices.shape
    np_ = _ceil_to(n_out, block_n)
    dyp = jnp.pad(dy, ((0, 0), (0, np_ - n_out)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, np_ - n_out), (0, 0)))

    dw = pl.pallas_call(
        _dw_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((b, block_n), lambda j: (0, j)),
            pl.BlockSpec((b, d_in), lambda j: (0, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, k), values_dtype(dy)),
        interpret=interpret,
    )(dyp, x, ip)
    return dw[:n_out]


def values_dtype(dy: jax.Array):
    # Gradients accumulate in f32 regardless of activation dtype.
    return jnp.float32 if dy.dtype in (jnp.bfloat16, jnp.float16) else dy.dtype
