"""Pallas TPU kernel: condensed constant fan-in matmul (forward + dw).

TPU adaptation of the paper's Alg. 1 (a CPU loop / CUDA gather kernel):

* The whole input-feature axis of the current batch tile is staged in VMEM
  (``x_tile: (B_blk, d_in)``) so the per-neuron gathers are VMEM-local — the
  TPU analogue of CUDA shared-memory gathers. HBM traffic for the weights is
  exactly ``2 * n_out * k`` words (values + indices): sparsity converts
  directly into HBM-byte savings, which is what matters for the bandwidth-
  bound decode/online-inference shapes this kernel targets. The quantized
  variant (``scales`` passed) streams values at ONE byte per element
  (int8/fp8) plus ``4 * n_out`` bytes of per-neuron f32 scales; the
  dequantization is fused into the gather-reduce (one multiply per output
  element, after the k-reduction — exact, since the scale is per output
  neuron), so the HBM weight stream shrinks ~4x with no extra passes.
* Grid is (batch tiles x neuron tiles); each grid step gathers
  ``x_tile[:, idx_tile]`` -> (B_blk, N_blk, k) on the VPU and reduces over k.
* ``d_in`` is NOT blocked (constant fan-in indices may reference any input
  feature), so the block shape must satisfy the VMEM budget

      forward:  B_blk*d_in + N_blk*k*2 + B_blk*N_blk          words
      dw:       B_blk*N_blk + B_blk*d_in + 2*N_blk*k          words
                (dy tile      x tile       idx tile + dw tile)

  against the per-backend VMEM cap. The 16 MiB/core figure in ``VMEM_BYTES``
  is the published v5e (and v4) per-core VMEM size; Mosaic's ACTUAL
  per-kernel budget is the scoped-VMEM limit the compiler enforces
  (``pltpu.CompilerParams(vmem_limit_bytes=...)`` /
  ``xla_tpu_scoped_vmem_limit_kib``), which defaults to less than the full
  core VMEM — that is why only ``VMEM_USABLE_FRACTION`` (half) of the cap is
  budgeted here, leaving room for double buffering and compiler temporaries.
  On parts with a different VMEM size, or to mirror an explicitly lowered
  ``vmem_limit_bytes``, override the cap with ``REPRO_VMEM_CAP_BYTES``
  (bytes; the usable fraction still applies). The budget formulas charge
  every tile at 4 B/elem even for 1-byte quantized values — conservative by
  ``3 * N_blk * k`` bytes, so a block that fits at f32 always fits
  quantized. ``block_candidates`` / ``dw_block_candidates`` enumerate the
  8x128-aligned shapes that fit; ``default_blocks`` picks an untimed default
  and ``repro.sparse.autotune`` runs the timed search.
* Decode shapes (B <= 8) use a specialized variant: the grid runs over
  neuron tiles only and the (sublane-padded) batch is staged whole, so a
  B=1 request does not pay for a 128-row batch tile of padding.
* The dw kernel is blocked over batch tiles (accumulating into the output
  block across the innermost grid dimension), so large-batch training shapes
  never stage the full batch in VMEM.
* ``interpret`` is auto-selected from the backend (interpret only on CPU);
  ``REPRO_PALLAS_INTERPRET={0,1}`` overrides in either direction.

Validated against ``ref.condensed_matmul_ref`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU tiling units (f32): last dim 128 lanes, second-to-last 8 sublanes.
LANE = 128
SUBLANE = 8

# Decode-specialized variant threshold: at or below this batch the whole
# (sublane-padded) batch is staged in VMEM and the grid runs over neuron
# tiles only.
SMALL_BATCH_MAX = 8

# Per-backend VMEM capacity in bytes. CPU (interpret mode) has no hard cap,
# but uses the TPU budget so block choices transfer to the real target.
VMEM_BYTES = {"tpu": 16 * 2**20, "gpu": 16 * 2**20, "cpu": 16 * 2**20}
# Fraction of VMEM one grid step's working set may occupy (the rest is left
# for double buffering of the next blocks and compiler temporaries).
VMEM_USABLE_FRACTION = 0.5

_WORD = 4  # f32 values / int32 indices; bf16 inputs still accumulate in f32


def default_interpret(backend: str | None = None) -> bool:
    """Interpret-mode default: only on CPU (no Mosaic lowering there).

    ``REPRO_PALLAS_INTERPRET`` overrides in either direction (``0`` forces
    compiled lowering, anything else forces the interpreter) — the escape
    hatch for debugging compiled kernels on TPU or forcing interpret in CI.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return (backend or jax.default_backend()) == "cpu"


def vmem_budget_bytes(backend: str | None = None) -> int:
    """Usable per-kernel VMEM budget in bytes.

    ``REPRO_VMEM_CAP_BYTES`` overrides the per-backend cap (use it on parts
    whose VMEM differs from the 16 MiB v5e figure, or to mirror an explicit
    ``pltpu.CompilerParams(vmem_limit_bytes=...)``); the usable fraction
    still applies on top, preserving double-buffering headroom.
    """
    env = os.environ.get("REPRO_VMEM_CAP_BYTES")
    if env:
        cap = int(env)
    else:
        cap = VMEM_BYTES.get(backend or jax.default_backend(),
                             VMEM_BYTES["tpu"])
    return int(cap * VMEM_USABLE_FRACTION)


def fwd_vmem_words(block_b: int, block_n: int, d_in: int, k: int) -> int:
    """Forward working set: x tile + (values + indices) tiles + out tile."""
    return block_b * d_in + block_n * k * 2 + block_b * block_n


def dw_vmem_words(block_b: int, block_n: int, d_in: int, k: int) -> int:
    """dw working set: dy tile + x tile + indices tile + dw accumulator."""
    return block_b * block_n + block_b * d_in + 2 * block_n * k


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


_BLOCK_B_CANDIDATES = (8, 16, 32, 64, 128, 256, 512)
_BLOCK_N_CANDIDATES = (128, 256, 512, 1024)


def _aligned_candidates(words_fn, b: int, d_in: int, n_out: int, k: int,
                        backend: str | None) -> list[tuple[int, int]]:
    """All 8x128-aligned (block_b, block_n) shapes whose ``words_fn`` working
    set fits the VMEM budget. Blocks larger than the padded problem dims are
    excluded (they only add padding work). Always returns at least one
    shape: the (8, 128) minimum is kept even if the budget formula rejects
    it, because ``d_in`` is structurally unblocked — a problem too large at
    minimum blocks needs a different kernel, not a smaller tile.
    """
    budget = vmem_budget_bytes(backend)
    bp = _ceil_to(max(b, 1), SUBLANE)
    np_ = _ceil_to(max(n_out, 1), LANE)
    out = []
    for bb in _BLOCK_B_CANDIDATES:
        if bb > bp and bb != SUBLANE:
            continue
        for bn in _BLOCK_N_CANDIDATES:
            if bn > np_ and bn != LANE:
                continue
            if words_fn(bb, bn, d_in, k) * _WORD <= budget:
                out.append((bb, bn))
    if not out:
        out.append((SUBLANE, LANE))
    return out


def block_candidates(b: int, d_in: int, n_out: int, k: int, *,
                     backend: str | None = None) -> list[tuple[int, int]]:
    """Forward-kernel candidates (see _aligned_candidates / fwd_vmem_words)."""
    return _aligned_candidates(fwd_vmem_words, b, d_in, n_out, k, backend)


def dw_block_candidates(b: int, d_in: int, n_out: int, k: int, *,
                        backend: str | None = None) -> list[tuple[int, int]]:
    """dw-kernel candidates (see _aligned_candidates / dw_vmem_words)."""
    return _aligned_candidates(dw_vmem_words, b, d_in, n_out, k, backend)


def _fit_block_b(words_fn, block_n: int, b: int, d_in: int, k: int, *,
                 backend: str | None = None, cap: int | None = None) -> int:
    """Largest aligned batch tile fitting ``words_fn``'s budget at a FORCED
    neuron tile (any ``block_n``, aligned or not). Floors at the 8-row
    minimum — a caller-forced neuron tile is honored even over budget."""
    budget = vmem_budget_bytes(backend)
    bp = _ceil_to(max(b, 1), SUBLANE)
    best = SUBLANE
    for bb in _BLOCK_B_CANDIDATES:
        if (bb > bp and bb != SUBLANE) or (cap is not None and bb > cap):
            continue
        if words_fn(bb, block_n, d_in, k) * _WORD <= budget:
            best = max(best, bb)
    return best


def _fit_block_n(words_fn, block_b: int, n_out: int, d_in: int, k: int, *,
                 backend: str | None = None, cap: int | None = None) -> int:
    """Mirror of _fit_block_b: largest aligned neuron tile fitting the
    budget at a FORCED batch tile, flooring at the 128-lane minimum."""
    budget = vmem_budget_bytes(backend)
    np_ = _ceil_to(max(n_out, 1), LANE)
    best = LANE
    for bn in _BLOCK_N_CANDIDATES:
        if (bn > np_ and bn != LANE) or (cap is not None and bn > cap):
            continue
        if words_fn(block_b, bn, d_in, k) * _WORD <= budget:
            best = max(best, bn)
    return best


def pick_default_blocks(cands: list[tuple[int, int]], b: int,
                        n_out: int) -> tuple[int, int]:
    """Default-block policy shared by every kernel family: the legacy
    128x128 when it is among ``cands``, otherwise the largest fitting
    candidate (closest to the target first, then raw area)."""
    target = (min(128, _ceil_to(max(b, 1), SUBLANE)),
              min(128, _ceil_to(max(n_out, 1), LANE)))
    if target in cands:
        return target
    return max(cands, key=lambda c: (min(c[0], target[0]) * min(c[1], target[1]),
                                     c[0] * c[1]))


def default_blocks(b: int, d_in: int, n_out: int, k: int, *,
                   backend: str | None = None) -> tuple[int, int]:
    """Untimed default block shape: the legacy 128x128 when it fits the VMEM
    budget, otherwise the largest fitting candidate (batch dim shrinks first
    — the ``B_blk * d_in`` x-tile term is what blows the budget at large
    ``d_in``). The timed search in repro.sparse.autotune refines this."""
    return pick_default_blocks(block_candidates(b, d_in, n_out, k,
                                                backend=backend), b, n_out)


def default_dw_blocks(b: int, d_in: int, n_out: int, k: int, *,
                      backend: str | None = None) -> tuple[int, int]:
    """Largest fitting dw block: stage as much batch per step as the budget
    allows (fewer accumulation passes over the output block), neuron tile at
    the legacy 128 when possible."""
    cands = dw_block_candidates(b, d_in, n_out, k, backend=backend)
    bn_target = min(128, _ceil_to(max(n_out, 1), LANE))
    with_bn = [c for c in cands if c[1] == bn_target] or cands
    return max(with_bn, key=lambda c: c[0])


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w_ref, idx_ref, out_ref):
    """One (B_blk, N_blk) output tile.

    x_ref   : (B_blk, d_in)    VMEM
    w_ref   : (N_blk, k)       VMEM
    idx_ref : (N_blk, k)       VMEM (int32)
    out_ref : (B_blk, N_blk)   VMEM
    """
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    idx = idx_ref[...]
    n_blk, k = idx.shape
    # VMEM-local gather: (B_blk, N_blk * k) -> (B_blk, N_blk, k)
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    acc = jnp.sum(gathered * w[None], axis=-1)  # f32 accumulate
    out_ref[...] = acc.astype(out_ref.dtype)


def _fwd_scaled_kernel(x_ref, w_ref, idx_ref, scale_ref, out_ref):
    """Quantized variant of ``_fwd_kernel``: ``w_ref`` holds int8/fp8 codes
    and ``scale_ref`` a (1, N_blk) tile of per-neuron f32 scales. The scale
    multiply is applied AFTER the k-reduction — exact (the scale is constant
    over a neuron's fan-in) and one multiply per output element instead of
    one per weight."""
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    idx = idx_ref[...]
    n_blk, k = idx.shape
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    acc = jnp.sum(gathered * w[None], axis=-1)  # f32 accumulate
    acc = acc * scale_ref[...].astype(jnp.float32)  # (1, N_blk) broadcast
    out_ref[...] = acc.astype(out_ref.dtype)


def _dw_kernel(dy_ref, x_ref, idx_ref, dw_ref):
    """dw tile: dw[n, k] = sum_b dy[b, n] * x[b, idx[n, k]].

    dy_ref : (B_blk, N_blk), x_ref : (B_blk, d_in), idx_ref : (N_blk, k).
    Grid is (neuron tiles, batch tiles) with batch innermost: the output
    block stays resident while batch tiles accumulate into it, so the full
    batch is never staged in VMEM at once (see dw_vmem_words).
    """
    i = pl.program_id(1)
    dy = dy_ref[...].astype(jnp.float32)
    x = x_ref[...]
    idx = idx_ref[...]
    n_blk, k = idx.shape
    gathered = jnp.take(x, idx.reshape(-1), axis=1).astype(jnp.float32)
    gathered = gathered.reshape(x.shape[0], n_blk, k)
    contrib = jnp.einsum("bn,bnk->nk", dy, gathered).astype(dw_ref.dtype)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = contrib

    @pl.when(i != 0)
    def _accumulate():
        dw_ref[...] += contrib


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def _fwd_tiled(x, values, indices, scales=None, *, block_b: int, block_n: int,
               interpret: bool):
    """General forward: grid over (batch tiles, neuron tiles). ``scales``
    (per-neuron f32, quantized values) adds a (1, block_n) tile and routes
    to the dequant-fused kernel."""
    b, d_in = x.shape
    n_out, k = values.shape
    bp, np_ = _ceil_to(max(b, 1), block_b), _ceil_to(n_out, block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wp = jnp.pad(values, ((0, np_ - n_out), (0, 0)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, np_ - n_out), (0, 0)))

    in_specs = [
        pl.BlockSpec((block_b, d_in), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
    ]
    operands = [xp, wp, ip]
    kernel = _fwd_kernel
    if scales is not None:
        sp = jnp.pad(scales.astype(jnp.float32),
                     (0, np_ - n_out)).reshape(1, np_)
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j: (0, j)))
        operands.append(sp)
        kernel = _fwd_scaled_kernel

    out = pl.pallas_call(
        kernel,
        grid=(bp // block_b, np_ // block_n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:b, :n_out]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _fwd_decode(x, values, indices, scales=None, *, block_n: int,
                interpret: bool):
    """Decode-specialized forward: batch staged whole (padded to the 8-row
    sublane unit, not a 128-row batch tile), grid over neuron tiles only."""
    b, d_in = x.shape
    n_out, k = values.shape
    bp, np_ = _ceil_to(max(b, 1), SUBLANE), _ceil_to(n_out, block_n)
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wp = jnp.pad(values, ((0, np_ - n_out), (0, 0)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, np_ - n_out), (0, 0)))

    in_specs = [
        pl.BlockSpec((bp, d_in), lambda j: (0, 0)),
        pl.BlockSpec((block_n, k), lambda j: (j, 0)),
        pl.BlockSpec((block_n, k), lambda j: (j, 0)),
    ]
    operands = [xp, wp, ip]
    kernel = _fwd_kernel
    if scales is not None:
        sp = jnp.pad(scales.astype(jnp.float32),
                     (0, np_ - n_out)).reshape(1, np_)
        in_specs.append(pl.BlockSpec((1, block_n), lambda j: (0, j)))
        operands.append(sp)
        kernel = _fwd_scaled_kernel

    out = pl.pallas_call(
        kernel,
        grid=(np_ // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bp, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:b, :n_out]


def condensed_matmul(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    *,
    scales: jax.Array | None = None,
    block_b: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Forward condensed matmul via pallas_call. Shapes as in ref.py.

    ``block_b=None`` auto-selects: decode shapes (B <= SMALL_BATCH_MAX) go to
    the decode-specialized variant, larger batches get the VMEM-budget
    default (see default_blocks; repro.sparse.autotune supplies timed
    choices). ``interpret=None`` resolves from the backend (CPU only).
    Explicit ``block_b`` forces the general tiled kernel.

    ``scales`` (shape (n_out,), f32) marks ``values`` as quantized codes
    (int8/fp8); dequantization fuses into the kernel epilogue.
    """
    b, d_in = x.shape
    n_out, k = values.shape
    if interpret is None:
        interpret = default_interpret()
    if block_b is None and b <= SMALL_BATCH_MAX:
        return condensed_matmul_decode(x, values, indices, scales=scales,
                                       block_n=block_n, interpret=interpret)
    if block_b is None and block_n is None:
        block_b, block_n = default_blocks(b, d_in, n_out, k)
    elif block_b is None:
        # a forced neuron tile re-sizes the batch tile against the SAME
        # budget (a 128-sized default could overflow VMEM at large block_n)
        block_b = _fit_block_b(fwd_vmem_words, block_n, b, d_in, k, cap=128)
    elif block_n is None:
        block_n = _fit_block_n(fwd_vmem_words, block_b, n_out, d_in, k,
                               cap=128)
    return _fwd_tiled(x, values, indices, scales, block_b=block_b,
                      block_n=block_n, interpret=interpret)


def condensed_matmul_decode(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    *,
    scales: jax.Array | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode-specialized condensed matmul (batch staged whole).

    Bit-identical to the general kernel: the per-row reduction over k is
    independent of how the batch axis is padded or tiled. Intended for
    B <= SMALL_BATCH_MAX but correct for any batch that fits VMEM."""
    b, d_in = x.shape
    n_out, k = values.shape
    if interpret is None:
        interpret = default_interpret()
    if block_n is None:
        _, block_n = default_blocks(min(b, SMALL_BATCH_MAX), d_in, n_out, k)
    return _fwd_decode(x, values, indices, scales, block_n=block_n,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def _dw_tiled(dy, x, indices, *, block_b: int, block_n: int, interpret: bool):
    b, d_in = x.shape
    n_out, k = indices.shape
    bp, np_ = _ceil_to(max(b, 1), block_b), _ceil_to(n_out, block_n)
    dyp = jnp.pad(dy, ((0, bp - b), (0, np_ - n_out)))
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    ip = jnp.pad(indices.astype(jnp.int32), ((0, np_ - n_out), (0, 0)))

    # batch tiles innermost (last grid dim iterates fastest): the (block_n, k)
    # output block stays resident across the accumulation
    dw = pl.pallas_call(
        _dw_kernel,
        grid=(np_ // block_n, bp // block_b),
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda j, i: (i, j)),
            pl.BlockSpec((block_b, d_in), lambda j, i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda j, i: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, k), values_dtype(dy)),
        interpret=interpret,
    )(dyp, xp, ip)
    return dw[:n_out]


def condensed_matmul_dw(
    dy: jax.Array,
    x: jax.Array,
    indices: jax.Array,
    *,
    block_b: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Backward-wrt-values kernel. dy: (B, n_out), x: (B, d_in) -> (n_out, k).

    Blocked over batch tiles (``block_b``), accumulating into the output
    block, so large-batch training shapes never stage the full batch in
    VMEM; the working set per grid step is ``dw_vmem_words`` words. Defaults
    stage the largest batch tile the VMEM budget allows.
    """
    b, d_in = x.shape
    n_out, k = indices.shape
    if interpret is None:
        interpret = default_interpret()
    if block_b is None and block_n is None:
        block_b, block_n = default_dw_blocks(b, d_in, n_out, k)
    elif block_b is None:
        # size the batch tile against the dw budget AT the forced neuron
        # tile — default_dw_blocks assumes a 128-wide tile and its block_b
        # could overflow VMEM when combined with a larger caller block_n
        block_b = _fit_block_b(dw_vmem_words, block_n, b, d_in, k)
    elif block_n is None:
        block_n = _fit_block_n(dw_vmem_words, block_b, n_out, d_in, k,
                               cap=128)
    return _dw_tiled(dy, x, indices, block_b=block_b, block_n=block_n,
                     interpret=interpret)


def values_dtype(dy: jax.Array):
    # Gradients accumulate in f32 regardless of activation dtype.
    return jnp.float32 if dy.dtype in (jnp.bfloat16, jnp.float16) else dy.dtype
