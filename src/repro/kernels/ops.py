"""Jit'd public wrappers for the condensed kernels, with custom VJP.

``condensed_linear`` is the layer-level op used by repro.sparse.condensed:
forward runs the Pallas kernel; the backward pass computes

  dx = scatter-add of dy * values   (jnp; XLA lowers this well on TPU)
  dw = Pallas dw kernel (gather formulation, batch-tiled, no scatter needed)

The condensed path is inference-first (decode / online serving); training uses
the masked-dense MXU path (repro.sparse.masked), so the jnp dx here is not on
the training hot path.

Block-shape resolution (when the caller does not force one): the tuned
winner from repro.sparse.autotune's persistent cache for this backend +
shape + batch bucket, else the untimed VMEM-budget default inside
kernels.condensed_matmul (which also routes B <= 8 to the decode-specialized
variant). ``interpret`` resolves from the backend — interpret-mode only on
CPU, overridable with REPRO_PALLAS_INTERPRET={0,1}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import condensed_matmul as cm
from repro.kernels import ref


def _resolve_blocks(batch: int, d_in: int, n_out: int, k: int,
                    block_b, block_n, itemsize: int):
    """Caller-forced blocks win; else the autotune cache; else (None, None)
    so kernels.condensed_matmul applies its VMEM-budget default.

    The cache key is derived through the format protocol
    (``formats.shape_tuning_key`` — the same derivation the formats'
    ``tuning_key`` methods and ``autotune.tune_registry`` use, so a tuned
    entry written under a format's key is exactly what this dispatch reads
    back). The cache is consulted only when NEITHER dim is forced: a tuned
    winner was validated as a PAIR, so splicing one of its dims against an
    arbitrary caller-forced other dim could exceed the VMEM budget — with a
    half-forced call the remaining dim goes to the kernel module's budget
    fit instead."""
    if block_b is not None or block_n is not None:
        return block_b, block_n
    # lazy imports: keep kernels importable alone
    from repro.sparse import autotune
    from repro.sparse import formats
    tuned = autotune.lookup_entry(
        formats.shape_tuning_key(d_in, n_out, k, batch, itemsize=itemsize))
    if tuned is not None:
        return tuned["block_b"], tuned["block_n"]
    return None, None


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def condensed_linear(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """y[b, n] = sum_k x[b, indices[n, k]] * values[n, k]."""
    bb, bn = _resolve_blocks(x.shape[0], x.shape[-1], *values.shape,
                             block_b, block_n, jnp.dtype(x.dtype).itemsize)
    return cm.condensed_matmul(x, values, indices, block_b=bb, block_n=bn)


def _fwd(x, values, indices, block_b, block_n):
    y = condensed_linear(x, values, indices, block_b, block_n)
    return y, (x, values, indices)


def _bwd(block_b, block_n, res, dy):
    x, values, indices = res
    dx = ref.condensed_matmul_dx_ref(dy, values, indices, x.shape[-1]).astype(x.dtype)
    dw = cm.condensed_matmul_dw(dy, x, indices, block_n=block_n)
    return dx, dw.astype(values.dtype), None


condensed_linear.defvjp(_fwd, _bwd)


def condensed_linear_nd(x: jax.Array, values: jax.Array, indices: jax.Array, **kw) -> jax.Array:
    """Rank-polymorphic wrapper: flattens leading dims to the batch axis."""
    lead = x.shape[:-1]
    y = condensed_linear(x.reshape(-1, x.shape[-1]), values, indices, **kw)
    return y.reshape(*lead, values.shape[0])


def condensed_over_active_linear_nd(x: jax.Array, values: jax.Array,
                                    indices: jax.Array, out_index: jax.Array,
                                    d_out: int, **kw) -> jax.Array:
    """Composed Fig. 4 representation: condensed gather over ACTIVE rows only.

    values/indices: (a, k) condensed arrays covering only surviving (non-
    ablated) neurons; out_index: (a,) int32 position of each surviving row in
    the full (d_out,) output, with out-of-range entries (== d_out) marking
    padding rows. The gather kernel runs over a <= d_out rows — the ablated-
    neuron fraction converts directly into fewer HBM bytes AND fewer gather
    FLOPs — and the result is scattered into the dense output layout (ablated
    neurons are exact zeros, so greedy decode stays token-identical to the
    masked path).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y_act = condensed_linear(x2, values, indices, **kw)      # (B, a)
    y = jnp.zeros((x2.shape[0], d_out), y_act.dtype)
    # active rows are unique, padding rows point at d_out -> dropped
    y = y.at[:, out_index].add(y_act, mode="drop")
    return y.reshape(*lead, d_out)


def structured_dense(x: jax.Array, weight: jax.Array, neuron_active: jax.Array) -> jax.Array:
    """"Structured-only" path from Fig. 4: drop ablated neurons, dense matmul.

    weight: (d_in, n_out); computes x @ weight with ablated outputs forced to
    exact zeros. NOTE: as implemented this reads the full dense weight and
    runs the full matmul — the only traffic saved vs masked is the bool
    fan-in mask (neuron_active is n_out bools). A genuinely column-gathered
    kernel that delivers the active-fraction byte/FLOP saving is a ROADMAP
    follow-up; the cost model in repro.sparse.plan prices this path at what
    it actually executes.
    """
    w = weight * neuron_active[None, :].astype(weight.dtype)
    return x @ w
