"""Jit'd public wrappers for the sparse serving kernels, with custom VJPs.

``condensed_linear`` is the layer-level op used by repro.sparse.condensed:
forward runs the Pallas kernel; the backward pass computes

  dx = scatter-add of dy * values   (jnp; XLA lowers this well on TPU)
  dw = Pallas dw kernel (gather formulation, batch-tiled, no scatter needed)

``structured_linear`` is the layer-level op behind the StructuredFanIn
format (column-gathered Pallas matmul + fused scatter epilogue from
kernels.structured_matmul), and ``condensed_over_active_linear_nd`` runs the
FUSED condensed-over-active kernel (output written through out_index inside
the kernel — no standalone scatter dispatch on the decode path).

All three are inference-first (decode / online serving); training uses the
masked-dense MXU path (repro.sparse.masked), so the jnp backward pieces here
are not on the training hot path.

Block-shape resolution (when the caller does not force one): the tuned
winner from repro.sparse.autotune's persistent cache for this backend +
shape + batch bucket (keys derive from ``formats.shape_tuning_key`` — the
structured kernel's keys carry ``kind="structured"``), else the untimed
VMEM-budget default inside the kernel module (which also routes B <= 8 to
the decode-specialized variants). ``interpret`` resolves from the backend —
interpret-mode only on CPU, overridable with REPRO_PALLAS_INTERPRET={0,1}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import condensed_matmul as cm
from repro.kernels import ref
from repro.kernels import structured_matmul as sm


def _resolve_blocks(batch: int, d_in: int, n_out: int, k: int,
                    block_b, block_n, itemsize: int, kind: str = "condensed",
                    scatter_width: int | None = None,
                    values_dtype: str | None = None):
    """Caller-forced blocks win; else the autotune cache; else (None, None)
    so the kernel module applies its VMEM-budget default.

    The cache key is derived through the format protocol
    (``formats.shape_tuning_key`` — the same derivation the formats'
    ``tuning_key`` methods and ``autotune.tune_registry`` use, so a tuned
    entry written under a format's key is exactly what this dispatch reads
    back). ``kind``/``scatter_width`` select the ablation-aware kernels' key
    spaces ("structured" and "coa" entries are timed on THOSE kernels, whose
    VMEM geometry includes the dense scatter width — see
    ``formats.shape_tuning_key``). The cache is consulted only when NEITHER
    dim is forced: a tuned winner was validated as a PAIR, so splicing one
    of its dims against an arbitrary caller-forced other dim could exceed
    the VMEM budget — with a half-forced call the remaining dim goes to the
    kernel module's budget fit instead. ``values_dtype`` (quantized storage:
    "int8"/"fp8") selects the quantized key space — quantized shapes are
    timed on the dequant-fused kernels, whose balance differs."""
    if block_b is not None or block_n is not None:
        return block_b, block_n
    # lazy imports: keep kernels importable alone
    from repro.sparse import autotune
    from repro.sparse import formats
    tuned = autotune.lookup_entry(
        formats.shape_tuning_key(d_in, n_out, k, batch, itemsize=itemsize,
                                 kind=kind, scatter_width=scatter_width,
                                 values_dtype=values_dtype))
    if tuned is not None:
        return tuned["block_b"], tuned["block_n"]
    return None, None


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def condensed_linear(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """y[b, n] = sum_k x[b, indices[n, k]] * values[n, k]."""
    bb, bn = _resolve_blocks(x.shape[0], x.shape[-1], *values.shape,
                             block_b, block_n, jnp.dtype(x.dtype).itemsize)
    return cm.condensed_matmul(x, values, indices, block_b=bb, block_n=bn)


def _fwd(x, values, indices, block_b, block_n):
    y = condensed_linear(x, values, indices, block_b, block_n)
    return y, (x, values, indices)


def _bwd(block_b, block_n, res, dy):
    x, values, indices = res
    dx = ref.condensed_matmul_dx_ref(dy, values, indices, x.shape[-1]).astype(x.dtype)
    dw = cm.condensed_matmul_dw(dy, x, indices, block_n=block_n)
    return dx, dw.astype(values.dtype), None


condensed_linear.defvjp(_fwd, _bwd)


def _quantized_name(values: jax.Array) -> str:
    """Tuning-key tag for quantized storage ("int8" / "fp8")."""
    from repro.sparse import formats
    return formats.resolve_quantize_spec(values.dtype)


def condensed_linear_nd(x: jax.Array, values: jax.Array, indices: jax.Array,
                        *, scales: jax.Array | None = None, **kw) -> jax.Array:
    """Rank-polymorphic wrapper: flattens leading dims to the batch axis.

    ``scales`` marks ``values`` as int8/fp8 codes and routes to the
    dequant-fused kernel (inference-only: no custom VJP — quantized storage
    is a serving artifact, training runs the masked path)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if scales is None:
        y = condensed_linear(x2, values, indices, **kw)
        return y.reshape(*lead, values.shape[0])
    bb, bn = _resolve_blocks(x2.shape[0], x2.shape[-1], *values.shape,
                             kw.get("block_b"), kw.get("block_n"),
                             jnp.dtype(x.dtype).itemsize,
                             values_dtype=_quantized_name(values))
    y = cm.condensed_matmul(x2, values, indices, scales=scales,
                            block_b=bb, block_n=bn)
    return y.reshape(*lead, values.shape[0])


def _dy_active(dy, out_index, d_out: int):
    """Gather dy at the surviving rows' dense positions; padding rows
    (out_index == d_out) get exact-zero cotangents — the drop semantics of
    the fused scatter epilogue."""
    sel = jnp.take(dy, jnp.minimum(out_index, d_out - 1), axis=1)
    return sel * (out_index < d_out)[None, :].astype(sel.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def condensed_over_active_linear(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    out_index: jax.Array,
    d_out: int,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Fused composed Fig. 4 representation: condensed gather over ACTIVE
    rows, output written through out_index inside the kernel.

    values/indices: (a, k) condensed arrays covering only surviving (non-
    ablated) neurons; out_index: (a,) int32 position of each surviving row in
    the full (d_out,) output, with out-of-range entries (== d_out) marking
    padding rows. The kernel runs over a <= d_out rows — the ablated-neuron
    fraction converts directly into fewer HBM bytes AND fewer gather FLOPs —
    and its fused epilogue scatters each row into the dense output layout
    in-kernel (ablated neurons are exact zeros, so greedy decode stays
    token-identical to the masked path). Unlike the previous compose-then-
    scatter lowering there is no standalone ``y.at[:, out_index].add``
    dispatch and no compact-activation HBM round trip per layer.
    """
    a, k = values.shape
    bb, bn = _resolve_blocks(x.shape[0], x.shape[-1], a, k, block_b, block_n,
                             jnp.dtype(x.dtype).itemsize, kind="coa",
                             scatter_width=d_out)
    return sm.condensed_over_active_matmul(x, values, indices, out_index,
                                           d_out, block_b=bb, block_n=bn)


def _coa_fwd(x, values, indices, out_index, d_out, block_b, block_n):
    y = condensed_over_active_linear(x, values, indices, out_index, d_out,
                                     block_b, block_n)
    return y, (x, values, indices, out_index)


def _coa_bwd(d_out, block_b, block_n, res, dy):
    x, values, indices, out_index = res
    dy_act = _dy_active(dy, out_index, d_out)                # (B, a)
    dx = ref.condensed_matmul_dx_ref(dy_act, values, indices,
                                     x.shape[-1]).astype(x.dtype)
    dw = cm.condensed_matmul_dw(dy_act, x, indices, block_n=block_n)
    return dx, dw.astype(values.dtype), None, None


condensed_over_active_linear.defvjp(_coa_fwd, _coa_bwd)


def condensed_over_active_linear_nd(x: jax.Array, values: jax.Array,
                                    indices: jax.Array, out_index: jax.Array,
                                    d_out: int, *,
                                    scales: jax.Array | None = None,
                                    **kw) -> jax.Array:
    """Rank-polymorphic wrapper over the FUSED condensed-over-active kernel
    (flattens leading dims to the batch axis). ``scales`` routes to the
    dequant-fused quantized kernel (inference-only, no custom VJP)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if scales is None:
        y = condensed_over_active_linear(x2, values, indices, out_index,
                                         d_out, **kw)
        return y.reshape(*lead, d_out)
    bb, bn = _resolve_blocks(x2.shape[0], x2.shape[-1], *values.shape,
                             kw.get("block_b"), kw.get("block_n"),
                             jnp.dtype(x.dtype).itemsize, kind="coa",
                             scatter_width=d_out,
                             values_dtype=_quantized_name(values))
    y = sm.condensed_over_active_matmul(x2, values, indices, out_index,
                                        d_out, scales=scales,
                                        block_b=bb, block_n=bn)
    return y.reshape(*lead, d_out)


def condensed_over_active_linear_nd_unfused(
        x: jax.Array, values: jax.Array, indices: jax.Array,
        out_index: jax.Array, d_out: int, **kw) -> jax.Array:
    """Pre-fusion composition (reference): condensed gather over active rows,
    then a separate XLA scatter into the dense layout. Kept as the oracle the
    fused kernel is tested against, and as the lowering whose standalone
    scatter dispatch the fused epilogue provably removes (see the HLO
    dispatch-count test)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y_act = condensed_linear(x2, values, indices, **kw)      # (B, a)
    y = jnp.zeros((x2.shape[0], d_out), y_act.dtype)
    # active rows are unique, padding rows point at d_out -> dropped
    y = y.at[:, out_index].add(y_act, mode="drop")
    return y.reshape(*lead, d_out)


def structured_dense(x: jax.Array, weight: jax.Array, neuron_active: jax.Array) -> jax.Array:
    """Reference "structured-only" path from Fig. 4: drop ablated neurons,
    dense matmul.

    weight: (d_in, n_out); computes x @ weight with ablated outputs forced to
    exact zeros. This is the pure-jnp ORACLE the column-gathered Pallas
    kernel (``structured_linear`` / kernels.structured_matmul) is validated
    against — bit-identical on every active set, including zero ablation,
    all-but-one-ablated, non-tile-aligned active counts and bf16. It reads
    the full dense weight (the formulation the hot path executed before the
    gathered kernel landed); serving dispatches go through
    ``structured_linear``, whose HBM weight bytes and MXU FLOPs scale with
    the active fraction, and whose cost is what
    ``formats.StructuredFanIn.estimate_cost`` prices.
    """
    w = weight * neuron_active[None, :].astype(weight.dtype)
    return x @ w


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def structured_linear(
    x: jax.Array,
    w: jax.Array,
    active_index: jax.Array,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Column-gathered structured matmul: y = x @ w over the surviving
    columns only, ablated outputs exact zeros (fused scatter epilogue).

    ``active_index``: (a_pad,) int32 surviving-column ids, padded to tile
    alignment with the out-of-range sentinel ``d_out`` (see
    ``formats.StructuredFanIn`` / ``structured_matmul.padded_active_count``).
    Exact (bit-identical) to ``structured_dense`` with the matching
    neuron_active bools, for ablation-only masks the exact serving path.
    """
    d_out = w.shape[-1]
    bb, bn = _resolve_blocks(x.shape[0], x.shape[-1], active_index.shape[0],
                             0, block_b, block_n,
                             jnp.dtype(x.dtype).itemsize, kind="structured",
                             scatter_width=d_out)
    return sm.structured_matmul(x, w.astype(x.dtype), active_index,
                                block_b=bb, block_n=bn)


def _structured_fwd(x, w, active_index, block_b, block_n):
    y = structured_linear(x, w, active_index, block_b, block_n)
    return y, (x, w, active_index)


def _structured_bwd(block_b, block_n, res, dy):
    x, w, active_index = res
    d_out = w.shape[-1]
    dy_act = _dy_active(dy, active_index, d_out)             # (B, a_pad)
    w_act = sm._gather_columns(w, active_index).astype(dy_act.dtype)
    dx = (dy_act @ w_act.T).astype(x.dtype)
    # dw: only surviving columns receive gradient (ablated columns are
    # dropped from the forward); padding entries scatter out of range
    contrib = (x.astype(dy_act.dtype).T @ dy_act)            # (d_in, a_pad)
    dw = jnp.zeros_like(w).at[:, active_index].add(
        contrib.astype(w.dtype), mode="drop")
    return dx, dw, None


structured_linear.defvjp(_structured_fwd, _structured_bwd)


def structured_linear_nd(x: jax.Array, w: jax.Array,
                         active_index: jax.Array, **kw) -> jax.Array:
    """Rank-polymorphic wrapper: flattens leading dims to the batch axis."""
    lead = x.shape[:-1]
    y = structured_linear(x.reshape(-1, x.shape[-1]), w, active_index, **kw)
    return y.reshape(*lead, w.shape[-1])


# ---------------------------------------------------------------------------
# tensor-parallel (shard-blocked) execution paths
#
# The TP exports in repro.sparse.formats reorganize each format's arrays into
# ``tp`` contiguous blocks along the neuron axis (global shapes unchanged;
# out_index / active_index locally rebased per block). These wrappers execute
# that layout as a ``jax.vmap`` over the block axis in plain jnp: under GSPMD
# with the block axis sharded over the 'model' mesh axis, every gather /
# matmul / scatter below is shard-local (the activation ``x`` stays
# replicated, so the stored indices are valid on every shard), and the single
# cross-device exchange is the all-gather XLA inserts when the (tp, B, wloc)
# partial outputs are reassembled into the replicated (B, d_out) activation.
# On one device the vmap formulation is just a reshape — bit-identical math —
# which is what makes the sharded stack testable on a simulated mesh.
#
# Pure jnp rather than Pallas: pallas_call is opaque to GSPMD propagation, so
# a sharded Pallas dispatch would need shard_map plumbing through every apply
# call site; the jnp formulation partitions for free and the per-shard shapes
# stay available to the autotune cache keys (formats.tuning_key shrinks by
# 1/tp) for a later shard_map'd kernel. Inference-only: no custom VJPs.
# ---------------------------------------------------------------------------


def condensed_linear_tp_nd(x: jax.Array, values: jax.Array,
                           indices: jax.Array, tp: int, *,
                           scales: jax.Array | None = None) -> jax.Array:
    """Condensed gather over ``tp`` contiguous neuron blocks.

    values/indices: (n, k) with ``n = tp * (n // tp)`` rows grouped by block
    (the plain condensed layout already is — contiguous rows partition).
    ``scales``: optional (n,) per-neuron dequant scales (quantized storage).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    n, k = values.shape
    npt = n // tp
    v = values.reshape(tp, npt, k)
    i = indices.reshape(tp, npt, k)

    def shard(v_s, i_s):
        g = jnp.take(x2, i_s, axis=1)                    # (B, npt, k) local
        return jnp.sum(g * v_s[None].astype(x2.dtype), axis=-1)

    y = jax.vmap(shard)(v, i)                            # (tp, B, npt)
    if scales is not None:
        y = y * scales.reshape(tp, 1, npt).astype(y.dtype)
    return jnp.moveaxis(y, 0, 1).reshape(x2.shape[0], n).reshape(*lead, n)


def condensed_over_active_linear_tp_nd(
        x: jax.Array, values: jax.Array, indices: jax.Array,
        out_index: jax.Array, d_out: int, tp: int, *,
        scales: jax.Array | None = None) -> jax.Array:
    """Condensed-over-active gather + LOCAL scatter over ``tp`` blocks.

    values/indices: (tp * a_tp, k) surviving-row arrays grouped by block;
    ``out_index``: (tp * a_tp,) int32 LOCALLY REBASED scatter positions in
    ``[0, d_out // tp)`` with the per-shard sentinel ``d_out // tp`` marking
    padding rows (dropped by the local scatter).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    a, k = values.shape
    a_tp = a // tp
    wloc = d_out // tp
    v = values.reshape(tp, a_tp, k)
    i = indices.reshape(tp, a_tp, k)
    oi = out_index.reshape(tp, a_tp)
    s = scales.reshape(tp, a_tp) if scales is not None else None

    def shard(v_s, i_s, oi_s, s_s):
        g = jnp.take(x2, i_s, axis=1)                    # (B, a_tp, k) local
        y_act = jnp.sum(g * v_s[None].astype(x2.dtype), axis=-1)
        if s_s is not None:
            y_act = y_act * s_s[None].astype(y_act.dtype)
        y_s = jnp.zeros((x2.shape[0], wloc), y_act.dtype)
        return y_s.at[:, oi_s].add(y_act, mode="drop")   # local positions

    if s is None:
        y = jax.vmap(lambda v_s, i_s, oi_s: shard(v_s, i_s, oi_s, None))(
            v, i, oi)
    else:
        y = jax.vmap(shard)(v, i, oi, s)
    return (jnp.moveaxis(y, 0, 1).reshape(x2.shape[0], d_out)
            .reshape(*lead, d_out))


def structured_linear_tp_nd(x: jax.Array, w: jax.Array,
                            active_index: jax.Array, tp: int) -> jax.Array:
    """Column-gathered structured matmul over ``tp`` output blocks.

    ``w``: the live dense (d_in, d_out) weight (its out dim shards over
    'model' under the standard column-parallel rules, so the block reshape
    keeps the gather shard-local); ``active_index``: (tp * a_tp,) int32
    LOCALLY REBASED surviving-column ids, sentinel ``d_out // tp``.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    d_in, d_out = w.shape
    wloc = d_out // tp
    a_tp = active_index.shape[0] // tp
    wb = jnp.moveaxis(w.reshape(d_in, tp, wloc), 1, 0)   # (tp, d_in, wloc)
    ai = active_index.reshape(tp, a_tp)

    def shard(w_s, ai_s):
        cols = jnp.take(w_s, jnp.minimum(ai_s, wloc - 1), axis=1)
        cols = jnp.where((ai_s < wloc)[None, :], cols, 0).astype(x2.dtype)
        y_act = x2 @ cols                                # (B, a_tp)
        y_s = jnp.zeros((x2.shape[0], wloc), y_act.dtype)
        return y_s.at[:, ai_s].add(y_act, mode="drop")

    y = jax.vmap(shard)(wb, ai)                          # (tp, B, wloc)
    return (jnp.moveaxis(y, 0, 1).reshape(x2.shape[0], d_out)
            .reshape(*lead, d_out))


def structured_gathered_linear_tp_nd(x: jax.Array, panel: jax.Array,
                                     active_index: jax.Array, d_out: int,
                                     tp: int) -> jax.Array:
    """Pre-gathered structured matmul over ``tp`` blocks (quantized
    StructuredFanIn storage: the (d_in, tp * a_tp) panel's columns are
    grouped by block; ``active_index`` locally rebased as above)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    d_in = panel.shape[0]
    wloc = d_out // tp
    a_tp = active_index.shape[0] // tp
    pb = jnp.moveaxis(panel.reshape(d_in, tp, a_tp), 1, 0)  # (tp, d_in, a_tp)
    ai = active_index.reshape(tp, a_tp)

    def shard(p_s, ai_s):
        y_act = x2 @ p_s.astype(x2.dtype)                # (B, a_tp)
        y_s = jnp.zeros((x2.shape[0], wloc), y_act.dtype)
        return y_s.at[:, ai_s].add(y_act, mode="drop")

    y = jax.vmap(shard)(pb, ai)                          # (tp, B, wloc)
    return (jnp.moveaxis(y, 0, 1).reshape(x2.shape[0], d_out)
            .reshape(*lead, d_out))


def structured_gathered_linear_nd(x: jax.Array, panel: jax.Array,
                                  active_index: jax.Array, d_out: int, *,
                                  values_dtype: str | None = None,
                                  **kw) -> jax.Array:
    """Structured matmul over a caller-supplied compact (d_in, a) panel —
    the serving entry for quantized StructuredFanIn storage, whose stored
    representation IS the compact panel (dequantized in XLA before this
    call; no dense weight exists to gather from). Inference-only: no custom
    VJP. Tuned blocks resolve under the same ``kind="structured"`` keys as
    ``structured_linear`` — the kernels are identical, only the gather pass
    is absent."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    bb, bn = _resolve_blocks(x2.shape[0], x2.shape[-1],
                             active_index.shape[0], 0, kw.pop("block_b", None),
                             kw.pop("block_n", None),
                             jnp.dtype(x.dtype).itemsize, kind="structured",
                             scatter_width=d_out, values_dtype=values_dtype)
    y = sm.structured_matmul_pregathered(x2, panel.astype(x.dtype),
                                         active_index, d_out,
                                         block_b=bb, block_n=bn, **kw)
    return y.reshape(*lead, d_out)
