"""Pure-jnp oracles for the condensed constant fan-in kernels.

These are the ground truth the Pallas kernels are validated against
(tests sweep shapes/dtypes and assert_allclose kernel vs oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def condensed_matmul_ref(x: jax.Array, values: jax.Array, indices: jax.Array) -> jax.Array:
    """Condensed constant fan-in matmul (paper Alg. 1 / Eq. 30-31).

    x       : (B, d_in)
    values  : (n_out, k)   non-zero weights per neuron
    indices : (n_out, k)   int — input feature index of each non-zero
    returns : (B, n_out)   out[b, n] = sum_k x[b, indices[n, k]] * values[n, k]
    """
    gathered = jnp.take(x, indices, axis=1)  # (B, n_out, k)
    return jnp.sum(gathered * values[None, :, :].astype(x.dtype), axis=-1)


def condensed_matmul_dx_ref(
    dy: jax.Array, values: jax.Array, indices: jax.Array, d_in: int
) -> jax.Array:
    """Gradient wrt x: scatter-add of dy * values back to input features."""
    b = dy.shape[0]
    n_out, k = values.shape
    contrib = dy[:, :, None] * values[None, :, :].astype(dy.dtype)  # (B, n_out, k)
    flat_idx = indices.reshape(-1)                                  # (n_out*k,)
    dx = jnp.zeros((b, d_in), dy.dtype)
    return dx.at[:, flat_idx].add(contrib.reshape(b, -1))


def condensed_matmul_dw_ref(dy: jax.Array, x: jax.Array, indices: jax.Array) -> jax.Array:
    """Gradient wrt values: dw[n, k] = sum_b dy[b, n] * x[b, indices[n, k]]."""
    gathered = jnp.take(x, indices, axis=1)  # (B, n_out, k)
    return jnp.einsum("bn,bnk->nk", dy, gathered)


def onehot_matmul_ref(x: jax.Array, values: jax.Array, indices: jax.Array) -> jax.Array:
    """MXU-friendly formulation: scatter values to dense then matmul.

    Mathematically identical to condensed_matmul_ref; used as the mid-sparsity
    alternative where the MXU beats the gather path (see DESIGN.md §3).
    """
    n_out, k = values.shape
    d_in = x.shape[-1]
    dense = jnp.zeros((n_out, d_in), values.dtype)
    rows = jnp.repeat(jnp.arange(n_out), k)
    dense = dense.at[rows, indices.reshape(-1)].add(values.reshape(-1))
    return x @ dense.T.astype(x.dtype)
