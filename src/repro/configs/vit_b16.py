"""ViT-B/16 — the paper's own transformer architecture (Table 4).

Encoder-only; patch frontend stubbed (precomputed patch embeddings, 197
tokens for 224x224/16 + CLS). Paper recipe: uniform sparsity distribution,
gamma_sal = 0.95, dense QKV input projections.
"""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="vit-b16", family="vit", causal=False,
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=1, n_classes=1000, frontend="vit", pad_heads_to=16,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.95,
                                distribution="uniform"),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, n_classes=10,
        ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16, dtype="float32",
    )
