"""mamba2-130m — SSD (state-space duality), attn-free [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=1, n_kv_heads=1, head_dim=64,
        d_ff=0, vocab_size=50_280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
        # §Perf pair-2: small SSD chunks shrink the intra-chunk quadratic
        # (B,Q,Q,H) tensors — prefill memory term -12%
        ssd_chunk=64,
        tie_embeddings=True,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, vocab_size=256, ssm_state=16, ssm_head_dim=16,
        ssd_chunk=16, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32",
    )
