"""The assigned input-shape suite (4 cells per LM architecture)."""
from __future__ import annotations

from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}

# long_500k requires sub-quadratic attention: run only for SSM / hybrid /
# sliding-window archs (DESIGN.md §5); pure full-attention archs skip it.
LONG_OK_FAMILIES = ("ssm", "hybrid")
LONG_OK_ARCHS = ("mamba2-130m", "zamba2-7b", "gemma3-1b")


def shapes_for(arch_name: str, family: str, causal: bool = True):
    out = [TRAIN_4K]
    if causal:  # encoder-only archs (ViT) have no decode/prefill cells
        out += [PREFILL_32K, DECODE_32K]
        if arch_name in LONG_OK_ARCHS or family in LONG_OK_FAMILIES:
            out.append(LONG_500K)
    return out
