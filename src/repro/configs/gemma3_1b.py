"""gemma3-1b — 5:1 local:global sliding-window attention [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab_size=262_144,
        sliding_window=512, local_global_ratio=5,
        rope_theta=1_000_000.0, tie_embeddings=True, pad_heads_to=16,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=16, local_global_ratio=2,
        ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16, dtype="float32",
    )
