"""qwen3-1.7b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-1.7b", family="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=6144, vocab_size=151_936, qk_norm=True,
        rope_theta=1_000_000.0, tie_embeddings=True,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32",
    )
