"""qwen2-vl-7b — M-RoPE, dynamic-resolution VLM backbone [arXiv:2409.12191].

The vision frontend is a STUB per assignment: input_specs() supplies
precomputed patch embeddings added to the token embeddings, plus the three
M-RoPE position streams (t, h, w).
"""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
        d_ff=18_944, vocab_size=152_064, mrope=True, pad_heads_to=16,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32",
    )
