"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49_155,
        n_experts=32, top_k_experts=8,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256, n_experts=4, top_k_experts=2,
        moe_group_size=64, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32",
    )
