"""zamba2-7b — Mamba2 blocks + shared attention block [arXiv:2411.15242].

81 Mamba2 layers; one *shared-weight* attention+MLP block is applied after
every 6th Mamba2 layer (13 applications), matching the Zamba2 shared-block
pattern. ssm_state=64.
"""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
        d_ff=14_336, vocab_size=32_000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
        hybrid_attn_every=6,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16,
        hybrid_attn_every=2, ssd_chunk=16,
        ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16, dtype="float32",
    )
