"""internlm2-20b — dense GQA transformer [arXiv:2403.17297]."""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16_384, vocab_size=92_544,
        fsdp=True, param_dtype="bfloat16", optimizer="adafactor",
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32",
    )
