"""Architecture + sparsity + run configuration dataclasses.

One ``ArchConfig`` instance per assigned architecture lives in
``repro/configs/<id>.py``; each also provides a reduced ``smoke()`` variant
for CPU tests. All fields are static (hashable) so configs can parameterize
jit'd functions.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """How SRigL (or a baseline) is applied to the model's linear layers."""

    method: Literal["srigl", "rigl", "set", "dense"] = "srigl"
    sparsity: float = 0.9
    distribution: Literal["erk", "uniform"] = "erk"
    gamma_sal: float = 0.3            # 0.95 for ViT-like (paper Sec 4.3)
    ablation: bool = True
    sparse_qkv: bool = False          # paper keeps MHA input projections dense
    sparse_embeddings: bool = False   # never sparsified in the paper
    delta_t: int = 100
    alpha: float = 0.3                # initial drop fraction
    t_end_fraction: float = 0.75
    grad_accum_for_saliency: int = 1  # paper D.2 uses 8 for ResNet-50


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio", "vit"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4

    # --- hybrid (Zamba2): one shared attention block every N ssm blocks ---
    hybrid_attn_every: int = 6

    # --- attention pattern ---
    qk_norm: bool = False
    sliding_window: int = 0           # 0 = full/global attention
    local_global_ratio: int = 0       # gemma3: 5 local layers per 1 global
    rope_theta: float = 10_000.0
    mrope: bool = False               # qwen2-vl multimodal RoPE (3 position axes)

    # --- modality frontend stubs ---
    frontend: Literal["none", "vlm", "audio", "vit"] = "none"
    n_codebooks: int = 0              # musicgen EnCodec codebooks
    n_classes: int = 0                # ViT classification head

    # --- distribution ---
    fsdp: bool = False   # ZeRO-3: shard the non-TP weight dim over 'data'

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    causal: bool = True               # ViT is encoder-only (False)
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # storage dtype (bf16 for the 100B+ archs)

    # --- perf knobs (hillclimbed in EXPERIMENTS.md §Perf) ---
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    ssd_chunk: int = 256
    moe_group_size: int = 2048
    ce_chunk: int = 512               # chunked cross-entropy (big-vocab archs)
    remat: str = "block"              # "none" | "block" — activation ckpt policy
    microbatches: int = 1             # gradient-accumulation chunks per step
    optimizer: str = "adamw"          # "adamw" | "sgdm" | "adafactor"

    sparsity: SparsityConfig = dataclasses.field(default_factory=SparsityConfig)

    # --- vocab padding ------------------------------------------------------
    # The LM-head vocab axis is padded to a multiple of this so it can be
    # sharded over the TP axis (and MXU-lane aligned). Padded logit columns
    # are masked to -inf in the loss; tokens never index padded rows.
    pad_vocab_to: int = 128

    @property
    def vocab_padded(self) -> int:
        if self.pad_vocab_to and self.vocab_size > 1:
            return -(-self.vocab_size // self.pad_vocab_to) * self.pad_vocab_to
        return self.vocab_size

    # --- tensor-parallel head padding -------------------------------------
    # TP shards the query-head axis; when n_heads % tp_degree != 0 the head
    # count is padded up (padded heads are masked to exact-zero output, so
    # results are bit-identical — see models/attention.py). MHA archs
    # (n_kv_heads == n_heads) pad KV alongside; GQA archs replicate KV.
    pad_heads_to: int = 0             # 0 = no padding

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA group must divide"

    @property
    def n_heads_padded(self) -> int:
        if self.pad_heads_to and self.n_heads % self.pad_heads_to:
            return -(-self.n_heads // self.pad_heads_to) * self.pad_heads_to
        return self.n_heads

    @property
    def n_kv_heads_padded(self) -> int:
        if self.n_kv_heads == self.n_heads:  # MHA: kv pads with q
            return self.n_heads_padded
        return self.n_kv_heads

    @property
    def head_to_kv(self) -> tuple:
        """Static map q-head -> kv-head (padded heads point at kv 0)."""
        g = self.n_heads // self.n_kv_heads
        base = [h // g for h in range(self.n_heads)]
        if self.n_kv_heads == self.n_heads:
            base += list(range(self.n_heads, self.n_heads_padded))
        else:
            base += [0] * (self.n_heads_padded - self.n_heads)
        return tuple(base)

    @property
    def q_dim(self) -> int:
        return self.n_heads_padded * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads_padded * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def window_for_layer(self, layer: int) -> int:
        """Per-layer attention window (gemma3 local:global interleave)."""
        if self.local_global_ratio and self.sliding_window:
            # every (ratio+1)-th layer is global
            return 0 if (layer % (self.local_global_ratio + 1) == self.local_global_ratio) \
                else self.sliding_window
        return self.sliding_window

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the evaluation grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch
