"""musicgen-medium — decoder-only over EnCodec tokens (4 codebooks) [arXiv:2306.05284].

EnCodec frontend is a STUB: inputs are the 4 parallel codebook token streams
(delay-pattern preprocessing assumed done upstream); embeddings are summed and
4 separate heads predict the next token of each codebook.
"""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048, n_codebooks=4, pad_heads_to=16,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, n_codebooks=2,
        ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16, dtype="float32",
    )
