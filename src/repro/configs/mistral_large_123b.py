"""mistral-large-123b — dense GQA transformer [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b", family="dense",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=28_672, vocab_size=32_768,
        param_dtype="bfloat16", optimizer="adafactor",
        fsdp=True,
        # §Perf pair-3: fewer scan trips -> -24% memory, -31% collectives
        ce_chunk=2048, attn_q_chunk=2048, attn_kv_chunk=2048,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32", param_dtype="float32",
    )
