"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (
    gemma3_1b,
    granite_moe_1b,
    internlm2_20b,
    kimi_k2_1t,
    mamba2_130m,
    mistral_large_123b,
    musicgen_medium,
    qwen2_vl_7b,
    qwen3_1_7b,
    vit_b16,
    zamba2_7b,
)
from repro.configs.base import ArchConfig, ShapeConfig, SparsityConfig  # noqa: F401
from repro.configs.shapes import ALL_SHAPES, SHAPES, shapes_for  # noqa: F401

_MODULES = {
    "mamba2-130m": mamba2_130m,
    "granite-moe-1b-a400m": granite_moe_1b,
    "kimi-k2-1t-a32b": kimi_k2_1t,
    "mistral-large-123b": mistral_large_123b,
    "qwen3-1.7b": qwen3_1_7b,
    "gemma3-1b": gemma3_1b,
    "internlm2-20b": internlm2_20b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "musicgen-medium": musicgen_medium,
    "zamba2-7b": zamba2_7b,
    "vit-b16": vit_b16,
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "vit-b16")
ALL_ARCHS = tuple(_MODULES)


def get_config(name: str) -> "ArchConfig":
    return _MODULES[name].config()


def get_smoke_config(name: str) -> "ArchConfig":
    return _MODULES[name].smoke()
