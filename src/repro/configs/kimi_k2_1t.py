"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].

param_dtype bf16 + adafactor: at 1T params the optimizer state must be
factored and weights stored bf16 to fit 512 x 16 GiB HBM (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, SparsityConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
        d_ff=2048, vocab_size=163_840,
        n_experts=384, top_k_experts=8,
        param_dtype="bfloat16", optimizer="adafactor",
        fsdp=True,
        moe_group_size=4096,
        sparsity=SparsityConfig(method="srigl", sparsity=0.9, gamma_sal=0.3),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256, n_experts=8, top_k_experts=2,
        moe_group_size=64, ce_chunk=16, attn_q_chunk=16, attn_kv_chunk=16,
        dtype="float32", param_dtype="float32",
    )
