"""TrainState: everything a step needs, one pytree (checkpointable)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import make_optimizer
from repro.sparse import registry as REG


class TrainState(NamedTuple):
    step: jax.Array            # () int32
    params: Any                # model parameter pytree
    opt_state: Any
    masks: Any                 # sparse boolean masks (paths mirror params).
                               # ALWAYS the raw training layout — serving
                               # representations (repro.sparse.formats
                               # objects) live in Plan/ServingEngine trees,
                               # never in TrainState, so checkpoints and the
                               # straight-through masked matmul are
                               # unaffected by the serving-format API
    neuron_active: Any         # per-stack (lead..., d_out) bool
    grad_accum: Any            # dense-grad accumulator for the saliency window
                               # ({} when grad_accum_for_saliency == 1)
    mask_versions: Any         # {stack name: () int32} — bumped by the DST
                               # step when that stack's mask changed; the
                               # serving-side Plan.refresh / ServingEngine
                               # .refresh re-condense only stacks whose
                               # counter moved (incremental export)
    rng: jax.Array


def init_train_state(cfg, key: jax.Array) -> TrainState:
    k_params, k_masks, k_rng = jax.random.split(key, 3)
    registry = REG.build_registry(cfg)
    k_fan = REG.k_fan_map(cfg, registry)
    params = M.init_params(cfg, k_params, k_fan)
    if registry:
        sp_state = REG.init_sparsity_state(cfg, k_masks, registry)
        masks, active = sp_state["masks"], sp_state["neuron_active"]
    else:
        masks, active = {}, {}
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    if cfg.sparsity.grad_accum_for_saliency > 1 and registry:
        accum = {}
        for s in registry:
            w = REG.get_path(params, s.path)
            REG._set_path(accum, s.path, jnp.zeros(w.shape, jnp.float32))
    else:
        accum = {}
    versions = {s.name: jnp.zeros((), jnp.int32) for s in registry}
    return TrainState(
        step=jnp.zeros((), jnp.int32), params=params, opt_state=opt_state,
        masks=masks, neuron_active=active, grad_accum=accum,
        mask_versions=versions, rng=k_rng)
