"""Training step + host-side Trainer loop.

``train_step`` is a single jit-able function closed over (cfg, registry):

  1. forward/backward — sparse layers use straight-through masking, so the
     gradient pytree is DENSE (RigL/SRigL grow criterion) at zero extra cost;
  2. optimizer update — gradients/moments re-masked inside the optimizer;
  3. every ``delta_t`` steps (lax.cond — topology work costs nothing on other
     steps) the DST update prunes/grows/ablates and zeroes newly-grown weights
     (RigL semantics: regrown connections start at w=0, zero momentum).

The Trainer adds the production shell: prefetching, checkpoint/restart,
step-time watchdog (straggler detection), and failure-recovery restore.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.schedule import DSTSchedule
from repro.models import model as M
from repro.optim import make_optimizer
from repro.sparse import registry as REG
from repro.train.state import TrainState, init_train_state


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)) + 1e-30)


def _dst_schedule(cfg) -> DSTSchedule:
    sp = cfg.sparsity
    return DSTSchedule(delta_t=sp.delta_t, alpha=sp.alpha,
                       t_end_fraction=sp.t_end_fraction,
                       total_steps=getattr(cfg, "total_steps", 100_000))


def make_train_step(cfg, registry, lr_fn: Callable, *, clip_norm: float = 1.0,
                    microbatches: int = 1):
    """Build the jit-able HOT-PATH step(state, batch) -> (state, metrics).

    The topology update is deliberately NOT in this program — it runs as its
    own jitted program every delta_t steps (make_dst_step). Keeping the
    selection sorts out of the hot path removes their buffers from this
    program's peak memory and their FLOPs from its roofline; the update cost
    is amortized 1/delta_t (paper App. G makes the same accounting).
    The step DOES accumulate the dense saliency gradients when the config
    asks for a multi-step saliency window (paper D.2 averages 8 steps).
    """
    sched = _dst_schedule(cfg)
    _, opt_update = make_optimizer(cfg.optimizer)
    accum_n = cfg.sparsity.grad_accum_for_saliency

    def _value_and_grad(params, masks, batch):
        return jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, masks, batch), has_aux=True)(params)

    def train_step(state: TrainState, batch: dict):
        rng, rng_next = jax.random.split(state.rng)
        if microbatches > 1:
            # gradient accumulation: scan over microbatches so activation
            # memory scales with batch/microbatches (how the 1T-param config
            # fits tighter HBM); grads averaged in f32.
            def split(x):
                b = x.shape[0] if x.ndim >= 1 else 1
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = {k: (jnp.moveaxis(split(v), 0, 0) if k != "mrope_positions"
                      else v.reshape(3, microbatches, -1, v.shape[-1]).swapaxes(0, 1))
                  for k, v in batch.items()}

            def acc_step(carry, xs):
                (l_sum, g_sum) = carry
                (l, m_), g = _value_and_grad(state.params, state.masks, xs)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    g_sum, g)
                return (l_sum + l / microbatches, g_sum), m_

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (loss, grads), ms = jax.lax.scan(acc_step, (jnp.zeros(()), g0), mb)
            metrics = jax.tree.map(lambda x: x[-1], ms)
            metrics["loss"] = loss
        else:
            (loss, metrics), grads = _value_and_grad(state.params, state.masks,
                                                     batch)

        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9)) if clip_norm else 1.0
        # clip in the gradient's own dtype: a persistent f32 copy of a bf16
        # grad tree would double gradient memory (16 GB/device at 1T params);
        # optimizers upcast per-leaf internally.
        grads_c = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)

        lr = lr_fn(state.step)
        params, opt_state = opt_update(state.params, grads_c, state.opt_state, lr,
                                       masks=state.masks if registry else None)

        # dense-grad window for the saliency criterion (paper D.2): keep the
        # running sum of the last accum_n steps' dense grads per sparse stack.
        grad_accum = state.grad_accum
        if accum_n > 1 and registry:
            decay = jnp.where(state.step % accum_n == 0, 0.0, 1.0)
            new_accum = {}
            for s in registry:
                a = REG.get_path(grad_accum, s.path)
                g = REG.get_path(grads, s.path).astype(jnp.float32)
                REG._set_path(new_accum, s.path, a * decay + g)
            grad_accum = new_accum
        # (accum_n == 1: no persistent accumulator — the topology-update
        # program recomputes its own dense grads, ~1/delta_t amortized cost)

        new_state = TrainState(step=state.step + 1, params=params,
                               opt_state=opt_state, masks=state.masks,
                               neuron_active=state.neuron_active,
                               grad_accum=grad_accum,
                               mask_versions=state.mask_versions, rng=rng_next)
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, lr=lr,
                       drop_fraction=sched.drop_fraction(state.step))
        return new_state, metrics

    return train_step


def make_dst_step(cfg, registry, compute_specs: dict | None = None):
    """Build the jit-able topology-update program (runs every delta_t steps).

    state -> state with new masks / neuron_active; newly-grown weights
    restart at 0 (RigL semantics), their optimizer moments are re-masked on
    the next optimizer call.
    """
    sched = _dst_schedule(cfg)
    accum_n = max(cfg.sparsity.grad_accum_for_saliency, 1)

    def dst_step(state: TrainState, batch: dict):
        rng, rng_next = jax.random.split(state.rng)
        drop = sched.drop_fraction(state.step)
        if accum_n > 1:
            sal_grads = jax.tree.map(lambda a: a / accum_n, state.grad_accum)
        else:
            # recompute dense grads for the grow criterion (1/delta_t amortized)
            grads = jax.grad(lambda p: M.loss_fn(cfg, p, state.masks, batch)[0])(
                state.params)
            sal_grads = {}
            for s in registry:
                REG._set_path(sal_grads, s.path,
                              REG.get_path(grads, s.path).astype(jnp.float32))
        sp_state = {"masks": state.masks, "neuron_active": state.neuron_active}
        new_sp, _stats = REG.dst_update(cfg, registry, state.params, sal_grads,
                                        sp_state, drop, rng,
                                        compute_specs=compute_specs)
        new_params = jax.tree.map(lambda x: x, state.params)  # fresh containers
        new_versions = dict(state.mask_versions)
        for s in registry:
            w = REG.get_path(new_params, s.path)
            old_m = REG.get_path(state.masks, s.path)
            new_m = REG.get_path(new_sp["masks"], s.path)
            w = jnp.where(new_m & ~old_m, 0.0, w).astype(w.dtype)
            REG._set_path(new_params, s.path, w)
            # stamp the per-stack mask-version counter: the serving plan's
            # incremental refresh re-condenses only stacks whose counter moved
            changed = jnp.any(new_m != old_m)
            new_versions[s.name] = (state.mask_versions[s.name]
                                    + changed.astype(jnp.int32))
        return state._replace(params=new_params, masks=new_sp["masks"],
                              neuron_active=new_sp["neuron_active"],
                              mask_versions=new_versions, rng=rng_next)

    return dst_step


# convenience single-call API used by tests/examples
def train_step(cfg, registry, state, batch, lr: float = 1e-3):
    step_fn = make_train_step(cfg, registry, lambda s: jnp.float32(lr))
    return step_fn(state, batch)


@dataclasses.dataclass
class Trainer:
    """Host-side production loop: prefetch, checkpoint/restart, watchdog."""

    cfg: Any
    lr_fn: Callable
    ckpt_dir: str | None = None
    ckpt_every: int = 1000
    keep_checkpoints: int = 3
    log_every: int = 50
    straggler_factor: float = 3.0   # step slower than 3x median -> flagged
    # live train->serve sync (repro.sync.Publisher): publish right after
    # every DST step (the moment mask_versions move — topology deltas), and
    # additionally every ``publish_every`` steps so serving replicas track
    # the weight VALUES between topology updates (values-only deltas).
    publisher: Any = None
    publish_every: int | None = None

    def __post_init__(self):
        self.registry = REG.build_registry(self.cfg)
        self._step_fn = None
        self._step_times: list[float] = []
        self.straggler_events: list[tuple[int, float]] = []

    def init_or_restore(self, key) -> TrainState:
        from repro.train import checkpoint as CKPT
        if self.ckpt_dir:
            latest = CKPT.latest_step(self.ckpt_dir)
            if latest is not None:
                template = init_train_state(self.cfg, key)
                return CKPT.restore(self.ckpt_dir, latest, template)
        return init_train_state(self.cfg, key)

    def fit(self, state: TrainState, batches, n_steps: int,
            log_fn: Callable = print) -> TrainState:
        from repro.train import checkpoint as CKPT
        if self._step_fn is None:
            self._step_fn = jax.jit(make_train_step(self.cfg, self.registry, self.lr_fn),
                                    donate_argnums=(0,))
            self._dst_fn = (jax.jit(make_dst_step(self.cfg, self.registry),
                                    donate_argnums=(0,))
                            if self.registry else None)
        sched = _dst_schedule(self.cfg)
        it = iter(batches)
        start = int(state.step)
        for i in range(start, start + n_steps):
            batch = next(it)
            t0 = time.perf_counter()
            try:
                state, metrics = self._step_fn(state, batch)
                dst_ran = (self._dst_fn is not None
                           and bool(sched.is_update_step(i + 1)))
                if dst_ran:
                    state = self._dst_fn(state, batch)
                if self.publisher is not None and (
                        dst_ran or (self.publish_every
                                    and (i + 1) % self.publish_every == 0)):
                    # host-side hook, outside the jitted programs: DST just
                    # stamped mask_versions, so this generation ships the
                    # moved stacks as topology deltas
                    self.publisher.publish(state)
            except Exception:
                # fault tolerance: restore from the last checkpoint and rethrow
                # if no checkpoint exists (caller decides whether to re-enter).
                if self.ckpt_dir and CKPT.latest_step(self.ckpt_dir) is not None:
                    log_fn(f"[trainer] step {i}: failure — restoring last checkpoint")
                    state = CKPT.restore(self.ckpt_dir, CKPT.latest_step(self.ckpt_dir),
                                         state)
                    continue
                raise
            dt = time.perf_counter() - t0
            self._watch_stragglers(i, dt, log_fn)
            if i % self.log_every == 0:
                loss = float(metrics["loss"])
                log_fn(f"[trainer] step {i} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if self.ckpt_dir and (i + 1) % self.ckpt_every == 0:
                CKPT.save(self.ckpt_dir, state, keep=self.keep_checkpoints)
        return state

    def _watch_stragglers(self, step: int, dt: float, log_fn):
        self._step_times.append(dt)
        if len(self._step_times) >= 20:
            med = sorted(self._step_times[-100:])[len(self._step_times[-100:]) // 2]
            if dt > self.straggler_factor * med:
                self.straggler_events.append((step, dt))
                log_fn(f"[trainer] straggler: step {step} took {dt:.2f}s (median {med:.2f}s)")
