"""Training runtime: state, trainer loop, checkpointing, elasticity."""
from repro.train.state import TrainState, init_train_state  # noqa: F401
from repro.train.trainer import Trainer, train_step  # noqa: F401
