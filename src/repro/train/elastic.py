"""Elastic scaling + fault-domain utilities.

Checkpoints are stored mesh-agnostic (see checkpoint.py), so elastic scaling
is: (1) detect the new device set, (2) rebuild the mesh with
``largest_feasible_mesh``, (3) re-lower train_step under the new mesh,
(4) restore the checkpoint with the new-sharding template. Nothing else in the
stack changes — DST state (masks / neuron_active) reshards with its weights
because the shardings are path-parallel.

Straggler mitigation at the multi-slice level (documented pattern, exercised
by the Trainer watchdog hook): ΔT-aligned checkpoint cadence keeps the restart
penalty below one DST period; hot-spare slices take over the data-parallel
rank of a failed slice by replaying from (step // ckpt_every) * ckpt_every.
"""
from __future__ import annotations

import jax


def largest_feasible_mesh(n_devices: int, model_parallel: int):
    """Greatest (data, model) grid with model fixed and data = n // model.

    Elastic restarts keep the model-parallel degree (weight shards must stay
    rectangular) and absorb device loss in the data axis; leftover devices
    idle until the next maintenance window.
    """
    model = model_parallel
    data = max(1, n_devices // model)
    return (data, model)


def remesh(template_state, ckpt_dir: str, step: int, make_state_fn):
    """Re-shard a checkpoint onto the current device topology.

    make_state_fn() must initialize a state under the *new* mesh (shardings
    attached); values are then overwritten from the checkpoint.
    """
    from repro.train import checkpoint as CKPT
    new_template = make_state_fn()
    return CKPT.restore(ckpt_dir, step, new_template)


def device_health() -> dict:
    """Cheap liveness probe across local devices (multi-host: all_gather it)."""
    out = {}
    for d in jax.local_devices():
        try:
            x = jax.device_put(jax.numpy.ones(()), d)
            out[str(d)] = bool(x.block_until_ready() == 1.0)
        except Exception:  # pragma: no cover - only on real hw faults
            out[str(d)] = False
    return out
