"""Fault-tolerant checkpointing: atomic, step-tagged, keep-N, reshard-on-load.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json ;  <dir>/step_<N>.tmp is
written first and atomically renamed, so a crash mid-save never corrupts the
latest checkpoint. Arrays are stored *unsharded-logical* (host numpy), so a
restart may use a different mesh/device count — the restore path simply
device_puts into whatever shardings the new jit wants (elastic scaling).

On a real multi-host pod each host writes its own data-parallel shard of the
arrays plus a shared manifest (process_index suffix) — the single-host layout
here is the degenerate case; see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil

import jax
import numpy as np


def _formats():
    from repro.sparse import formats  # lazy: keeps checkpoint deps minimal
    return formats


def _flatten(tree, prefix=()):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, prefix + (f"#{i}",)))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), prefix + (str(k),)))
    elif isinstance(tree, _formats().SparseFormat):
        # serving-format node: array fields are saved under the SAME keys the
        # legacy dict leaves used ("…/values", "…/indices", …), so old
        # checkpoints restore into format templates and vice versa; static
        # geometry is carried by the restore template, not the archive
        for k in tree._array_fields:
            out.update(_flatten(getattr(tree, k), prefix + (str(k),)))
    else:
        out["/".join(prefix)] = tree
    return out


def save(ckpt_dir: str, state, keep: int = 3) -> str:
    step = int(state.step)
    flat = _flatten(state._asdict() if hasattr(state, "_asdict") else state)
    arrays = {k: np.asarray(v) for k, v in flat.items() if v is not None}

    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(arrays)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template):
    """Load into the structure of ``template`` (a TrainState or pytree).

    Values are device_put respecting each template leaf's sharding when the
    template is already placed (elastic re-mesh: pass a freshly-initialized
    state lowered under the *new* mesh as template).
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "arrays.npz")
    data = np.load(path)
    flat_t = _flatten(template._asdict() if hasattr(template, "_asdict") else template)

    def build(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: build(v, prefix + (str(k),)) for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(**{k: build(getattr(tree, k), prefix + (str(k),))
                                 for k in tree._fields})
        if isinstance(tree, _formats().SparseFormat):
            # rebuild the format around restored arrays; statics come from
            # the template. Two legacy layouts restore here: dict leaves
            # (saved under the same field-name keys) resolve directly, and a
            # pre-formats MASKED leaf — a bare bool array saved at the stack
            # path itself — is picked up by the single-array fallback below
            # (shape-guarded so a wrong-rank hit can never slip through).
            missing = set()

            def _build_field(name, leaf):
                key = "/".join(prefix + (name,))
                bare = "/".join(prefix)
                if (key not in data and len(tree._array_fields) == 1
                        and bare in data
                        and tuple(data[bare].shape) == tuple(leaf.shape)):
                    return build(leaf, prefix)
                if key not in data:
                    missing.add(name)
                    return build(leaf, prefix + (name,))
                # quantized<->float template/archive mismatches must keep
                # the ARCHIVE's dtype: a blind astype would truncate f32
                # codes into int8 garbage (or reinterpret int8 codes as
                # floats). restore_finalize re-/de-quantizes exactly below.
                if _formats().is_quantized_storage(data[key].dtype) \
                        != _formats().is_quantized_storage(leaf.dtype):
                    return jax.numpy.asarray(data[key])
                return build(leaf, prefix + (name,))
            rebuilt = tree.map_arrays_with_names(_build_field)
            # optional fields the TEMPLATE does not carry (e.g. a float
            # template restoring a quantized archive's scales) are adopted
            # from the archive so restore_finalize can dequantize
            extra = {name: jax.numpy.asarray(data["/".join(prefix + (name,))])
                     for name in tree._array_fields
                     if getattr(tree, name) is None
                     and "/".join(prefix + (name,)) in data}
            if extra:
                rebuilt = dataclasses.replace(rebuilt, **extra)
            # fields the archive predates (e.g. StructuredFanIn.active_index)
            # are re-derived from the restored arrays instead of keeping the
            # template's values, so the format stays internally consistent;
            # restore_finalize then reconciles values/scales storage dtypes
            # against the template's declared values_dtype (quantize a float
            # archive into a quantized template, dequantize the reverse)
            if missing:
                rebuilt = rebuilt.rebuild_missing(frozenset(missing))
            return rebuilt.restore_finalize()
        if isinstance(tree, (list, tuple)):
            return type(tree)(build(v, prefix + (f"#{i}",)) for i, v in enumerate(tree))
        key = "/".join(prefix)
        if key not in data:
            return tree  # new fields keep template init
        arr = data[key]
        leaf = flat_t.get(key)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(leaf, "is_deleted") and not leaf.is_deleted():
            return jax.device_put(arr.astype(leaf.dtype), sharding)
        return jax.numpy.asarray(arr)

    if hasattr(template, "_asdict"):
        return type(template)(**build(template._asdict()))
    return build(template)
