"""Condensed-representation export: masks -> {values, indices} pytree.

The paper's serving story (Sec. 4.4): the SAME trained weights can execute
as masked-dense (MXU path, training/prefill) or condensed constant fan-in
(bandwidth path, decode/online inference). This module converts a trained
(params, masks) pair into the condensed pytree that repro.models.layers
dispatches on, and provides the abstract (ShapeDtypeStruct) variant the
dry-run uses to lower the condensed decode program without allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.core import topology
from repro.sparse import registry as REG


def _condense_stack(weight, mask, k: int):
    """vmap dense_to_condensed over the leading stack dims."""
    fn = lambda w, m: topology.dense_to_condensed(w, m, k)
    for _ in range(weight.ndim - 2):
        fn = jax.vmap(fn)
    vals, idx = fn(weight, mask)
    return {"values": vals, "indices": idx}


def export_condensed(cfg, registry, params: dict, masks: dict) -> dict:
    """Concrete export after training. k per stack = max realized fan-in."""
    out: dict = {}
    for s in registry:
        w = REG.get_path(params, s.path)
        m = REG.get_path(masks, s.path)
        nnz_per_col = jnp.sum(m, axis=-2)
        k = int(jnp.max(nnz_per_col))
        REG._set_path(out, s.path, _condense_stack(w * m, m, k))
    return out


def export_structured(cfg, registry, masks: dict) -> dict:
    """Structured-only serving pytree: {"neuron_active": (lead..., d_out)}.

    The Fig. 4 "structured" representation drops ablated output neurons but
    keeps active columns dense — repro.models.layers.linear dispatches these
    dicts to kernels.ops.structured_dense. A neuron is active iff its mask
    column has any non-zero (matches the trainer's neuron_active state after
    an SRigL update, and degrades gracefully for unstructured masks).
    """
    out: dict = {}
    for s in registry:
        m = REG.get_path(masks, s.path)
        REG._set_path(out, s.path,
                      {"neuron_active": jnp.any(m, axis=-2)})
    return out


def abstract_condensed(cfg, registry, param_dtype=None) -> dict:
    """ShapeDtypeStruct stand-ins at the target fan-in (for the dry-run)."""
    dt = jnp.dtype(param_dtype or cfg.param_dtype)
    out: dict = {}
    for s in registry:
        k = D.fan_in_from_density(s.d_in, s.density)
        shape = (*s.lead, s.d_out, k)
        REG._set_path(out, s.path, {
            "values": jax.ShapeDtypeStruct(shape, dt),
            "indices": jax.ShapeDtypeStruct(shape, jnp.int32),
        })
    return out


def condensed_bytes(cfg, registry) -> tuple[int, int]:
    """(condensed weight bytes, dense weight bytes) across sparse stacks."""
    dense = cond = 0
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    for s in registry:
        k = D.fan_in_from_density(s.d_in, s.density)
        n = s.n_replicas
        dense += n * s.d_in * s.d_out * itemsize
        cond += n * s.d_out * k * (itemsize + 4)  # values + int32 indices
    return cond, dense
