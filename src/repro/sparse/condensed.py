"""Condensed-representation export: masks -> {values, indices} pytree.

The paper's serving story (Sec. 4.4): the SAME trained weights can execute
as masked-dense (MXU path, training/prefill) or condensed constant fan-in
(bandwidth path, decode/online inference). This module converts a trained
(params, masks) pair into the condensed pytree that repro.models.layers
dispatches on, and provides the abstract (ShapeDtypeStruct) variant the
dry-run uses to lower the condensed decode program without allocation.
"""
from __future__ import annotations

import functools
import typing

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.core import topology
from repro.sparse import registry as REG


class ExportStats(typing.NamedTuple):
    """Realized per-stack structure, measured from the trained masks."""
    k: int                  # max realized fan-in over all columns/replicas
    max_active: int         # max active (non-ablated) neurons over replicas
    active_fraction: float  # mean fraction of active neurons


def export_stats(registry, masks: dict,
                 stacks: typing.Sequence | None = None) -> dict[str, ExportStats]:
    """Per-stack realized stats with ONE device program and ONE host sync.

    The naive per-stack ``int(jnp.max(...))`` forces a device->host transfer
    per stack (a serialization point on every export); here every stack's
    reductions are fused into a single stacked (n_stacks, 3) array and fetched
    with a single ``jax.device_get``. ``stacks`` optionally restricts the
    computation to a subset (incremental refresh re-measures only the stacks
    whose masks changed).
    """
    stacks = list(registry if stacks is None else stacks)
    rows = []
    for s in stacks:
        m = REG.get_path(masks, s.path)
        nnz = jnp.sum(m.astype(jnp.int32), axis=-2)          # (lead..., d_out)
        act = jnp.any(m, axis=-2)                            # (lead..., d_out)
        rows.append(jnp.stack([
            jnp.max(nnz).astype(jnp.float32),
            jnp.max(jnp.sum(act.astype(jnp.int32), axis=-1)).astype(jnp.float32),
            jnp.mean(act.astype(jnp.float32)),
        ]))
    if not rows:
        return {}
    table = jax.device_get(jnp.stack(rows))                  # single transfer
    return {s.name: ExportStats(k=int(r[0]), max_active=int(r[1]),
                                active_fraction=float(r[2]))
            for s, r in zip(stacks, table)}


def _condense_stack(weight, mask, k: int):
    """vmap dense_to_condensed over the leading stack dims."""
    fn = lambda w, m: topology.dense_to_condensed(w, m, k)
    vals, idx = _vmap_lead(fn, weight.ndim - 2)(weight, mask)
    return {"values": vals, "indices": idx}


def condense_stack_leaf(weight, mask, stats: ExportStats) -> dict:
    """Condensed leaf {"values", "indices"} for one stack at realized fan-in."""
    return _condense_stack(weight * mask, mask, max(stats.k, 1))


def export_condensed(cfg, registry, params: dict, masks: dict,
                     stats: dict[str, ExportStats] | None = None) -> dict:
    """Concrete export after training. k per stack = max realized fan-in."""
    stats = stats if stats is not None else export_stats(registry, masks)
    out: dict = {}
    for s in registry:
        w = REG.get_path(params, s.path)
        m = REG.get_path(masks, s.path)
        REG._set_path(out, s.path, condense_stack_leaf(w, m, stats[s.name]))
    return out


def _condense_active_stack(weight, mask, k: int, a: int):
    """Condensed-over-active leaf for one stack (vmapped over lead dims).

    Drops ablated output neurons FIRST (Fig. 4's "structured" move), then
    condenses only the surviving columns to constant fan-in ``k`` — the
    composed representation of the paper's combined Fig. 4 point. ``a`` is
    the (static) max active-neuron count across the stack's replicas; rows
    beyond a replica's realized active count are padding with values 0 and
    an out-of-range ``out_index`` so the scatter in kernels.ops drops them.

    A neuron is treated as active iff its mask column has any non-zero —
    derived from the mask itself (not the trainer's neuron_active bookkeeping)
    so the representation is exact vs masked-dense by construction.
    """
    d_out = weight.shape[-1]

    def fn(w, m):
        col_active = jnp.any(m, axis=0)                      # (d_out,)
        order = jnp.argsort(~col_active, stable=True).astype(jnp.int32)
        out_index = order[:a]                                # active cols first
        sel = col_active[out_index]                          # (a,)
        w_sel = jnp.take(w, out_index, axis=1)
        m_sel = jnp.take(m, out_index, axis=1) & sel[None, :]
        vals, idx = topology.dense_to_condensed(w_sel * m_sel, m_sel, k)
        return vals, idx, jnp.where(sel, out_index, d_out).astype(jnp.int32)

    vals, idx, oi = _vmap_lead(fn, weight.ndim - 2)(weight, mask)
    return {"values": vals, "indices": idx, "out_index": oi}


def condense_active_stack_leaf(weight, mask, stats: ExportStats) -> dict:
    return _condense_active_stack(weight, mask, max(stats.k, 1),
                                  max(stats.max_active, 1))


# --- jitted donated re-export -----------------------------------------------
#
# Plan.refresh runs against a LIVE serving job, so the re-export must not
# transiently hold two copies of a stack's condensed weights. The helpers
# below run the re-condense / values-regather as ONE jitted program with the
# plan's old {values, indices} buffers donated: when the new leaf has the
# same avals (fan-in k and active-row count unchanged — the common case for
# a DST step, which rewires at constant fan-in), XLA writes the new arrays
# into the donated buffers and the old jax.Arrays are invalidated at
# dispatch. keep_unused=True stops jit from pruning the donated args (the
# output aliases them by shape/dtype, not dataflow). No weight data ever
# crosses to the host.


def _vmap_lead(fn, n_lead: int):
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    return fn


@functools.partial(jax.jit, static_argnames=("k",), donate_argnums=(2, 3),
                   keep_unused=True)
def _recondense_donated(weight, mask, old_values, old_indices, *, k: int):
    fn = lambda w, m: topology.dense_to_condensed(w * m, m, k)
    vals, idx = _vmap_lead(fn, weight.ndim - 2)(weight, mask)
    return {"values": vals.astype(old_values.dtype), "indices": idx}


@functools.partial(jax.jit, static_argnames=("k", "a"),
                   donate_argnums=(2, 3, 4), keep_unused=True)
def _recondense_active_donated(weight, mask, old_values, old_indices,
                               old_out_index, *, k: int, a: int):
    leaf = _condense_active_stack(weight, mask, k, a)
    leaf["values"] = leaf["values"].astype(old_values.dtype)
    return leaf


def recondense_stack_leaf(weight, mask, stats: ExportStats, old_leaf: dict,
                          *, over_active: bool = False,
                          donate: bool = True) -> dict:
    """Re-condense one stack for Plan.refresh, reusing ``old_leaf``'s device
    buffers when the new leaf's avals match (see block comment above).

    CAUTION (donate=True): the arrays in ``old_leaf`` are invalidated —
    callers must not read them afterwards. Falls back to a fresh (non-
    donating) export when the realized fan-in / active count changed shape.
    """
    k = max(stats.k, 1)
    if over_active:
        a = max(stats.max_active, 1)
        shape = (*weight.shape[:-2], a, k)
        if (donate and "out_index" in old_leaf
                and old_leaf["values"].shape == shape
                and old_leaf["values"].dtype == weight.dtype):
            return _recondense_active_donated(
                weight, mask, old_leaf["values"], old_leaf["indices"],
                old_leaf["out_index"], k=k, a=a)
        return condense_active_stack_leaf(weight, mask, stats)
    shape = (*weight.shape[:-2], weight.shape[-1], k)
    if (donate and "out_index" not in old_leaf
            and old_leaf["values"].shape == shape
            and old_leaf["values"].dtype == weight.dtype):
        return _recondense_donated(weight, mask, old_leaf["values"],
                                   old_leaf["indices"], k=k)
    return condense_stack_leaf(weight, mask, stats)


def _gather_at_indices(weight, mask, indices, out_index=None):
    def fn(w, m, idx, oi=None):
        wm_t = (w * m).T                                     # (d_out, d_in)
        if oi is not None:  # select surviving columns (clip: padding dropped)
            wm_t = jnp.take(wm_t, jnp.minimum(oi, wm_t.shape[0] - 1), axis=0)
        return jnp.take_along_axis(wm_t, idx, axis=1)

    n_lead = weight.ndim - 2
    if out_index is None:
        return _vmap_lead(fn, n_lead)(weight, mask, indices)
    return _vmap_lead(fn, n_lead)(weight, mask, indices, out_index)


@functools.partial(jax.jit, donate_argnums=(2,), keep_unused=True)
def _revalue_donated(weight, mask, old_values, indices):
    return _gather_at_indices(weight, mask, indices).astype(old_values.dtype)


@functools.partial(jax.jit, donate_argnums=(2,), keep_unused=True)
def _revalue_active_donated(weight, mask, old_values, indices, out_index):
    return _gather_at_indices(weight, mask, indices,
                              out_index).astype(old_values.dtype)


def revalue_stack_leaf(weight, mask, leaf: dict, *, donate: bool = False) -> dict:
    """Values-only refresh of a condensed(-over-active) leaf under UNCHANGED
    topology: re-gather ``weight * mask`` at the stored indices, reusing the
    indices (and out_index) arrays verbatim.

    Exact because padding slots point at inactive rows (dense_to_condensed's
    invariant), so they re-gather exact zeros; condensed-over-active padding
    ROWS may re-gather garbage from a clipped column but are dropped by the
    out-of-range out_index at scatter time. This skips the argsort and the
    stats host sync — the cheap path Plan.refresh uses for stacks whose mask
    version did NOT move while the weights kept training. No host transfer
    of weight data happens either way: the regather is a device program.

    ``donate=True`` runs it as one jitted program with the OLD values buffer
    donated: the regathered values are written in place (the returned array
    aliases ``leaf["values"]``'s storage, which is invalidated), so a live
    serving job never holds two copies of a stack's values. The indices /
    out_index objects are returned verbatim in both modes.
    """
    out_index = leaf.get("out_index")
    if donate:
        if out_index is None:
            values = _revalue_donated(weight, mask, leaf["values"],
                                      leaf["indices"])
        else:
            values = _revalue_active_donated(weight, mask, leaf["values"],
                                             leaf["indices"], out_index)
    else:
        values = _gather_at_indices(weight, mask, leaf["indices"],
                                    out_index).astype(leaf["values"].dtype)
    if out_index is None:
        return {"values": values, "indices": leaf["indices"]}
    return {"values": values, "indices": leaf["indices"],
            "out_index": out_index}


def export_condensed_over_active(cfg, registry, params: dict, masks: dict,
                                 stats: dict[str, ExportStats] | None = None) -> dict:
    """Composed export: ablated neurons dropped, survivors condensed.

    Leaf type: {"values": (lead..., a, k), "indices": (lead..., a, k),
    "out_index": (lead..., a)} — repro.models.layers.linear dispatches these
    to kernels.ops.condensed_over_active_linear_nd. Token-identical to the
    masked path for ANY mask (ablated columns contribute exact zeros either
    way); the byte saving over plain condensed is the ablated-neuron fraction.
    """
    stats = stats if stats is not None else export_stats(registry, masks)
    out: dict = {}
    for s in registry:
        w = REG.get_path(params, s.path)
        m = REG.get_path(masks, s.path)
        REG._set_path(out, s.path, condense_active_stack_leaf(w, m, stats[s.name]))
    return out


def structured_stack_leaf(mask) -> dict:
    """Structured-only leaf for one stack: {"neuron_active": (lead..., d_out)}.

    A neuron is active iff its mask column has any non-zero (matches the
    trainer's neuron_active state after an SRigL update, and degrades
    gracefully for unstructured masks). Single definition shared by
    export_structured and repro.sparse.plan's leaf builder."""
    return {"neuron_active": jnp.any(mask, axis=-2)}


def export_structured(cfg, registry, masks: dict) -> dict:
    """Structured-only serving pytree (Fig. 4 "structured"): ablated output
    neurons dropped, active columns kept dense — repro.models.layers.linear
    dispatches these dicts to kernels.ops.structured_dense."""
    out: dict = {}
    for s in registry:
        m = REG.get_path(masks, s.path)
        REG._set_path(out, s.path, structured_stack_leaf(m))
    return out


def abstract_condensed(cfg, registry, param_dtype=None) -> dict:
    """ShapeDtypeStruct stand-ins at the target fan-in (for the dry-run).
    Delegates to the plan subsystem's abstract tree (single leaf-schema
    definition); lazy import to avoid a module cycle."""
    from repro.sparse import plan as PLAN
    return PLAN.abstract_serving_tree(cfg, registry,
                                      {s.name: "condensed" for s in registry},
                                      param_dtype=param_dtype)


def condensed_bytes(cfg, registry) -> tuple[int, int]:
    """(condensed weight bytes, dense weight bytes) across sparse stacks."""
    dense = cond = 0
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    for s in registry:
        k = D.fan_in_from_density(s.d_in, s.density)
        n = s.n_replicas
        dense += n * s.d_in * s.d_out * itemsize
        cond += n * s.d_out * k * (itemsize + 4)  # values + int32 indices
    return cond, dense
