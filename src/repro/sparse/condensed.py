"""Condensed-representation export: masks -> serving-format pytrees.

The paper's serving story (Sec. 4.4): the SAME trained weights can execute
as masked-dense (MXU path, training/prefill) or condensed constant fan-in
(bandwidth path, decode/online inference). This module converts a trained
(params, masks) pair into serving pytrees whose leaves are the typed format
objects from ``repro.sparse.formats`` (the representation layer proper —
``apply``/``cost``/``tuning_key``/``donate_refresh`` all live there); what
stays here is the REGISTRY-LEVEL orchestration: fused per-stack stats with
one host sync, whole-tree exports, and byte accounting.

The per-leaf helpers (``condense_stack_leaf`` & co.) are kept as thin
delegates to the format constructors so pre-redesign callers keep working;
they now return ``SparseFormat`` objects (which still answer
``leaf["values"]``-style access during the migration).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.sparse import formats as F
from repro.sparse import registry as REG

# re-exports: these names predate the formats module and are widely imported
ExportStats = F.ExportStats


def export_stats(registry, masks: dict,
                 stacks: typing.Sequence | None = None) -> dict[str, ExportStats]:
    """Per-stack realized stats with ONE device program and ONE host sync.

    The naive per-stack ``int(jnp.max(...))`` forces a device->host transfer
    per stack (a serialization point on every export); here every stack's
    reductions are fused into a single stacked (n_stacks, 3) array and fetched
    with a single ``jax.device_get``. ``stacks`` optionally restricts the
    computation to a subset (incremental refresh re-measures only the stacks
    whose masks changed).
    """
    stacks = list(registry if stacks is None else stacks)
    rows = []
    for s in stacks:
        m = REG.get_path(masks, s.path)
        nnz = jnp.sum(m.astype(jnp.int32), axis=-2)          # (lead..., d_out)
        act = jnp.any(m, axis=-2)                            # (lead..., d_out)
        rows.append(jnp.stack([
            jnp.max(nnz).astype(jnp.float32),
            jnp.max(jnp.sum(act.astype(jnp.int32), axis=-1)).astype(jnp.float32),
            jnp.mean(act.astype(jnp.float32)),
            # min fan-in over ACTIVE columns: == d_in iff the mask is
            # ablation-ONLY (surviving columns fully dense) — the regime
            # where the structured representation is exact (plan auto)
            jnp.min(jnp.where(nnz > 0, nnz, m.shape[-2])).astype(jnp.float32),
        ]))
    if not rows:
        return {}
    table = jax.device_get(jnp.stack(rows))                  # single transfer
    return {s.name: ExportStats(k=int(r[0]), max_active=int(r[1]),
                                active_fraction=float(r[2]),
                                min_fan_in=int(r[3]))
            for s, r in zip(stacks, table)}


def stats_from_leaf(leaf, *, min_fan_in: int = 0) -> ExportStats:
    """ExportStats derived from an exported leaf's GEOMETRY (no mask).

    The sync subscriber adopts leaves that were exported remotely — the
    replica never holds the trainer's mask, so realized stats must come
    from the leaf's own shapes via ``leaf.spec()``. k / max_active are
    exact (they size the arrays); ``active_fraction`` is the spec's padded
    estimate and ``min_fan_in`` defaults to 0 ("unknown"), so a plan
    repriced from these stats can never enable the structured-exact path
    by accident.
    """
    spec = leaf.spec()
    return ExportStats(k=int(spec.k), max_active=int(spec.max_active),
                       active_fraction=float(spec.active_fraction),
                       min_fan_in=int(min_fan_in))


def _condense_stack(weight, mask, k: int):
    """Condensed arrays at forced fan-in ``k`` (exactness-test reference)."""
    from repro.core import topology
    fn = lambda w, m: topology.dense_to_condensed(w, m, k)
    vals, idx = F._vmap_lead(fn, weight.ndim - 2)(weight, mask)
    return {"values": vals, "indices": idx}


def condense_stack_leaf(weight, mask, stats: ExportStats) -> F.Condensed:
    """Condensed format for one stack at realized fan-in."""
    return F.Condensed.export_from_dense(weight, mask, stats)


def condense_active_stack_leaf(weight, mask,
                               stats: ExportStats) -> F.CondensedOverActive:
    return F.CondensedOverActive.export_from_dense(weight, mask, stats)


def structured_stack_leaf(mask, *, d_in: int | None = None,
                          weight_itemsize: int = 4,
                          stats: ExportStats | None = None) -> F.StructuredFanIn:
    """Structured-only format for one stack. A neuron is active iff its mask
    column has any non-zero (matches the trainer's neuron_active state after
    an SRigL update, and degrades gracefully for unstructured masks).
    ``stats`` (when precomputed) sizes the gathered kernel's ``active_index``
    at the realized active count without a host sync."""
    stats = stats if stats is not None else F._realized_stats(mask)
    d_out = int(mask.shape[-1])
    a_pad = F.padded_active_count(max(stats.max_active, 1), d_out)
    return F.StructuredFanIn(neuron_active=jnp.any(mask, axis=-2),
                             active_index=F.active_index_from_mask(mask, a_pad),
                             d_in=int(d_in if d_in is not None
                                      else mask.shape[-2]),
                             weight_itemsize=weight_itemsize)


def recondense_stack_leaf(weight, mask, stats: ExportStats, old_leaf, *,
                          over_active: bool = False,
                          donate: bool = True,
                          quantize_spec=None, tp: int = 1) -> F.SparseFormat:
    """Re-condense one stack for Plan.refresh, reusing ``old_leaf``'s device
    buffers when the new arrays' avals match (see the donated-program notes
    in repro.sparse.formats).

    CAUTION (donate=True): the arrays in ``old_leaf`` are invalidated —
    callers must not read them afterwards. Falls back to a fresh (non-
    donating) export when the realized fan-in / active count changed shape.
    Accepts legacy dict leaves through the deprecation shim.

    ``quantize_spec`` only matters on the fresh-export fallback (the plan's
    values dtype for a leaf whose representation just changed); the donated
    path re-exports under the OLD leaf's own ``values_dtype``, which for a
    plan-managed leaf is the same thing.

    ``tp`` is the plan's per-stack shard count: an old leaf exported at a
    DIFFERENT shard layout cannot be donated into (its block structure
    changed even when shapes match), so the refresh falls back to a fresh
    export at ``tp_shards=tp``.
    """
    if isinstance(old_leaf, dict):
        old_leaf = F.from_legacy_leaf(old_leaf, d_in=weight.shape[-2],
                                      d_out=weight.shape[-1])
    cls = F.CondensedOverActive if over_active else F.Condensed
    tp = max(int(tp), 1)
    if not isinstance(old_leaf, cls) or getattr(old_leaf, "tp", 1) != tp:
        # representation or shard layout changed: fresh export
        return cls.export_from_dense(weight, mask, stats,
                                     quantize_spec=quantize_spec,
                                     tp_shards=tp)
    return old_leaf.donate_refresh(weight, mask, stats, donate=donate)


def revalue_stack_leaf(weight, mask, leaf, *, donate: bool = False) -> F.SparseFormat:
    """Values-only refresh of a condensed(-over-active) leaf under UNCHANGED
    topology: re-gather ``weight * mask`` at the stored indices, reusing the
    indices (and out_index) arrays verbatim. See
    ``formats.Condensed.refresh_values`` for the exactness/donation contract.
    """
    if isinstance(leaf, dict):
        leaf = F.from_legacy_leaf(leaf, d_in=weight.shape[-2],
                                  d_out=weight.shape[-1])
    return leaf.refresh_values(weight, mask, donate=donate)


def export_condensed(cfg, registry, params: dict, masks: dict,
                     stats: dict[str, ExportStats] | None = None) -> dict:
    """Concrete export after training. k per stack = max realized fan-in.
    Leaves are ``formats.Condensed`` objects."""
    return _export_tree(F.Condensed, registry, params, masks, stats)


def export_condensed_over_active(cfg, registry, params: dict, masks: dict,
                                 stats: dict[str, ExportStats] | None = None) -> dict:
    """Composed export: ablated neurons dropped, survivors condensed
    (``formats.CondensedOverActive`` leaves — the paper's combined Fig. 4
    point, token-identical to masked for ANY mask)."""
    return _export_tree(F.CondensedOverActive, registry, params, masks, stats)


def export_structured(cfg, registry, masks: dict,
                      stats: dict[str, ExportStats] | None = None) -> dict:
    """Structured-only serving pytree (Fig. 4 "structured"):
    ``formats.StructuredFanIn`` leaves — ablated output neurons dropped,
    active columns kept dense and executed by the column-gathered kernel
    (``active_index`` sized at each stack's realized active count, fetched
    with the registry-level fused stats sync)."""
    stats = stats if stats is not None else export_stats(registry, masks)
    out: dict = {}
    for s in registry:
        m = REG.get_path(masks, s.path)
        REG.set_path(out, s.path,
                     structured_stack_leaf(m, d_in=s.d_in,
                                           stats=stats[s.name]))
    return out


def _export_tree(cls, registry, params, masks, stats):
    stats = stats if stats is not None else export_stats(registry, masks)
    out: dict = {}
    for s in registry:
        w = REG.get_path(params, s.path)
        m = REG.get_path(masks, s.path)
        REG.set_path(out, s.path, cls.export_from_dense(w, m, stats[s.name]))
    return out


def abstract_condensed(cfg, registry, param_dtype=None) -> dict:
    """ShapeDtypeStruct stand-ins at the target fan-in (for the dry-run).
    Delegates to the plan subsystem's abstract tree (single leaf-schema
    definition); lazy import to avoid a module cycle."""
    from repro.sparse import plan as PLAN
    return PLAN.abstract_serving_tree(cfg, registry,
                                      {s.name: "condensed" for s in registry},
                                      param_dtype=param_dtype)


def condensed_bytes(cfg, registry) -> tuple[int, int]:
    """(condensed weight bytes, dense weight bytes) across sparse stacks."""
    dense = cond = 0
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    for s in registry:
        k = D.fan_in_from_density(s.d_in, s.density)
        n = s.n_replicas
        dense += n * s.d_in * s.d_out * itemsize
        cond += n * s.d_out * k * (itemsize + 4)  # values + int32 indices
    return cond, dense
