"""Sparse-layer registry and execution paths (masked-dense / condensed)."""
from repro.sparse.registry import (  # noqa: F401
    SparseStack,
    build_registry,
    dst_update,
    init_sparsity_state,
    k_fan_map,
    sparsity_summary,
)
