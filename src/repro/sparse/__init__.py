"""Sparse-layer registry and execution paths (masked-dense / condensed /
structured / condensed-over-active), plus the serving execution-plan
subsystem (repro.sparse.plan) that picks a representation per stack."""
from repro.sparse.registry import (  # noqa: F401
    SparseStack,
    build_registry,
    dst_update,
    init_sparsity_state,
    k_fan_map,
    sparsity_summary,
)
