"""First-class sparse serving formats: one protocol, four representations.

The paper's serving story (Sec. 4.4, Fig. 4) is that ONE trained constant
fan-in topology can be *executed* under several storage/compute
representations, and which one wins is a property of the request shape and
the hardware balance. This module makes each representation a registered
pytree dataclass with a single shared protocol, replacing the untyped
``{"values": ..., "indices": ...}`` dict leaves that every consumer used to
re-interpret with its own key-sniffing conventions.

Mapping to PAPER.md Fig. 4 (serving-time representations of an SRigL mask):

* ``MaskedDense``          — the training layout: dense weight + bool mask,
                             dense MXU matmul. Fig. 4's "dense/masked"
                             baseline point; wins back at large batch.
* ``StructuredFanIn``      — Fig. 4 "structured": ablated output neurons are
                             dropped, surviving columns stay dense and run
                             through the column-gathered Pallas kernel
                             (``active_index``; bytes/FLOPs scale with the
                             active fraction). Exact only for ablation-only
                             masks.
* ``Condensed``            — Fig. 4 "condensed": the constant fan-in gather
                             layout (Alg. 1). Weight reads shrink to
                             n_out*k entries; wins the bandwidth-bound
                             decode shapes.
* ``CondensedOverActive``  — Fig. 4's combined point: ablated neurons are
                             dropped FIRST, then the survivors are
                             condensed. Exact for any mask; the byte/FLOP
                             saving over plain condensed is the ablated
                             fraction.

Protocol (every format implements all of it):

* ``apply(x, w)``                    — execute the sparse linear. ``w`` is
                                       the live dense weight (read by the
                                       masked/structured formats, ignored by
                                       the condensed family).
* ``export_from_dense(w, mask, stats)`` (classmethod) — build the format
                                       from a trained (weight, mask) pair.
* ``cost(batch, profile)``           — estimated seconds per serving step
                                       under ``profile`` (the plan cost
                                       model); ``estimate_cost`` is the
                                       allocation-free classmethod variant
                                       priced from a ``FormatSpec``.
* ``tuning_key(batch, ...)``         — the autotune-cache key this format's
                                       kernel dispatch looks up (None for
                                       formats with no tunable kernel).
* ``donate_refresh(w, mask, stats)`` — in-place re-export: rebuilds the
                                       format with ``self``'s old device
                                       buffers DONATED whenever the new
                                       arrays have matching avals (a live
                                       serving job never holds two copies).
* ``refresh_values(w, mask)``        — cheap values-only refresh under
                                       unchanged topology (indices reused
                                       verbatim; no-op for formats that
                                       read the live weights).

Formats are pytree nodes: their array fields are traced leaves (they flow
through ``jit``/``lax.scan``/``device_put``/donation like any array) and
their static fields ride along as hashable aux data. ``from_legacy_leaf``
upgrades the pre-redesign dict leaves (deprecation shim), so existing
checkpoints and serialized serving trees keep loading.
"""
from __future__ import annotations

import dataclasses
import functools
import typing
import warnings

import jax
import jax.numpy as jnp

from repro.core import topology
from repro.core.srigl import apply_mask_for_forward
from repro.kernels import ops
from repro.kernels.structured_matmul import padded_active_count


class ExportStats(typing.NamedTuple):
    """Realized per-stack structure, measured from the trained masks."""
    k: int                  # max realized fan-in over all columns/replicas
    max_active: int         # max active (non-ablated) neurons over replicas
    active_fraction: float  # mean fraction of active neurons
    # min realized fan-in over ACTIVE columns (columns with >= 1 non-zero);
    # min_fan_in == d_in means every surviving column is fully dense — the
    # ablation-ONLY regime where the structured column-drop representation
    # is exact. Defaults to 0 ("unknown / not ablation-only") so stats built
    # by older call sites never enable structured by accident.
    min_fan_in: int = 0


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """Static geometry a format is priced and cache-keyed from (no arrays).

    Built either from a registry ``SparseStack`` + realized ``ExportStats``
    (``spec_for_stack``) or from a live format instance (``fmt.spec()``) —
    the allocation-free half of the protocol, used by the plan cost model
    and the autotune key derivation before any export has happened.

    ``values_dtype`` is the canonical short name of the exported values
    storage dtype (``"int8"``/``"fp8"`` for the quantized formats, None for
    "same as ``itemsize``'s dtype") — the pricing methods read the REAL byte
    width from it so ``--path auto`` re-derives its crossovers honestly
    under quantization.
    """
    d_in: int
    d_out: int
    n_replicas: int
    itemsize: int           # serving dtype bytes for values/weights
    k: int                  # constant fan-in
    max_active: int         # exported row count for condensed-over-active
    active_fraction: float  # mean active-neuron fraction
    values_dtype: str | None = None  # canonical name; None = itemsize's dtype
    tp: int = 1             # neuron-axis tensor-parallel shard count


# ---------------------------------------------------------------------------
# quantized values: canonical dtype names + per-neuron symmetric scales
# ---------------------------------------------------------------------------

# canonical names accepted by quantize_spec / --values-dtype. fp8 resolves to
# e4m3 (the inference-weight variant) where this jax build carries it.
_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)
VALUES_DTYPES: dict[str, typing.Any] = {
    "f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8,
    **({"fp8": _FP8_DTYPE} if _FP8_DTYPE is not None else {}),
}
QUANTIZED_DTYPES = ("int8", "fp8")
# symmetric per-neuron scale maps the row's absmax onto the code's top value
_QMAX = {"int8": 127.0, "fp8": 448.0}  # e4m3 finite max = 448


def resolve_quantize_spec(spec) -> str | None:
    """Normalize a quantize spec (canonical name / dtype / None) to a
    canonical name, validating backend support. ``"f32"``/None mean "no
    quantization" (the export keeps float values, no scales)."""
    if spec is None or spec == "f32":
        return None
    if isinstance(spec, str):
        name = spec
    else:
        dt = jnp.dtype(spec)
        by_dtype = {jnp.dtype(v): k for k, v in VALUES_DTYPES.items()}
        name = by_dtype.get(dt, dt.name)
    if name in ("f32", "float32"):
        return None
    if name == "fp8" and _FP8_DTYPE is None:
        raise ValueError("fp8 values need a jax build with float8_e4m3fn; "
                         "this one has none — use int8 instead")
    if name not in VALUES_DTYPES:
        raise ValueError(f"unknown values dtype {spec!r}; expected one of "
                         f"{sorted(VALUES_DTYPES)}")
    return name


def values_itemsize(spec: FormatSpec) -> int:
    """Byte width of one stored value under ``spec`` (the real streamed
    width, not the compute dtype's)."""
    if spec.values_dtype is None:
        return spec.itemsize
    return jnp.dtype(VALUES_DTYPES[spec.values_dtype]).itemsize


def quantize_values(values, name: str, *, axis: int = -1):
    """Per-neuron symmetric quantization of a float values array.

    ``axis`` is the within-neuron axis reduced for the scale (fan-in ``k``
    for the condensed layouts, ``d_in`` for the structured gathered panel).
    Returns ``(q, scales)`` with ``scales = absmax/qmax`` as float32 and
    ``q ~ values/scales`` in the target dtype. All-zero rows (and the exact-
    zero padding slots the exports guarantee) quantize to exact 0 under a
    scale of 1, so dequantization reproduces their zeros bit-exactly.
    """
    name = typing.cast(str, resolve_quantize_spec(name))
    if name not in QUANTIZED_DTYPES:
        raise ValueError(f"quantize_values needs one of {QUANTIZED_DTYPES}, "
                         f"got {name!r}")
    v = values.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=axis, keepdims=True)
    scales = jnp.where(amax > 0, amax / _QMAX[name], 1.0).astype(jnp.float32)
    scaled = v / scales
    if name == "int8":
        q = jnp.clip(jnp.round(scaled), -127.0, 127.0).astype(jnp.int8)
    else:
        q = scaled.astype(VALUES_DTYPES[name])
    return q, jnp.squeeze(scales, axis=axis)


def dequantize_values(q, scales, *, axis: int = -1, dtype=jnp.float32):
    """Inverse of ``quantize_values``: broadcast the per-neuron scale back
    over ``axis`` (the reference dequantization the kernels fuse)."""
    return (q.astype(jnp.float32)
            * jnp.expand_dims(scales.astype(jnp.float32), axis)).astype(dtype)


def spec_for_stack(stack, stats: ExportStats, itemsize: int,
                   values_dtype: str | None = None, tp: int = 1) -> FormatSpec:
    """``stack`` is duck-typed (registry.SparseStack or any object with
    d_in/d_out; n_replicas defaults to 1 — benchmarks price bare shapes)."""
    return FormatSpec(d_in=stack.d_in, d_out=stack.d_out,
                      n_replicas=getattr(stack, "n_replicas", 1),
                      itemsize=itemsize,
                      k=max(stats.k, 1), max_active=max(stats.max_active, 1),
                      active_fraction=min(max(stats.active_fraction, 0.0), 1.0),
                      values_dtype=resolve_quantize_spec(values_dtype),
                      tp=max(int(tp), 1))


def shape_tuning_key(d_in: int, n_out: int, k: int, batch: int, *,
                     backend: str | None = None, itemsize: int = 4,
                     kind: str = "condensed",
                     scatter_width: int | None = None,
                     values_dtype: str | None = None) -> str:
    """Canonical autotune-cache key for a sparse kernel dispatch shape.

    Single definition shared by the formats' ``tuning_key`` methods, by
    ``repro.sparse.autotune`` (which persists entries under it) and by
    ``repro.kernels.ops`` (which looks blocks up at trace time) — the three
    can never drift. Batch is bucketed (``autotune.batch_bucket``) so a
    tuned entry serves every batch in its bucket, and the SAME buckets key
    the serving engine's request groups.

    ``kind`` separates the key spaces of the three kernels (entries are only
    valid for the kernel they were timed on):

    * ``"condensed"`` — the plain condensed gather; key layout unchanged
      from earlier cache versions.
    * ``"structured"`` — the column-gathered structured matmul; ``n_out`` is
      the padded active-column count, ``k`` is 0 (the contraction width is
      ``d_in`` itself) and ``scatter_width`` is the dense output width the
      fused epilogue scatters into (part of the kernel's VMEM geometry).
    * ``"coa"`` — the fused condensed-over-active kernel; ``n_out``/``k``
      are the surviving-row condensed arrays' dims and ``scatter_width`` is
      again the dense output width.

    ``values_dtype`` (a canonical name from ``VALUES_DTYPES``) distinguishes
    quantized key spaces: int8 and fp8 both store 1 byte per value, so the
    plain ``w{bits}`` width component cannot tell them apart — quantized
    dispatches key as ``w<name>`` (e.g. ``wint8``) instead. Float dtypes
    keep the byte-identical legacy ``w{bits}`` layout so every existing
    cache entry stays valid.
    """
    from repro.sparse import autotune as AT  # lazy: autotune is optional at import
    backend = backend or jax.default_backend()
    vd = resolve_quantize_spec(values_dtype)
    width = f"w{vd}" if vd in QUANTIZED_DTYPES else f"w{itemsize * 8}"
    key = (f"{backend}/{width}/d{d_in}/n{n_out}/k{k}"
           f"/b{AT.batch_bucket(batch)}")
    if kind != "condensed":
        key += f"/{kind}-o{scatter_width}"
    return key


def _gather_rate(profile, batch: int) -> float:
    """Batch-dependent gather throughput: profiles calibrated at two points
    (see plan.HardwareProfile.gather_rate) expose the activation-traffic
    cache cliff; single-rate profiles fall back to their scalar rate."""
    fn = getattr(profile, "gather_rate", None)
    if callable(fn):
        return fn(batch)
    return profile.gather_flops_per_s


def _vmap_lead(fn, n_lead: int):
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    return fn


def _realized_stats(mask) -> ExportStats:
    """Host-syncing fallback when the caller has no precomputed stats."""
    d_in = mask.shape[-2]
    nnz = jnp.sum(mask.astype(jnp.int32), axis=-2)
    act = jnp.any(mask, axis=-2)
    k, a, frac, mk = jax.device_get((
        jnp.max(nnz), jnp.max(jnp.sum(act.astype(jnp.int32), axis=-1)),
        jnp.mean(act.astype(jnp.float32)),
        jnp.min(jnp.where(nnz > 0, nnz, d_in))))
    return ExportStats(k=int(k), max_active=int(a), active_fraction=float(frac),
                       min_fan_in=int(mk))


def active_index_from_bools(neuron_active: jax.Array, a_pad: int) -> jax.Array:
    """Surviving-column index vector for the structured kernel, from the
    per-neuron active bools (lead dims vmapped).

    Returns (lead..., a_pad) int32: the ids of the active columns in
    increasing order, padded with the out-of-range sentinel ``d_out`` — the
    fused scatter epilogue drops sentinel slots exactly, so ``a_pad`` only
    needs to be an upper bound on each replica's realized active count
    (``padded_active_count`` rounds it to the 128-lane tile).
    """
    d_out = neuron_active.shape[-1]
    n = min(a_pad, d_out)

    def fn(act):
        order = jnp.argsort(~act, stable=True).astype(jnp.int32)
        oi = jnp.where(act[order[:n]], order[:n], d_out)
        return jnp.pad(oi, (0, a_pad - n),
                       constant_values=d_out).astype(jnp.int32)

    return _vmap_lead(fn, neuron_active.ndim - 1)(neuron_active)


def active_index_from_mask(mask: jax.Array, a_pad: int) -> jax.Array:
    """``active_index_from_bools`` of the mask's column-activity bools."""
    return active_index_from_bools(jnp.any(mask, axis=-2), a_pad)


# ---------------------------------------------------------------------------
# tensor-parallel (shard-blocked) layout helpers
#
# A TP export keeps every array at its GLOBAL shape but reorganizes the
# neuron/active-row axis into ``tp`` contiguous blocks, one per model-axis
# shard: values/indices rows are grouped by block and out_index/active_index
# entries are rebased to the block-LOCAL output range [0, d_out // tp) with
# the local sentinel ``d_out // tp`` marking padding. Sharding that axis over
# 'model' then gives each device exactly its own block, every gather stays
# shard-local against the replicated activation, and the constant fan-in
# guarantees the shards' work is exactly balanced (the property CSR lacks).
# ---------------------------------------------------------------------------


def _check_tp_shards(d_out: int, tp: int) -> int:
    tp = max(int(tp), 1)
    if tp > 1 and d_out % tp != 0:
        raise ValueError(f"tp_shards={tp} must divide the output width "
                         f"d_out={d_out} (neuron-axis blocks must be equal)")
    return tp


def _rebased_global_index(local_idx: jax.Array, tp: int,
                          d_out: int) -> jax.Array:
    """Map a block-LOCAL index vector (sentinel ``d_out // tp``) back to
    GLOBAL output positions (sentinel ``d_out``) — used wherever a TP
    instance must address the dense weight (refresh regathers) or reuse a
    global-layout program."""
    a_tp = local_idx.shape[-1] // tp
    wloc = d_out // tp
    off = (jnp.arange(local_idx.shape[-1], dtype=local_idx.dtype)
           // a_tp) * wloc
    return jnp.where(local_idx < wloc, local_idx + off,
                     d_out).astype(local_idx.dtype)


def _per_shard_active_bound(mask, tp: int) -> int:
    """Max active-neuron count over any tp-block of the output axis (ONE
    host sync — exports are host-driven, same as ``_realized_stats``)."""
    act = jnp.any(mask, axis=-2)
    wloc = act.shape[-1] // tp
    blocks = act.reshape(*act.shape[:-1], tp, wloc)
    n = jnp.max(jnp.sum(blocks.astype(jnp.int32), axis=-1))
    return max(int(jax.device_get(n)), 1)


# ---------------------------------------------------------------------------
# base class
# ---------------------------------------------------------------------------


class SparseFormat:
    """Base for the four serving formats (see module docstring).

    Subclasses are frozen dataclasses declaring ``_array_fields`` (pytree
    leaves) and ``_static_fields`` (hashable aux data); registration happens
    via ``_register``. Legacy dict-style access (``fmt["values"]``,
    ``"out_index" in fmt``) is kept as a migration convenience — new code
    should use the attributes.
    """
    format_name: typing.ClassVar[str]
    _array_fields: typing.ClassVar[tuple[str, ...]]
    _static_fields: typing.ClassVar[tuple[str, ...]] = ()

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in self._array_fields),
                tuple(getattr(self, f) for f in self._static_fields))

    @classmethod
    def tree_unflatten(cls, aux, children):
        kw = dict(zip(cls._array_fields, children))
        kw.update(zip(cls._static_fields, aux))
        return cls(**kw)

    # -- legacy dict-leaf compatibility ------------------------------------
    def __getitem__(self, key: str):
        if key in self._array_fields:
            return getattr(self, key)
        raise KeyError(key)

    def __contains__(self, key: str) -> bool:
        return key in self._array_fields

    def to_legacy_dict(self) -> dict:
        """The pre-redesign dict leaf this format replaces. ``None`` fields
        (optional fields the instance does not carry, e.g. unquantized
        ``scales``) are omitted — the legacy layouts never had them."""
        return {f: getattr(self, f) for f in self._array_fields
                if getattr(self, f) is not None}

    def map_arrays_with_names(self, fn):
        """Rebuild with each array field replaced by ``fn(name, value)`` —
        used by sharding/checkpoint code that walks trees by path. ``None``
        fields (legacy instances predating an optional field) pass through."""
        return dataclasses.replace(
            self, **{f: (None if getattr(self, f) is None
                         else fn(f, getattr(self, f)))
                     for f in self._array_fields})

    # -- protocol (subclass responsibilities) -------------------------------
    def apply(self, x: jax.Array, w: jax.Array | None = None) -> jax.Array:
        raise NotImplementedError

    @classmethod
    def export_from_dense(cls, w, mask, stats: ExportStats | None = None, *,
                          quantize_spec=None):
        raise NotImplementedError

    def spec(self) -> FormatSpec:
        raise NotImplementedError

    def cost(self, batch: int, profile) -> float:
        """Estimated seconds per serving step for THIS exported instance."""
        return self.estimate_cost(self.spec(), batch, profile)

    @classmethod
    def estimate_cost(cls, spec: FormatSpec, batch: int, profile) -> float:
        raise NotImplementedError

    @classmethod
    def estimate_weight_bytes(cls, spec: FormatSpec) -> int:
        """Per-step weight-side HBM traffic this format actually reads."""
        raise NotImplementedError

    @classmethod
    def estimate_values_bytes(cls, spec: FormatSpec) -> int:
        """The VALUE stream alone (weights + per-neuron scales, excluding
        index/topology arrays both a float and a quantized export of the
        same mask share) — the bytes quantization actually shrinks."""
        return cls.estimate_weight_bytes(spec)

    # -- tensor-parallel pricing (collective-aware cost model) --------------
    @classmethod
    def shard_spec(cls, spec: FormatSpec, tp: int) -> FormatSpec:
        """The per-shard geometry a ``tp``-way neuron partition executes:
        the output width and surviving-row bound shrink by ``1/tp``
        (max_active via ceil — the even-spread approximation the export
        realizes exactly for plain condensed and approximately for the
        ablation formats), fan-in and replica count stay global (every
        shard reads the full replicated activation)."""
        tp = max(int(tp), 1)
        if tp == 1:
            return spec
        return dataclasses.replace(
            spec, d_out=max(spec.d_out // tp, 1),
            max_active=max(-(-spec.max_active // tp), 1), tp=1)

    @classmethod
    def estimate_collective(cls, spec: FormatSpec, batch: int, profile,
                            tp: int) -> float:
        """Seconds for the per-layer output all-gather a ``tp``-way neuron
        partition pays: each device ring-exchanges the other shards' (B,
        d_out/tp) output blocks — ``(tp-1)/tp`` of the replicated activation
        — at the profile's measured interconnect rate."""
        tp = max(int(tp), 1)
        if tp <= 1:
            return 0.0
        b = max(int(batch), 1)
        bytes_ = (b * spec.n_replicas * spec.d_out * spec.itemsize
                  * (tp - 1) / tp)
        return bytes_ / profile.ici_bytes_per_s

    @classmethod
    def estimate_cost_sharded(cls, spec: FormatSpec, batch: int, profile,
                              tp: int) -> float:
        """Estimated seconds per serving step under a ``tp``-way neuron
        partition: the per-shard execution (1/tp of the weight stream and
        gather work — the constant fan-in keeps shards exactly balanced)
        PLUS the output all-gather. ``--path auto`` compares this against
        ``estimate_cost`` (replicate, pay full HBM) so the shard-vs-
        replicate crossover comes out of the cost model, not a flag."""
        tp = max(int(tp), 1)
        if tp <= 1:
            return cls.estimate_cost(spec, batch, profile)
        return (cls.estimate_cost(cls.shard_spec(spec, tp), batch, profile)
                + cls.estimate_collective(spec, batch, profile, tp))

    def tuning_key(self, batch: int, *, backend: str | None = None) -> str | None:
        """Autotune-cache key for this instance's kernel dispatch (None when
        the format has no tunable kernel)."""
        return None

    @classmethod
    def spec_tuning_key(cls, spec: FormatSpec, batch: int, *,
                        backend: str | None = None) -> str | None:
        return None

    @classmethod
    def abstract(cls, lead: tuple[int, ...], d_in: int, d_out: int, k: int,
                 dtype) -> "SparseFormat":
        """ShapeDtypeStruct-leaved instance (dry-run / compile-only)."""
        raise NotImplementedError

    def donate_refresh(self, w, mask, stats: ExportStats | None = None, *,
                       donate: bool = True) -> "SparseFormat":
        """Full re-export from (w, mask), reusing ``self``'s device buffers
        when the new arrays' avals match. CAUTION: with ``donate=True`` and
        matching avals, ``self``'s arrays are invalidated."""
        return type(self).export_from_dense(w, mask, stats)

    def refresh_values(self, w, mask, *, donate: bool = True) -> "SparseFormat":
        """Values-only refresh under unchanged topology (no-op for formats
        that read the live weights at execution time)."""
        return self

    def rebuild_missing(self, missing: frozenset) -> "SparseFormat":
        """Recompute array fields an older checkpoint archive did not carry
        (``missing``: field names the restore found no arrays for). Default:
        keep the template's values. Overridden where a derived field must
        stay consistent with restored ones."""
        return self

    def restore_finalize(self) -> "SparseFormat":
        """Reconcile restored arrays with the template's declared storage
        dtype. Checkpoint restore keeps each array at the ARCHIVE's dtype,
        so a pre-quantization archive restored into a quantized template
        arrives with float values (re-quantize), and a quantized archive
        restored into a float template arrives with int8/fp8 values plus
        adopted scales (dequantize). Default: nothing to reconcile."""
        return self

    def adopt_arrays(self, new: dict, *, donate: bool = True):
        """Rebuild with the array fields named in ``new`` replaced by the
        given (host or device) arrays, donating each matching old buffer
        (see :func:`adopt_array`). The sync-subscriber apply path: the new
        bytes were exported remotely, so this is replacement, not
        re-export."""
        unknown = set(new) - set(self._array_fields)
        if unknown:
            raise ValueError(f"{type(self).__name__} has no array fields "
                             f"{sorted(unknown)}")
        return dataclasses.replace(
            self, **{f: adopt_array(v, getattr(self, f), donate=donate)
                     for f, v in new.items()})


def _register(cls):
    jax.tree_util.register_pytree_node_class(cls)
    return cls


# ---------------------------------------------------------------------------
# buffer adoption: write an externally-computed array over a live one
#
# The sync subscriber (repro.sync) hands the engine host arrays that were
# exported on the TRAINER -- there is no (w, mask) pair to re-export from,
# so the donated-refresh programs above don't apply. Adoption is the
# degenerate donated program: identity over the new array with the old
# buffer donated, so XLA writes the incoming bytes into the replica's
# existing allocation and the old jax.Array is invalidated at dispatch --
# zero weight-memory doubling, same guarantee as donate_refresh.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(1,), keep_unused=True)
def _adopt_donated(new, old):
    return new


def adopt_array(new, old=None, *, donate: bool = True):
    """Move ``new`` (host or device) onto device, donating ``old``'s buffer
    when it is a live matching-aval jax.Array. Falls back to a plain
    transfer when shapes/dtypes differ (a topology delta that changed k or
    the active-row count) or donation is off."""
    arr = jnp.asarray(new)
    if (donate and old is not None and isinstance(old, jax.Array)
            and not old.is_deleted()
            and old.shape == arr.shape and old.dtype == arr.dtype):
        return _adopt_donated(arr, old)
    return arr


# ---------------------------------------------------------------------------
# jitted donated re-export programs (shared by Condensed / CondensedOverActive)
#
# A serving plan refreshes against a LIVE job, so the re-export must not
# transiently hold two copies of a stack's condensed weights. These run the
# re-condense / values-regather as ONE jitted program with the old buffers
# donated: when the new leaf has the same avals (fan-in k and active-row
# count unchanged — the common case for a DST step, which rewires at
# constant fan-in), XLA writes the new arrays into the donated buffers and
# the old jax.Arrays are invalidated at dispatch. keep_unused=True stops jit
# from pruning the donated args (the output aliases them by shape/dtype, not
# dataflow). No weight data ever crosses to the host.
# ---------------------------------------------------------------------------


def _condense_active_stack(weight, mask, k: int, a: int, tp: int = 1):
    """Condensed-over-active arrays for one stack (vmapped over lead dims).

    Drops ablated output neurons FIRST (Fig. 4's "structured" move), then
    condenses only the surviving columns to constant fan-in ``k``. ``a`` is
    the (static) max active-neuron count across the stack's replicas; rows
    beyond a replica's realized active count are padding with values 0 and
    an out-of-range ``out_index`` so the scatter in kernels.ops drops them.

    A neuron is treated as active iff its mask column has any non-zero —
    derived from the mask itself (not the trainer's neuron_active
    bookkeeping) so the representation is exact vs masked-dense by
    construction.

    ``tp > 1`` builds the shard-blocked TP layout instead: the output axis
    splits into ``tp`` contiguous blocks, each condensed independently to
    ``a`` surviving rows (``a`` is then the PER-SHARD bound), with
    ``out_index`` rebased block-locally (sentinel ``d_out // tp``). The
    returned arrays are the tp=1 shapes with ``tp * a`` total rows, grouped
    by block.
    """
    d_out = weight.shape[-1]

    def fn(w, m):
        col_active = jnp.any(m, axis=0)                      # (d_out,)
        order = jnp.argsort(~col_active, stable=True).astype(jnp.int32)
        out_index = order[:a]                                # active cols first
        sel = col_active[out_index]                          # (a,)
        w_sel = jnp.take(w, out_index, axis=1)
        m_sel = jnp.take(m, out_index, axis=1) & sel[None, :]
        vals, idx = topology.dense_to_condensed(w_sel * m_sel, m_sel, k)
        return vals, idx, jnp.where(sel, out_index, d_out).astype(jnp.int32)

    if tp <= 1:
        return _vmap_lead(fn, weight.ndim - 2)(weight, mask)

    wloc = d_out // tp

    def blk(w_s, m_s):
        col_active = jnp.any(m_s, axis=0)                    # (wloc,)
        order = jnp.argsort(~col_active, stable=True).astype(jnp.int32)
        out_index = order[:a]
        sel = col_active[out_index]
        w_sel = jnp.take(w_s, out_index, axis=1)
        m_sel = jnp.take(m_s, out_index, axis=1) & sel[None, :]
        vals, idx = topology.dense_to_condensed(w_sel * m_sel, m_sel, k)
        # indices address the FULL d_in rows (x stays replicated under TP);
        # out_index is block-LOCAL with the per-shard sentinel wloc
        return vals, idx, jnp.where(sel, out_index, wloc).astype(jnp.int32)

    def fn_tp(w, m):
        d_in = w.shape[0]
        wb = jnp.moveaxis(w.reshape(d_in, tp, wloc), 1, 0)   # (tp, d_in, wloc)
        mb = jnp.moveaxis(m.reshape(d_in, tp, wloc), 1, 0)
        vals, idx, oi = jax.vmap(blk)(wb, mb)                # (tp, a, ...)
        return (vals.reshape(tp * a, k), idx.reshape(tp * a, k),
                oi.reshape(tp * a))

    return _vmap_lead(fn_tp, weight.ndim - 2)(weight, mask)


@functools.partial(jax.jit, static_argnames=("k",), donate_argnums=(2, 3),
                   keep_unused=True)
def _recondense_donated(weight, mask, old_values, old_indices, *, k: int):
    fn = lambda w, m: topology.dense_to_condensed(w * m, m, k)
    vals, idx = _vmap_lead(fn, weight.ndim - 2)(weight, mask)
    return vals.astype(old_values.dtype), idx


@functools.partial(jax.jit, static_argnames=("k", "a", "tp"),
                   donate_argnums=(2, 3, 4), keep_unused=True)
def _recondense_active_donated(weight, mask, old_values, old_indices,
                               old_out_index, *, k: int, a: int, tp: int = 1):
    vals, idx, oi = _condense_active_stack(weight, mask, k, a, tp)
    return vals.astype(old_values.dtype), idx, oi


def _gather_at_indices(weight, mask, indices, out_index=None):
    def fn(w, m, idx, oi=None):
        wm_t = (w * m).T                                     # (d_out, d_in)
        if oi is not None:  # select surviving columns (clip: padding dropped)
            wm_t = jnp.take(wm_t, jnp.minimum(oi, wm_t.shape[0] - 1), axis=0)
        return jnp.take_along_axis(wm_t, idx, axis=1)

    n_lead = weight.ndim - 2
    if out_index is None:
        return _vmap_lead(fn, n_lead)(weight, mask, indices)
    return _vmap_lead(fn, n_lead)(weight, mask, indices, out_index)


@functools.partial(jax.jit, donate_argnums=(2,), keep_unused=True)
def _revalue_donated(weight, mask, old_values, indices):
    return _gather_at_indices(weight, mask, indices).astype(old_values.dtype)


@functools.partial(jax.jit, donate_argnums=(2,), keep_unused=True)
def _revalue_active_donated(weight, mask, old_values, indices, out_index):
    return _gather_at_indices(weight, mask, indices,
                              out_index).astype(old_values.dtype)


# quantized variants: same donation contract, with the per-neuron quantize
# epilogue fused into the jitted program so the new int8/fp8 values and f32
# scales are written straight into the OLD quantized buffers — a live
# quantized plan refreshes without ever holding a float copy of the stack.

@functools.partial(jax.jit, static_argnames=("k", "qdt"),
                   donate_argnums=(2, 3, 4), keep_unused=True)
def _recondense_quantized_donated(weight, mask, old_values, old_indices,
                                  old_scales, *, k: int, qdt: str):
    fn = lambda w, m: topology.dense_to_condensed(w * m, m, k)
    vals, idx = _vmap_lead(fn, weight.ndim - 2)(weight, mask)
    q, s = quantize_values(vals, qdt)
    return q, idx, s


@functools.partial(jax.jit, static_argnames=("k", "a", "qdt", "tp"),
                   donate_argnums=(2, 3, 4, 5), keep_unused=True)
def _recondense_active_quantized_donated(weight, mask, old_values, old_indices,
                                         old_out_index, old_scales, *,
                                         k: int, a: int, qdt: str,
                                         tp: int = 1):
    vals, idx, oi = _condense_active_stack(weight, mask, k, a, tp)
    q, s = quantize_values(vals, qdt)
    return q, idx, oi, s


@functools.partial(jax.jit, static_argnames=("qdt",), donate_argnums=(2, 3),
                   keep_unused=True)
def _revalue_quantized_donated(weight, mask, old_values, old_scales, indices,
                               *, qdt: str):
    return quantize_values(_gather_at_indices(weight, mask, indices), qdt)


@functools.partial(jax.jit, static_argnames=("qdt",), donate_argnums=(2, 3),
                   keep_unused=True)
def _revalue_active_quantized_donated(weight, mask, old_values, old_scales,
                                      indices, out_index, *, qdt: str):
    return quantize_values(
        _gather_at_indices(weight, mask, indices, out_index), qdt)


def _gather_active_panel(weight, mask, active_index):
    """(lead..., d_in, a_pad) surviving-column panel of ``weight * mask``.
    Sentinel (padding) slots are zeroed so they quantize to exact 0 and
    never pollute a real column's scale."""
    def fn(w, m, ai):
        d_out = w.shape[-1]
        g = jnp.take(w * m, jnp.minimum(ai, d_out - 1), axis=1)
        return jnp.where((ai < d_out)[None, :], g, 0.0)

    return _vmap_lead(fn, weight.ndim - 2)(weight, mask, active_index)


@functools.partial(jax.jit, static_argnames=("qdt",), donate_argnums=(3, 4),
                   keep_unused=True)
def _revalue_structured_quantized_donated(weight, mask, active_index,
                                          old_values, old_scales, *, qdt: str):
    return quantize_values(_gather_active_panel(weight, mask, active_index),
                           qdt, axis=-2)


def is_quantized_storage(arr_or_dtype) -> bool:
    """Is this array (or dtype) stored in one of the quantized values
    dtypes? Used by checkpoint restore to decide when a template/archive
    dtype mismatch means "re-/de-quantize" rather than "cast"."""
    dt = jnp.dtype(getattr(arr_or_dtype, "dtype", arr_or_dtype))
    return any(jnp.dtype(VALUES_DTYPES[n]) == dt for n in QUANTIZED_DTYPES
               if n in VALUES_DTYPES)


def _finalize_quantized_restore(fmt, *, axis: int = -1):
    """Reconcile a restored format's values/scales with its declared
    ``values_dtype`` (see ``SparseFormat.restore_finalize``). ``axis`` is
    the per-neuron reduction axis of the class's scale convention."""
    vals = fmt.values
    if vals is None or isinstance(vals, jax.ShapeDtypeStruct):
        return fmt
    declared = fmt.values_dtype
    if declared in QUANTIZED_DTYPES:
        if jnp.issubdtype(vals.dtype, jnp.floating) \
                and not is_quantized_storage(vals):
            q, s = quantize_values(vals, declared, axis=axis)
            return dataclasses.replace(fmt, values=q, scales=s)
        return fmt
    if is_quantized_storage(vals) and fmt.scales is not None:
        # quantized archive into a float template: dequantize and drop scales
        deq = dequantize_values(vals, fmt.scales, axis=axis)
        return dataclasses.replace(fmt, values=deq, scales=None)
    return fmt


# ---------------------------------------------------------------------------
# the four formats
# ---------------------------------------------------------------------------


@_register
@dataclasses.dataclass(frozen=True, eq=False)
class MaskedDense(SparseFormat):
    """Training layout: dense weight + bool mask, dense MXU matmul.

    ``weight_itemsize`` (static) records the dense weight's dtype bytes so
    the instance can price its own HBM traffic without seeing the weight.
    """
    mask: jax.Array                      # (lead..., d_in, d_out) bool
    weight_itemsize: int = 4

    format_name: typing.ClassVar[str] = "masked"
    _array_fields: typing.ClassVar[tuple[str, ...]] = ("mask",)
    _static_fields: typing.ClassVar[tuple[str, ...]] = ("weight_itemsize",)

    def apply(self, x, w=None):
        return x @ apply_mask_for_forward(w, self.mask).astype(x.dtype)

    @classmethod
    def export_from_dense(cls, w, mask, stats=None):
        return cls(mask=mask, weight_itemsize=jnp.dtype(w.dtype).itemsize)

    def spec(self) -> FormatSpec:
        d_in, d_out = self.mask.shape[-2:]
        n = 1
        for s in self.mask.shape[:-2]:
            n *= s
        return FormatSpec(d_in=d_in, d_out=d_out, n_replicas=n,
                          itemsize=self.weight_itemsize, k=d_in,
                          max_active=d_out, active_fraction=1.0)

    @classmethod
    def estimate_cost(cls, spec, batch, profile):
        b = max(int(batch), 1)
        flops = 2.0 * b * spec.n_replicas * spec.d_in * spec.d_out
        return max(cls.estimate_weight_bytes(spec) / profile.hbm_bytes_per_s,
                   flops / profile.mxu_flops_per_s)

    @classmethod
    def estimate_weight_bytes(cls, spec):
        # dense weight + the bool mask the masked path also reads
        return spec.n_replicas * spec.d_in * spec.d_out * (spec.itemsize + 1)

    @classmethod
    def estimate_cost_sharded(cls, spec, batch, profile, tp):
        # masked-dense is the REPLICATED path under TP: each device serves a
        # data-parallel replica of the dense weight (full HBM stream, zero
        # collectives) — the alternative the collective-priced sharded
        # formats are compared against
        return cls.estimate_cost(spec, batch, profile)

    @classmethod
    def abstract(cls, lead, d_in, d_out, k, dtype):
        return cls(mask=jax.ShapeDtypeStruct((*lead, d_in, d_out), jnp.bool_),
                   weight_itemsize=jnp.dtype(dtype).itemsize)

    def donate_refresh(self, w, mask, stats=None, *, donate=True):
        return type(self).export_from_dense(w, mask, stats)


@_register
@dataclasses.dataclass(frozen=True, eq=False)
class StructuredFanIn(SparseFormat):
    """Fig. 4 "structured": ablated neurons dropped, active columns dense.

    Executed by the column-gathered Pallas kernel
    (``kernels.ops.structured_linear`` over ``active_index`` — surviving
    column ids padded to the 128-lane tile with the ``d_out`` sentinel): the
    matmul runs over only the ``a_pad`` surviving columns and a fused
    scatter epilogue writes exact zeros for ablated neurons, so per-step HBM
    weight bytes and MXU FLOPs scale with the active fraction.
    ``estimate_cost`` prices exactly that (padded) execution. Exact only for
    ablation-only masks — bit-identical to ``ops.structured_dense``.
    ``active_index=None`` (legacy instances built before the field existed)
    falls back to the reference full-dense path.
    """
    neuron_active: jax.Array             # (lead..., d_out) bool
    active_index: jax.Array | None = None  # (lead..., a_pad) int32, pad=d_out
    d_in: int = 0                        # dense weight fan-in (for pricing)
    weight_itemsize: int = 4
    values: jax.Array | None = None      # (lead..., d_in, a_pad) quantized panel
    scales: jax.Array | None = None      # (lead..., a_pad) f32 per column
    values_dtype: str | None = None      # canonical name when quantized
    tp: int = 1                          # shard-blocked TP layout when > 1

    format_name: typing.ClassVar[str] = "structured"
    _array_fields: typing.ClassVar[tuple[str, ...]] = ("neuron_active",
                                                       "active_index",
                                                       "values", "scales")
    _static_fields: typing.ClassVar[tuple[str, ...]] = ("d_in",
                                                        "weight_itemsize",
                                                        "values_dtype", "tp")

    def apply(self, x, w=None):
        if self.tp > 1:
            # shard-blocked layout: active_index is grouped in tp blocks and
            # LOCALLY rebased (sentinel d_out // tp) — the vmap-over-blocks
            # ops partition shard-locally under a 'model'-sharded block axis
            if self.values is not None and self.active_index is not None:
                panel = dequantize_values(self.values, self.scales, axis=-2,
                                          dtype=x.dtype)
                return ops.structured_gathered_linear_tp_nd(
                    x, panel, self.active_index,
                    self.neuron_active.shape[-1], self.tp)
            return ops.structured_linear_tp_nd(x, w, self.active_index,
                                               self.tp)
        if self.values is not None and self.active_index is not None:
            # quantized export: the gathered active-column panel is stored
            # in the format itself; dequantize the 1-byte stream and feed
            # the pre-gathered kernel path (no live-weight read, no
            # column-gather pass)
            panel = dequantize_values(self.values, self.scales, axis=-2,
                                      dtype=x.dtype)
            return ops.structured_gathered_linear_nd(
                x, panel, self.active_index, self.neuron_active.shape[-1],
                values_dtype=self.values_dtype)
        if self.active_index is None:
            return ops.structured_dense(x, w.astype(x.dtype),
                                        self.neuron_active)
        return ops.structured_linear_nd(x, w, self.active_index)

    @classmethod
    def export_from_dense(cls, w, mask, stats=None, *, quantize_spec=None,
                          tp_shards: int = 1):
        stats = stats if stats is not None else _realized_stats(mask)
        d_out = int(mask.shape[-1])
        tp = _check_tp_shards(d_out, tp_shards)
        if tp > 1:
            # per-block surviving-column ids, LOCALLY rebased (sentinel
            # d_out // tp), grouped into one (lead..., tp * a_pad) vector
            wloc = d_out // tp
            act = jnp.any(mask, axis=-2)
            a_pad = padded_active_count(_per_shard_active_bound(mask, tp),
                                        wloc)
            blocks = act.reshape(*act.shape[:-1], tp, wloc)
            ai = active_index_from_bools(blocks, a_pad)
            ai = ai.reshape(*act.shape[:-1], tp * a_pad)
        else:
            a_pad = padded_active_count(max(stats.max_active, 1), d_out)
            ai = active_index_from_mask(mask, a_pad)
        qdt = resolve_quantize_spec(quantize_spec)
        vals = scales = None
        if qdt in QUANTIZED_DTYPES:
            gi = _rebased_global_index(ai, tp, d_out) if tp > 1 else ai
            vals, scales = quantize_values(_gather_active_panel(w, mask, gi),
                                           qdt, axis=-2)
        else:
            qdt = None  # a bare storage cast has nothing to store here
        return cls(neuron_active=jnp.any(mask, axis=-2), active_index=ai,
                   d_in=int(mask.shape[-2]),
                   weight_itemsize=jnp.dtype(w.dtype).itemsize,
                   values=vals, scales=scales, values_dtype=qdt, tp=tp)

    def _a_pad(self) -> int:
        d_out = self.neuron_active.shape[-1]
        return (self.active_index.shape[-1] if self.active_index is not None
                else padded_active_count(d_out, d_out))

    def spec(self) -> FormatSpec:
        d_out = self.neuron_active.shape[-1]
        n = 1
        for s in self.neuron_active.shape[:-1]:
            n *= s
        a_pad = self._a_pad()
        return FormatSpec(d_in=self.d_in, d_out=d_out, n_replicas=n,
                          itemsize=self.weight_itemsize, k=self.d_in,
                          max_active=a_pad,
                          active_fraction=min(a_pad / max(d_out, 1), 1.0),
                          values_dtype=self.values_dtype, tp=self.tp)

    @classmethod
    def estimate_cost(cls, spec, batch, profile):
        # priced at the EXPORTED (lane-padded) column count the kernel runs
        # over; the compute term includes the fused one-hot scatter epilogue
        # (an MXU matmul of the compact tile against the selection matrix)
        b = max(int(batch), 1)
        a_pad = padded_active_count(spec.max_active, spec.d_out)
        flops = 2.0 * b * spec.n_replicas * a_pad * (spec.d_in + spec.d_out)
        return max(cls.estimate_weight_bytes(spec) / profile.hbm_bytes_per_s,
                   flops / profile.mxu_flops_per_s)

    @classmethod
    def estimate_weight_bytes(cls, spec):
        # the gathered (d_in, a_pad) weight panel (real stored width, + the
        # f32 per-column scale when quantized) + the int32 active_index;
        # neuron_active is not read on the gathered hot path
        a_pad = padded_active_count(spec.max_active, spec.d_out)
        return cls.estimate_values_bytes(spec) + spec.n_replicas * a_pad * 4

    @classmethod
    def estimate_values_bytes(cls, spec):
        a_pad = padded_active_count(spec.max_active, spec.d_out)
        vb = spec.n_replicas * spec.d_in * a_pad * values_itemsize(spec)
        if spec.values_dtype in QUANTIZED_DTYPES:
            vb += spec.n_replicas * a_pad * 4
        return vb

    def tuning_key(self, batch, *, backend=None):
        if self.active_index is None:
            return None  # legacy instance: reference path, nothing to tune
        # per-SHARD shapes under TP: a tuned entry describes the block one
        # device executes (the backend-keyed cache machinery is unchanged)
        return shape_tuning_key(
            self.d_in, self._a_pad() // self.tp, 0, batch, backend=backend,
            itemsize=self.weight_itemsize, kind="structured",
            scatter_width=self.neuron_active.shape[-1] // self.tp,
            values_dtype=self.values_dtype)

    @classmethod
    def spec_tuning_key(cls, spec, batch, *, backend=None):
        s = cls.shard_spec(spec, spec.tp)
        a_pad = padded_active_count(s.max_active, s.d_out)
        return shape_tuning_key(s.d_in, a_pad, 0, batch, backend=backend,
                                itemsize=s.itemsize, kind="structured",
                                scatter_width=s.d_out,
                                values_dtype=s.values_dtype)

    @classmethod
    def abstract(cls, lead, d_in, d_out, k, dtype, tp: int = 1):
        # a_pad = padded d_out static bound (no realized ablation counts at
        # lowering time); the concrete export shrinks it to the real count.
        # Under TP each of the tp blocks pads independently.
        tp = _check_tp_shards(d_out, tp)
        wloc = d_out // tp
        a_pad = padded_active_count(wloc, wloc) * tp
        return cls(neuron_active=jax.ShapeDtypeStruct((*lead, d_out),
                                                      jnp.bool_),
                   active_index=jax.ShapeDtypeStruct((*lead, a_pad),
                                                     jnp.int32),
                   d_in=d_in, weight_itemsize=jnp.dtype(dtype).itemsize,
                   tp=tp)

    def donate_refresh(self, w, mask, stats=None, *, donate=True):
        return type(self).export_from_dense(w, mask, stats,
                                            quantize_spec=self.values_dtype,
                                            tp_shards=self.tp)

    def refresh_values(self, w, mask, *, donate: bool = True):
        """No-op for float instances (they read the live weights). Quantized
        instances hold a stale panel: regather + requantize at the stored
        active_index, donated into the old 1-byte buffers."""
        if self.values is None or self.active_index is None:
            return self
        # TP instances store LOCAL column ids — rebase to the global output
        # axis for the dense-weight regather (layout reproduced exactly)
        ai = (_rebased_global_index(self.active_index, self.tp,
                                    self.neuron_active.shape[-1])
              if self.tp > 1 else self.active_index)
        if donate:
            vals, s = _revalue_structured_quantized_donated(
                w, mask, ai, self.values, self.scales,
                qdt=self.values_dtype)
        else:
            vals, s = quantize_values(
                _gather_active_panel(w, mask, ai),
                self.values_dtype, axis=-2)
        return dataclasses.replace(self, values=vals, scales=s)

    def rebuild_missing(self, missing):
        # archives written before active_index existed: derive it from the
        # RESTORED neuron_active, sized by the restored masks' realized
        # active count — NOT the template's length, which was sized from the
        # template's own (e.g. fresh-init) masks and may be too short for
        # the archive's actives (a too-short vector would silently zero the
        # overflow columns). Restore runs host-side on concrete arrays, so
        # the one scalar sync is fine here.
        out = self
        if "active_index" in missing and "neuron_active" not in missing \
                and self.active_index is not None:
            act = self.neuron_active
            if self.tp > 1:
                # TP templates rebuild the shard-blocked LOCAL layout
                wloc = act.shape[-1] // self.tp
                blocks = act.reshape(*act.shape[:-1], self.tp, wloc)
                realized = int(jax.device_get(jnp.max(
                    jnp.sum(blocks.astype(jnp.int32), axis=-1))))
                a_pad = padded_active_count(max(realized, 1), wloc)
                ai = active_index_from_bools(blocks, a_pad)
                ai = ai.reshape(*act.shape[:-1], self.tp * a_pad)
            else:
                realized = int(jax.device_get(
                    jnp.max(jnp.sum(act.astype(jnp.int32), axis=-1))))
                a_pad = padded_active_count(max(realized, 1), act.shape[-1])
                ai = active_index_from_bools(act, a_pad)
            out = dataclasses.replace(out, active_index=ai)
        if "values" in missing and out.values_dtype in QUANTIZED_DTYPES:
            # the archive predates the quantized panel and the panel cannot
            # be rebuilt without the live dense weight: degrade to the
            # live-weight (unquantized) execution path; the next
            # donate_refresh re-exports the panel at the declared dtype
            return dataclasses.replace(out, values=None, scales=None)
        if "scales" in missing and out.values_dtype in QUANTIZED_DTYPES:
            out = out.restore_finalize()
        return out

    def restore_finalize(self):
        return _finalize_quantized_restore(self, axis=-2)


@_register
@dataclasses.dataclass(frozen=True, eq=False)
class Condensed(SparseFormat):
    """Fig. 4 "condensed": the constant fan-in gather layout (paper Alg. 1).

    ``d_in`` (static) is the dense fan-in the indices address — needed for
    the autotune cache key (the kernel's VMEM footprint depends on the
    activation row length), not for ``apply``.

    Quantized exports (``quantize_spec="int8"``/``"fp8"``) store ``values``
    at 1 byte/element with a per-neuron float32 ``scales`` row-scale; the
    dequantize (one multiply per OUTPUT, after the k-reduction) is fused
    into the Pallas gather kernel, so the decode hot path streams the weight
    values at the quantized width. ``values_dtype`` (static) records the
    declared storage dtype so checkpoint restore can re-quantize a float
    archive into this template.
    """
    values: jax.Array                    # (lead..., d_out, k)
    indices: jax.Array                   # (lead..., d_out, k) int32
    d_in: int = 0
    scales: jax.Array | None = None      # (lead..., d_out) f32 when quantized
    values_dtype: str | None = None      # canonical name when quantized
    tp: int = 1                          # shard-blocked TP execution when > 1

    format_name: typing.ClassVar[str] = "condensed"
    _array_fields: typing.ClassVar[tuple[str, ...]] = ("values", "indices",
                                                       "scales")
    _static_fields: typing.ClassVar[tuple[str, ...]] = ("d_in", "values_dtype",
                                                        "tp")

    def apply(self, x, w=None):
        if self.tp > 1:
            # the plain condensed layout's contiguous neuron rows ARE the
            # shard blocks (constant fan-in: exactly balanced) — no array
            # reorganization, only the vmap-over-blocks execution
            return ops.condensed_linear_tp_nd(
                x, (self.values if self.scales is not None
                    else self.values.astype(x.dtype)),
                self.indices, self.tp, scales=self.scales)
        if self.scales is not None:
            return ops.condensed_linear_nd(x, self.values, self.indices,
                                           scales=self.scales)
        return ops.condensed_linear_nd(x, self.values.astype(x.dtype),
                                       self.indices)

    @classmethod
    def export_from_dense(cls, w, mask, stats=None, *, quantize_spec=None,
                          tp_shards: int = 1):
        stats = stats if stats is not None else _realized_stats(mask)
        k = max(stats.k, 1)
        # the exported arrays are IDENTICAL for every tp: contiguous neuron
        # rows already partition into equal blocks (validated divisible)
        tp = _check_tp_shards(int(w.shape[-1]), tp_shards)
        fn = lambda w_, m_: topology.dense_to_condensed(w_ * m_, m_, k)
        vals, idx = _vmap_lead(fn, w.ndim - 2)(w, mask)
        qdt = resolve_quantize_spec(quantize_spec)
        if qdt in QUANTIZED_DTYPES:
            q, s = quantize_values(vals, qdt)
            return cls(values=q, indices=idx, d_in=int(w.shape[-2]),
                       scales=s, values_dtype=qdt, tp=tp)
        if qdt is not None:  # plain storage-dtype cast (e.g. bf16)
            vals = vals.astype(VALUES_DTYPES[qdt])
        return cls(values=vals, indices=idx, d_in=int(w.shape[-2]), tp=tp)

    def spec(self) -> FormatSpec:
        d_out, k = self.values.shape[-2:]
        n = 1
        for s in self.values.shape[:-2]:
            n *= s
        quantized = self.values_dtype in QUANTIZED_DTYPES
        itemsize = (jnp.dtype(self.scales.dtype).itemsize
                    if quantized and self.scales is not None
                    else jnp.dtype(self.values.dtype).itemsize)
        return FormatSpec(d_in=self.d_in, d_out=d_out, n_replicas=n,
                          itemsize=itemsize, k=k, max_active=d_out,
                          active_fraction=1.0,
                          values_dtype=self.values_dtype, tp=self.tp)

    @classmethod
    def estimate_cost(cls, spec, batch, profile):
        b = max(int(batch), 1)
        gather_flops = 2.0 * b * spec.n_replicas * spec.d_out * spec.k
        return max(cls.estimate_weight_bytes(spec) / profile.hbm_bytes_per_s,
                   gather_flops / _gather_rate(profile, b))

    @classmethod
    def estimate_weight_bytes(cls, spec):
        # values at the real stored width + int32 indices (+ the f32 scales
        # row when quantized)
        return (cls.estimate_values_bytes(spec)
                + spec.n_replicas * spec.d_out * spec.k * 4)

    @classmethod
    def estimate_values_bytes(cls, spec):
        vb = spec.n_replicas * spec.d_out * spec.k * values_itemsize(spec)
        if spec.values_dtype in QUANTIZED_DTYPES:
            vb += spec.n_replicas * spec.d_out * 4  # per-neuron f32 scale
        return vb

    def tuning_key(self, batch, *, backend=None):
        d_out, k = self.values.shape[-2:]
        # per-SHARD shapes under TP (d_out shrinks by 1/tp; same cache)
        return shape_tuning_key(
            self.d_in, d_out // self.tp, k, batch, backend=backend,
            itemsize=jnp.dtype(self.values.dtype).itemsize,
            values_dtype=self.values_dtype)

    @classmethod
    def spec_tuning_key(cls, spec, batch, *, backend=None):
        s = cls.shard_spec(spec, spec.tp)
        return shape_tuning_key(s.d_in, s.d_out, s.k, batch,
                                backend=backend, itemsize=s.itemsize,
                                values_dtype=s.values_dtype)

    @classmethod
    def abstract(cls, lead, d_in, d_out, k, dtype, tp: int = 1):
        shape = (*lead, d_out, k)
        return cls(values=jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)),
                   indices=jax.ShapeDtypeStruct(shape, jnp.int32), d_in=d_in,
                   tp=_check_tp_shards(d_out, tp))

    def donate_refresh(self, w, mask, stats=None, *, donate=True):
        stats = stats if stats is not None else _realized_stats(mask)
        k = max(stats.k, 1)
        shape = (*w.shape[:-2], w.shape[-1], k)
        if donate and self.values.shape == shape:
            # the donated re-condense is tp-agnostic: the arrays' layout is
            # identical for every tp (contiguous row blocks), so the static
            # tp rides through dataclasses.replace unchanged
            if (self.values_dtype in QUANTIZED_DTYPES
                    and self.scales is not None):
                vals, idx, s = _recondense_quantized_donated(
                    w, mask, self.values, self.indices, self.scales,
                    k=k, qdt=self.values_dtype)
                return dataclasses.replace(self, values=vals, indices=idx,
                                           scales=s)
            if self.values.dtype == w.dtype:
                vals, idx = _recondense_donated(w, mask, self.values,
                                                self.indices, k=k)
                return dataclasses.replace(self, values=vals, indices=idx)
        return type(self).export_from_dense(w, mask, stats,
                                            quantize_spec=self.values_dtype,
                                            tp_shards=self.tp)

    def refresh_values(self, w, mask, *, donate: bool = True):
        """Regather ``w * mask`` at the stored indices (topology unchanged).

        Exact because padding slots point at inactive rows
        (dense_to_condensed's invariant), so they re-gather exact zeros.
        ``donate=True`` writes the new values into the OLD values buffer
        (see the donated-program block comment); indices are reused
        verbatim either way. Quantized instances re-quantize in the same
        donated program (fresh scales from the regathered rows).
        """
        if self.values_dtype in QUANTIZED_DTYPES and self.scales is not None:
            if donate:
                vals, s = _revalue_quantized_donated(
                    w, mask, self.values, self.scales, self.indices,
                    qdt=self.values_dtype)
            else:
                vals, s = quantize_values(_gather_at_indices(w, mask,
                                                             self.indices),
                                          self.values_dtype)
            return dataclasses.replace(self, values=vals, scales=s)
        if donate:
            vals = _revalue_donated(w, mask, self.values, self.indices)
        else:
            vals = _gather_at_indices(w, mask,
                                      self.indices).astype(self.values.dtype)
        return dataclasses.replace(self, values=vals)

    def rebuild_missing(self, missing):
        # a pre-quantization archive restored into a quantized template has
        # no scales: re-derive them (and the quantized codes) from the
        # restored float values
        if "scales" in missing and self.values_dtype in QUANTIZED_DTYPES:
            return self.restore_finalize()
        return self

    def restore_finalize(self):
        return _finalize_quantized_restore(self)


@_register
@dataclasses.dataclass(frozen=True, eq=False)
class CondensedOverActive(SparseFormat):
    """Fig. 4's combined point: drop ablated neurons, condense survivors.

    values/indices cover only the ``a <= d_out`` surviving rows;
    ``out_index`` scatters each surviving row back into the dense output
    layout (out-of-range entries mark padding rows, dropped at scatter).
    Exact for ANY mask — ablated outputs are exact zeros either way.
    """
    values: jax.Array                    # (lead..., a, k)
    indices: jax.Array                   # (lead..., a, k) int32
    out_index: jax.Array                 # (lead..., a) int32
    d_in: int = 0
    d_out: int = 0                       # dense output width (scatter target)
    scales: jax.Array | None = None      # (lead..., a) f32 when quantized
    values_dtype: str | None = None      # canonical name when quantized
    tp: int = 1                          # shard-blocked TP layout when > 1

    format_name: typing.ClassVar[str] = "condensed_over_active"
    _array_fields: typing.ClassVar[tuple[str, ...]] = ("values", "indices",
                                                       "out_index", "scales")
    _static_fields: typing.ClassVar[tuple[str, ...]] = ("d_in", "d_out",
                                                        "values_dtype", "tp")

    def apply(self, x, w=None):
        if self.tp > 1:
            # shard-blocked layout: rows grouped in tp blocks of a_tp, with
            # out_index LOCALLY rebased (sentinel d_out // tp) — the local
            # scatter never crosses shards
            return ops.condensed_over_active_linear_tp_nd(
                x, (self.values if self.scales is not None
                    else self.values.astype(x.dtype)),
                self.indices, self.out_index, self.d_out, self.tp,
                scales=self.scales)
        if self.scales is not None:
            return ops.condensed_over_active_linear_nd(
                x, self.values, self.indices, self.out_index, self.d_out,
                scales=self.scales)
        return ops.condensed_over_active_linear_nd(
            x, self.values.astype(x.dtype), self.indices, self.out_index,
            self.d_out)

    @classmethod
    def export_from_dense(cls, w, mask, stats=None, *, quantize_spec=None,
                          tp_shards: int = 1):
        stats = stats if stats is not None else _realized_stats(mask)
        tp = _check_tp_shards(int(w.shape[-1]), tp_shards)
        # per-shard surviving-row bound: the max over BLOCKS, not replicas
        # (one host sync; export is host-driven like _realized_stats)
        a = (_per_shard_active_bound(mask, tp) if tp > 1
             else max(stats.max_active, 1))
        vals, idx, oi = _condense_active_stack(w, mask, max(stats.k, 1),
                                               a, tp)
        qdt = resolve_quantize_spec(quantize_spec)
        if qdt in QUANTIZED_DTYPES:
            q, s = quantize_values(vals, qdt)
            return cls(values=q, indices=idx, out_index=oi,
                       d_in=int(w.shape[-2]), d_out=int(w.shape[-1]),
                       scales=s, values_dtype=qdt, tp=tp)
        if qdt is not None:
            vals = vals.astype(VALUES_DTYPES[qdt])
        return cls(values=vals, indices=idx, out_index=oi,
                   d_in=int(w.shape[-2]), d_out=int(w.shape[-1]), tp=tp)

    def spec(self) -> FormatSpec:
        a, k = self.values.shape[-2:]
        n = 1
        for s in self.values.shape[:-2]:
            n *= s
        quantized = self.values_dtype in QUANTIZED_DTYPES
        itemsize = (jnp.dtype(self.scales.dtype).itemsize
                    if quantized and self.scales is not None
                    else jnp.dtype(self.values.dtype).itemsize)
        return FormatSpec(d_in=self.d_in, d_out=self.d_out, n_replicas=n,
                          itemsize=itemsize, k=k, max_active=a,
                          active_fraction=a / max(self.d_out, 1),
                          values_dtype=self.values_dtype, tp=self.tp)

    @classmethod
    def estimate_cost(cls, spec, batch, profile):
        # priced at the EXPORTED row fraction (max_active rows per replica,
        # padding included) — the kernel runs over all of them; the mean
        # active fraction would under-price the path under uneven ablation
        b = max(int(batch), 1)
        row_frac = min(max(spec.max_active / max(spec.d_out, 1), 0.0), 1.0)
        gather_flops = 2.0 * b * spec.n_replicas * spec.d_out * spec.k
        return max(cls.estimate_weight_bytes(spec) / profile.hbm_bytes_per_s,
                   row_frac * gather_flops / _gather_rate(profile, b))

    @classmethod
    def estimate_weight_bytes(cls, spec):
        # max_active rows of k values (real stored width) + k int32 indices
        # plus the 4-byte out_index (and f32 scale when quantized) per row
        return (cls.estimate_values_bytes(spec)
                + spec.n_replicas * spec.max_active * (spec.k * 4 + 4))

    @classmethod
    def estimate_values_bytes(cls, spec):
        vb = spec.n_replicas * spec.max_active * spec.k * values_itemsize(spec)
        if spec.values_dtype in QUANTIZED_DTYPES:
            vb += spec.n_replicas * spec.max_active * 4
        return vb

    def tuning_key(self, batch, *, backend=None):
        a, k = self.values.shape[-2:]
        # per-SHARD shapes under TP: a_tp rows scattered into d_out/tp
        return shape_tuning_key(
            self.d_in, a // self.tp, k, batch, backend=backend,
            itemsize=jnp.dtype(self.values.dtype).itemsize, kind="coa",
            scatter_width=self.d_out // self.tp,
            values_dtype=self.values_dtype)

    @classmethod
    def spec_tuning_key(cls, spec, batch, *, backend=None):
        # the FUSED kernel runs over the (max_active, k) arrays the export
        # built and scatters into the d_out-wide output block in-kernel —
        # both are part of its key (kind="coa")
        s = cls.shard_spec(spec, spec.tp)
        return shape_tuning_key(s.d_in, s.max_active, s.k, batch,
                                backend=backend, itemsize=s.itemsize,
                                kind="coa", scatter_width=s.d_out,
                                values_dtype=s.values_dtype)

    @classmethod
    def abstract(cls, lead, d_in, d_out, k, dtype, tp: int = 1):
        # a = d_out static bound (no realized ablation counts at lowering
        # time); the concrete export shrinks a to the real max active count.
        # Under TP the bound is d_out/tp per block — tp blocks of it give
        # the SAME global shapes, only the static tp differs.
        shape = (*lead, d_out, k)
        return cls(values=jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)),
                   indices=jax.ShapeDtypeStruct(shape, jnp.int32),
                   out_index=jax.ShapeDtypeStruct((*lead, d_out), jnp.int32),
                   d_in=d_in, d_out=d_out, tp=_check_tp_shards(d_out, tp))

    def donate_refresh(self, w, mask, stats=None, *, donate=True):
        stats = stats if stats is not None else _realized_stats(mask)
        k = max(stats.k, 1)
        a = (_per_shard_active_bound(mask, self.tp) if self.tp > 1
             else max(stats.max_active, 1))
        shape = (*w.shape[:-2], self.tp * a, k)
        if donate and self.values.shape == shape:
            if (self.values_dtype in QUANTIZED_DTYPES
                    and self.scales is not None):
                vals, idx, oi, s = _recondense_active_quantized_donated(
                    w, mask, self.values, self.indices, self.out_index,
                    self.scales, k=k, a=a, qdt=self.values_dtype, tp=self.tp)
                return dataclasses.replace(self, values=vals, indices=idx,
                                           out_index=oi, scales=s)
            if self.values.dtype == w.dtype:
                vals, idx, oi = _recondense_active_donated(
                    w, mask, self.values, self.indices, self.out_index,
                    k=k, a=a, tp=self.tp)
                return dataclasses.replace(self, values=vals, indices=idx,
                                           out_index=oi)
        return type(self).export_from_dense(w, mask, stats,
                                            quantize_spec=self.values_dtype,
                                            tp_shards=self.tp)

    def refresh_values(self, w, mask, *, donate: bool = True):
        """Values-only regather. Padding ROWS may re-gather garbage from a
        clipped column but are dropped by the out-of-range out_index at
        scatter time, so the representation stays exact. Quantized instances
        re-quantize (fresh scales) in the same donated program. TP instances
        rebase their local out_index to the global output axis for the
        dense-weight regather (same programs, same donation contract)."""
        oi = (_rebased_global_index(self.out_index, self.tp, self.d_out)
              if self.tp > 1 else self.out_index)
        if self.values_dtype in QUANTIZED_DTYPES and self.scales is not None:
            if donate:
                vals, s = _revalue_active_quantized_donated(
                    w, mask, self.values, self.scales, self.indices,
                    oi, qdt=self.values_dtype)
            else:
                vals, s = quantize_values(
                    _gather_at_indices(w, mask, self.indices, oi),
                    self.values_dtype)
            return dataclasses.replace(self, values=vals, scales=s)
        if donate:
            vals = _revalue_active_donated(w, mask, self.values, self.indices,
                                           oi)
        else:
            vals = _gather_at_indices(w, mask, self.indices,
                                      oi).astype(self.values.dtype)
        return dataclasses.replace(self, values=vals)

    def rebuild_missing(self, missing):
        if "scales" in missing and self.values_dtype in QUANTIZED_DTYPES:
            return self.restore_finalize()
        return self

    def restore_finalize(self):
        return _finalize_quantized_restore(self)


FORMATS: dict[str, type[SparseFormat]] = {
    cls.format_name: cls
    for cls in (MaskedDense, Condensed, StructuredFanIn, CondensedOverActive)
}

# formats whose exported arrays go stale as weights train (the rest read the
# live weights at execution time)
CONDENSED_FAMILY = (Condensed, CondensedOverActive)


# ---------------------------------------------------------------------------
# legacy dict-leaf deprecation shim
# ---------------------------------------------------------------------------

_LEGACY_KEYSETS: dict[frozenset, type[SparseFormat]] = {
    frozenset({"values", "indices"}): Condensed,
    frozenset({"values", "indices", "out_index"}): CondensedOverActive,
    frozenset({"neuron_active"}): StructuredFanIn,
}
_RESERVED_KEYS = frozenset({"values", "indices", "out_index", "neuron_active"})


def from_legacy_leaf(leaf: dict, *, d_in: int | None = None,
                     d_out: int | None = None,
                     warn: bool = True) -> SparseFormat:
    """Upgrade a pre-redesign serving dict leaf to its format object.

    Recognized key sets: ``{values, indices}`` -> Condensed,
    ``{values, indices, out_index}`` -> CondensedOverActive,
    ``{neuron_active}`` -> StructuredFanIn. A dict carrying any reserved key
    alongside unrecognized extras RAISES instead of silently mis-dispatching
    (the pre-redesign key-sniffing would have fallen through). ``d_in`` /
    ``d_out`` fill the static geometry the dict never carried (autotune keys
    need d_in; the scatter needs d_out — inferred from out_index's range
    bound is not possible without a host sync, so 0 means "unknown, tuned
    lookups disabled" unless the caller supplies it).
    """
    keys = frozenset(leaf)
    cls = _LEGACY_KEYSETS.get(keys)
    if cls is None:
        raise ValueError(
            f"unrecognized serving-leaf dict keys {sorted(keys)}: expected one "
            f"of {sorted(sorted(s) for s in _LEGACY_KEYSETS)} (legacy leaves) "
            f"or a repro.sparse.formats.SparseFormat instance")
    if warn:
        warnings.warn(
            "dict-style serving leaves are deprecated; build "
            f"repro.sparse.formats.{cls.__name__} objects instead",
            DeprecationWarning, stacklevel=2)
    if cls is Condensed:
        return Condensed(values=leaf["values"], indices=leaf["indices"],
                         d_in=int(d_in or 0))
    if cls is CondensedOverActive:
        if not d_out:
            # the scatter target width is NOT recoverable from the leaf's
            # arrays without a host sync — the pre-redesign dispatch read it
            # off the dense weight at call time
            raise ValueError(
                "upgrading a legacy condensed_over_active leaf requires "
                "d_out (the dense output width the out_index scatters into)")
        return CondensedOverActive(
            values=leaf["values"], indices=leaf["indices"],
            out_index=leaf["out_index"], d_in=int(d_in or 0),
            d_out=int(d_out))
    act = leaf["neuron_active"]
    d_out_real = act.shape[-1]
    # legacy dicts carry no realized active count (recovering one would need
    # a host sync) — build active_index at the padded d_out bound; a
    # re-export from the masks tightens it to the realized count
    return StructuredFanIn(
        neuron_active=act,
        active_index=active_index_from_bools(
            act, padded_active_count(d_out_real, d_out_real)),
        d_in=int(d_in or 0))


def is_legacy_leaf(node) -> bool:
    """Is this dict a pre-redesign serving leaf (or a malformed attempt)?"""
    return isinstance(node, dict) and bool(_RESERVED_KEYS & set(node))


def upgrade_serving_tree(tree, registry=None, *, warn: bool = True):
    """Walk a serving pytree and upgrade every legacy dict leaf in place
    (new tree returned; arrays shared). ``registry`` (iterable of
    SparseStack) fills d_in/d_out for leaves at known stack paths. Dicts
    with unrecognized reserved-key combinations raise."""
    geo = {}
    for s in (registry or []):
        geo[s.path] = (s.d_in, s.d_out)

    def rec(node, path):
        if is_legacy_leaf(node):
            d_in, d_out = geo.get(path, (None, None))
            return from_legacy_leaf(node, d_in=d_in, d_out=d_out, warn=warn)
        if isinstance(node, dict):
            return {k: rec(v, path + (k,)) for k, v in node.items()}
        return node

    return rec(tree, ())
