"""Serving execution plans: per-stack representation selection + refresh.

The paper's headline serving result (Sec. 4.4) is that the SAME trained
constant-fan-in weights can execute under multiple representations, and which
one wins depends on the request's batch shape and the hardware balance:
masked-dense rides the MXU at large batch, the condensed gather rides HBM
bandwidth at decode/B=1, and the best Fig. 4 point COMPOSES neuron ablation
with the condensed layout (condensed-over-active). This module is the single
place that decision lives:

* ``build_plan`` turns a trained (params, masks) pair into a ``Plan`` — a
  per-``SparseStack`` representation choice (priced by each format's
  ``estimate_cost`` from repro.sparse.formats when ``path="auto"``, or
  forced by a fixed path name) plus the serving pytree (format-object
  leaves) that plugs into the masks slot of prefill/decode_step.
* ``Plan.refresh`` is the incremental export: given the trainer's per-stack
  mask-version counters, only stacks whose version changed since the last
  export are re-condensed — a live training job can serve without paying a
  full re-export every delta_t steps.
* ``plan_for_shape`` / ``abstract_serving_tree`` are the allocation-free
  variants the dry-run uses to lower a planned decode program.

Consumers: repro.launch.engine (``ServingEngine`` builds one plan per
request group), repro.launch.serve (the thin CLI over the engine),
repro.launch.dryrun (``serve_plan``/``serve_engine`` programs),
benchmarks/serve_paths.py.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import registry as REG

REPRESENTATIONS = ("masked", "condensed", "structured", "condensed_over_active")
PATHS = REPRESENTATIONS + ("auto",)

# fraction below 1.0 at which a stack counts as having ablated neurons (guards
# against float fuzz in the mean-active reduction)
_ABLATION_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Throughput balance the format cost models price against.

    Defaults are TPU-v5e-like and deliberately coarse: the model only needs
    the RATIOS right (MXU ~50x the gather unit, arithmetic-intensity knee
    around B~100 for 10%-dense stacks) to reproduce the paper's batch-1 vs
    batch-256 crossover. ``HardwareProfile.measure()`` replaces the
    constants with rates microbenchmarked on the live backend, so the auto
    crossover batch is derived from THIS machine (serve.py --profile
    measured; benchmarks/kernel_autotune.py validates predicted-vs-measured
    crossover).

    The gather unit is calibrated at TWO batch points (``gather_flops_per_s``
    at ``gather_small_batch``, ``gather_flops_per_s_large`` at
    ``gather_large_batch``): the condensed gather's ACTIVATION traffic
    (b*n_out*k gathered elements) falls off a cache cliff at large batch
    that a single scalar rate cannot express. ``gather_rate(batch)``
    log-interpolates between the two measured points; profiles with
    ``gather_flops_per_s_large=None`` (e.g. the built-in default) behave as
    the old single-rate model.
    """
    name: str = "tpu-v5e-like"
    hbm_bytes_per_s: float = 8.19e11     # ~819 GB/s HBM
    mxu_flops_per_s: float = 1.97e14     # dense MXU matmul throughput
    gather_flops_per_s: float = 3.9e12   # VPU gather-MAC at the SMALL point
    gather_flops_per_s_large: float | None = None  # large-batch point (cliff)
    gather_small_batch: int = 8
    gather_large_batch: int = 512
    # per-device interconnect bandwidth (one ICI link direction) pricing the
    # tensor-parallel output all-gather: each device sends its (tp-1)/tp
    # share of the layer output over this rate. ~45 GB/s is the v5e 1D-ring
    # per-link figure; ``measure()`` replaces it with a timed all-gather when
    # the backend actually has multiple devices (kept at the default on a
    # 1-device host — simulated-mesh timings would price host memcpys).
    ici_bytes_per_s: float = 4.5e10

    def gather_rate(self, batch: int) -> float:
        """Gather throughput at ``batch``: log-log interpolation between the
        two calibration points, clamped outside them. Falls back to the
        single small-point rate when no large-point calibration exists."""
        small, large = self.gather_flops_per_s, self.gather_flops_per_s_large
        if not large or self.gather_large_batch <= self.gather_small_batch:
            return small
        b = int(batch)
        if b <= self.gather_small_batch:
            return small
        if b >= self.gather_large_batch:
            return large
        t = ((math.log(b) - math.log(self.gather_small_batch))
             / (math.log(self.gather_large_batch)
                - math.log(self.gather_small_batch)))
        return math.exp((1.0 - t) * math.log(small) + t * math.log(large))

    @classmethod
    def measure(cls, *, stream_mb: float = 96.0,
                matmul_shape: tuple[int, int, int] = (128, 2048, 1024),
                gather_shape: tuple[int, int, int, int] = (8, 2048, 1024, 205),
                gather_large_shape: tuple[int, int, int, int] = (512, 2048,
                                                                 1024, 205),
                reps: int = 5, use_cache: bool = True,
                save: bool = True) -> "HardwareProfile":
        """Microbenchmark the cost-model rates on the live backend.

        * ``hbm_bytes_per_s``    — streaming ``x + 1`` over ``stream_mb`` of
                                   f32 (reads + writes both counted; the
                                   default comfortably exceeds CPU last-level
                                   caches so the rate is main-memory, and the
                                   MEDIAN rep is used — a buffer that half
                                   fits LLC makes the fastest rep a cache
                                   burst, not the steady-state rate a serving
                                   step streams weights at);
        * ``mxu_flops_per_s``    — f32 matmul at ``matmul_shape = (b, d_in,
                                   d_out)``, a rectangular serving-batch
                                   shape rather than a peak-friendly square;
        * ``gather_flops_per_s`` / ``gather_flops_per_s_large`` — the
                                   condensed gather-MAC in its jnp
                                   formulation (kernels.ref) at TWO batch
                                   points: ``gather_shape`` sits at the top
                                   of the small-batch bucket (~10% density,
                                   the regime where the masked/condensed
                                   crossover is decided) and
                                   ``gather_large_shape`` at a batch whose
                                   gathered-activation working set blows the
                                   cache — together they bound the cache
                                   cliff the ROADMAP documents, so crossover
                                   prediction tightens beyond one-bucket
                                   accuracy.

        Each timing is the best of ``reps`` runs after a compile+warmup pass
        (min is the noise-robust estimator on shared hosts — see
        autotune._time_us). With ``use_cache`` the measured rates persist per
        backend in the autotune cache file (see
        repro.sparse.autotune.cache_path) and later calls return the stored
        profile without re-measuring; ``measure(use_cache=False)`` forces a
        fresh measurement, and ``save=False`` keeps it out of the cache.
        """
        import jax.random as jrandom

        from repro.kernels import ref as REF
        from repro.sparse import autotune as AT  # lazy: no module cycle

        backend = jax.default_backend()
        # the cache entry records its measurement settings: a profile
        # calibrated with different shapes/reps (e.g. a quick low-fidelity
        # test run) must not be silently substituted for this request
        params = {"stream_mb": stream_mb, "matmul_shape": list(matmul_shape),
                  "gather_shape": list(gather_shape),
                  "gather_large_shape": list(gather_large_shape),
                  "reps": reps}
        if use_cache:
            cached = AT.cached_profile(backend)
            if cached and cached.get("params") == params:
                return cls(name=cached["name"],
                           hbm_bytes_per_s=cached["hbm_bytes_per_s"],
                           mxu_flops_per_s=cached["mxu_flops_per_s"],
                           gather_flops_per_s=cached["gather_flops_per_s"],
                           gather_flops_per_s_large=cached.get(
                               "gather_flops_per_s_large"),
                           gather_small_batch=cached.get("gather_small_batch",
                                                         gather_shape[0]),
                           gather_large_batch=cached.get(
                               "gather_large_batch", gather_large_shape[0]),
                           # pre-TP cache entries have no interconnect rate;
                           # fall back to the class default rather than
                           # invalidating them
                           ici_bytes_per_s=cached.get("ici_bytes_per_s",
                                                      cls.ici_bytes_per_s))

        import statistics

        n = max(int(stream_mb * 2**20 / 4), 1024)
        xs = jnp.full((n,), 1.5, jnp.float32)
        t_stream = AT._time_us(jax.jit(lambda x: x + 1.0), xs, reps=reps,
                               agg=statistics.median)
        hbm = 8.0 * n / (t_stream * 1e-6)            # 4B read + 4B write

        key = jrandom.PRNGKey(0)
        mb, md_in, md_out = matmul_shape
        a = jrandom.normal(key, (mb, md_in), jnp.float32)
        b_ = jrandom.normal(jrandom.fold_in(key, 1), (md_in, md_out),
                            jnp.float32)
        t_mm = AT._time_us(jax.jit(jnp.matmul), a, b_, reps=reps)
        mxu = 2.0 * mb * md_in * md_out / (t_mm * 1e-6)

        def gather_point(shape, salt):
            gb, gd, gn, gk = shape
            x = jrandom.normal(jrandom.fold_in(key, salt), (gb, gd),
                               jnp.float32)
            vals = jrandom.normal(jrandom.fold_in(key, salt + 1), (gn, gk),
                                  jnp.float32)
            idx = jrandom.randint(jrandom.fold_in(key, salt + 2), (gn, gk),
                                  0, gd)
            t_g = AT._time_us(jax.jit(REF.condensed_matmul_ref), x, vals, idx,
                              reps=reps)
            return 2.0 * gb * gn * gk / (t_g * 1e-6)

        gather = gather_point(gather_shape, 2)
        gather_large = gather_point(gather_large_shape, 5)

        # interconnect: timed all-gather of a model-axis-sharded vector.
        # Only meaningful with REAL multiple devices — a simulated host mesh
        # would price host memcpys as ICI, so the default survives there too
        # (simulated devices all report the host platform but share one
        # process; len(jax.devices()) > 1 on hardware backends only when the
        # links exist).
        ici = cls.ici_bytes_per_s
        ndev = jax.device_count()
        if ndev > 1 and backend != "cpu":
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as PS

            from repro import compat
            m = compat.make_mesh((ndev,), ("model",))
            per = max(int(stream_mb * 2**20 / 4) // ndev, 1024)
            xs_sh = jax.device_put(jnp.zeros((ndev * per,), jnp.float32),
                                   NamedSharding(m, PS("model")))
            fn = jax.jit(lambda x: jax.lax.with_sharding_constraint(
                x + 1.0, NamedSharding(m, PS())))
            t_ici = AT._time_us(fn, xs_sh, reps=reps,
                                agg=statistics.median)
            # per-device send volume of the all-gather: (ndev-1)/ndev of the
            # replicated payload
            ici = 4.0 * per * (ndev - 1) / (t_ici * 1e-6)

        prof = cls(name=f"measured-{backend}", hbm_bytes_per_s=hbm,
                   mxu_flops_per_s=mxu, gather_flops_per_s=gather,
                   gather_flops_per_s_large=gather_large,
                   gather_small_batch=gather_shape[0],
                   gather_large_batch=gather_large_shape[0],
                   ici_bytes_per_s=ici)
        if save:
            AT.store_profile({"name": prof.name,
                              "hbm_bytes_per_s": prof.hbm_bytes_per_s,
                              "mxu_flops_per_s": prof.mxu_flops_per_s,
                              "gather_flops_per_s": prof.gather_flops_per_s,
                              "gather_flops_per_s_large":
                                  prof.gather_flops_per_s_large,
                              "gather_small_batch": prof.gather_small_batch,
                              "gather_large_batch": prof.gather_large_batch,
                              "ici_bytes_per_s": prof.ici_bytes_per_s,
                              "params": params},
                             backend=backend)
        return prof


DEFAULT_PROFILE = HardwareProfile()


@dataclasses.dataclass(frozen=True)
class StackDecision:
    """One stack's chosen representation + the cost table that chose it.

    ``tp`` is the chosen SHARD count for this stack's leaf: under a
    tensor-parallel plan the cost model decides PER STACK whether to shard
    the neuron axis (pay the output all-gather) or replicate (pay full HBM)
    — ``tp == 1`` means the replicated execution won even though the mesh
    has a model axis.
    """
    name: str
    representation: str
    est_s: dict[str, float]       # representation -> est. seconds per step
    stats: COND.ExportStats       # realized fan-in / ablation at export time
    tp: int = 1                   # chosen neuron-axis shard count (1 = replicated)

    @property
    def active_fraction(self) -> float:
        return self.stats.active_fraction

    @property
    def cost_key(self) -> str:
        """The ``est_s`` key the decision was priced at."""
        return (f"{self.representation}@tp{self.tp}" if self.tp > 1
                else self.representation)


def stack_costs(stack, *, batch_size: int, itemsize: int, k: int,
                active_fraction: float,
                profile: HardwareProfile = DEFAULT_PROFILE,
                max_active_fraction: float | None = None,
                values_dtype: str | None = None,
                tp: int = 1) -> dict[str, float]:
    """Estimated seconds per serving step for each representation.

    Pricing lives with the formats themselves now: each representation's
    ``estimate_cost`` (repro.sparse.formats) is the roofline max of its
    HBM-byte term (``estimate_weight_bytes``) and its compute term on the
    unit that executes it. This wrapper builds the ``FormatSpec`` each class
    prices from — ``max_active_fraction`` is the EXPORTED row fraction for
    condensed_over_active (the leaf carries max_active rows per replica,
    padding included; the mean ``active_fraction`` is the documented
    fallback and would under-price the path under uneven ablation).
    ``values_dtype`` (a canonical name from ``formats.VALUES_DTYPES``) lets
    each format price its REAL stored byte width — a quantized export
    shrinks the HBM roofline term, which can move the masked/condensed
    crossover batch.

    With ``tp > 1`` (and ``d_out`` divisible by it) the table ALSO carries
    ``"<rep>@tp<tp>"`` entries priced by each format's
    ``estimate_cost_sharded`` — shard-local roofline at ``1/tp`` shapes plus
    the output all-gather over ``profile.ici_bytes_per_s``. The plain keys
    stay the replicated prices, so the TP-vs-replicated crossover is read
    straight out of one table.
    """
    b = max(int(batch_size), 1)
    act = min(max(active_fraction, 0.0), 1.0)
    row_frac = act if max_active_fraction is None else \
        min(max(max_active_fraction, 0.0), 1.0)
    spec = F.FormatSpec(d_in=stack.d_in, d_out=stack.d_out,
                        n_replicas=stack.n_replicas, itemsize=itemsize,
                        k=max(k, 1), max_active=row_frac * stack.d_out,
                        active_fraction=act,
                        values_dtype=F.resolve_quantize_spec(values_dtype))
    costs = {name: cls.estimate_cost(spec, b, profile)
             for name, cls in F.FORMATS.items()}
    if tp > 1 and stack.d_out % tp == 0:
        for name, cls in F.FORMATS.items():
            costs[f"{name}@tp{tp}"] = cls.estimate_cost_sharded(spec, b,
                                                                profile, tp)
    return costs


def select_representation(stack, *, batch_size: int, itemsize: int,
                          stats: COND.ExportStats,
                          profile: HardwareProfile = DEFAULT_PROFILE,
                          values_dtype: str | None = None,
                          tp: int = 1) -> StackDecision:
    """Cost-model choice among EXACT representations for one stack.

    The always-exact candidates are masked, plain condensed, and — once
    ablation has created dead rows to drop — condensed-over-active. Plain
    condensed stays a candidate even with ablation: under UNEVEN ablation
    the exported condensed-over-active leaf still carries max_active rows
    (plus out_index bytes) and can price ABOVE plain condensed, which is
    exact for any mask.

    ``structured`` joins the candidate set only for ABLATION-ONLY stacks
    (``stats.min_fan_in == d_in``: every surviving column fully dense —
    structured keeps active columns dense, so that is the one regime where
    it is output-equivalent). With the column-gathered kernel its weight
    bytes and MXU FLOPs scale with the active fraction, so it wins the
    bandwidth-bound shapes of ablation-only stacks outright and cedes to
    masked at large batch where its fused scatter epilogue's extra MXU term
    outweighs the column saving.

    With ``tp > 1`` every non-masked candidate enters TWICE — replicated
    (plain HBM price) and neuron-axis sharded (``1/tp`` shapes plus the
    output all-gather) — and the winner fixes both ``representation`` and
    ``StackDecision.tp``. Masked-dense stays the data-parallel replica path
    (its sharded price is defined as its replicated price), so "replicate
    and ride the MXU" remains the honest large-batch answer under TP.
    """
    tp = tp if tp > 1 and stack.d_out % tp == 0 else 1
    costs = stack_costs(stack, batch_size=batch_size, itemsize=itemsize,
                        k=max(stats.k, 1),
                        active_fraction=stats.active_fraction, profile=profile,
                        max_active_fraction=_max_active_fraction(stack, stats),
                        values_dtype=values_dtype, tp=tp)
    has_ablation = stats.active_fraction < 1.0 - _ABLATION_EPS
    cands = ("masked", "condensed")
    if has_ablation:
        cands += ("condensed_over_active",)
        if stats.min_fan_in >= stack.d_in:
            cands += ("structured",)
    options = [(costs[r], r, 1) for r in cands]
    if tp > 1:
        options += [(costs[f"{r}@tp{tp}"], r, tp) for r in cands
                    if r != "masked"]
    _, rep, dec_tp = min(options, key=lambda o: o[0])
    return StackDecision(name=stack.name, representation=rep, est_s=costs,
                         stats=stats, tp=dec_tp)


def _max_active_fraction(stack, stats: COND.ExportStats) -> float:
    """Exported-row fraction pricing condensed_over_active: the leaf carries
    max_active rows per replica (stack-wide max, padding included)."""
    return max(stats.max_active, 1) / max(stack.d_out, 1)


def _build_leaf(rep: str, weight, mask, stats: COND.ExportStats,
                values_dtype: str | None = None, tp: int = 1) -> F.SparseFormat:
    """Construct the format object for one stack (export_from_dense).

    ``values_dtype`` becomes the export's ``quantize_spec`` for the formats
    that store values; masked-dense reads the live dense weights at
    execution time and has nothing to quantize, so it ignores the request
    (documented engine behavior: a quantized plan serves masked stacks at
    the param dtype).

    ``tp > 1`` exports the leaf in its neuron-axis block layout
    (``tp_shards``); masked-dense has no sharded layout (it serves as
    data-parallel replicas) and ignores it.
    """
    try:
        cls = F.FORMATS[rep]
    except KeyError:
        raise ValueError(f"unknown representation {rep!r}") from None
    if rep == "masked":
        return cls.export_from_dense(weight, mask, stats)
    kwargs = {"tp_shards": tp} if tp > 1 else {}
    if values_dtype is not None:
        kwargs["quantize_spec"] = values_dtype
    return cls.export_from_dense(weight, mask, stats, **kwargs)


def _decide(stack, path: str, *, batch_size: int, itemsize: int,
            stats: COND.ExportStats, profile: HardwareProfile,
            values_dtype: str | None = None, tp: int = 1) -> StackDecision:
    """One stack's decision: cost-model choice for "auto", forced otherwise.
    Shared by build_plan and Plan.refresh so the two can never diverge.

    Under a forced path with ``tp > 1`` the representation is pinned but the
    leaf still shards (that is what serving the path on a model mesh means);
    masked-dense and non-divisible stacks stay replicated.
    """
    if path == "auto":
        return select_representation(stack, batch_size=batch_size,
                                     itemsize=itemsize, stats=stats,
                                     profile=profile, values_dtype=values_dtype,
                                     tp=tp)
    tp = tp if tp > 1 and stack.d_out % tp == 0 else 1
    costs = stack_costs(stack, batch_size=batch_size, itemsize=itemsize,
                        k=max(stats.k, 1),
                        active_fraction=stats.active_fraction, profile=profile,
                        max_active_fraction=_max_active_fraction(stack, stats),
                        values_dtype=values_dtype, tp=tp)
    dec_tp = tp if path != "masked" else 1
    return StackDecision(name=stack.name, representation=path, est_s=costs,
                         stats=stats, tp=dec_tp)


def _host_versions(mask_versions: dict) -> dict[str, int]:
    """Trainer counters (host ints or device scalars) -> plain int dict.

    Already-host-int dicts (the engine/subscriber path keeps a host-side
    version cache) short-circuit WITHOUT any device sync — a no-op refresh
    costs zero blocking ``device_get``s. Anything else (device scalars from
    a live TrainState) is fetched with ONE fused device_get."""
    mv = dict(mask_versions)
    if all(type(v) is int for v in mv.values()):
        return mv
    return {k: int(v) for k, v in jax.device_get(mv).items()}


@dataclasses.dataclass
class Plan:
    """A built execution plan: decisions + serving pytree + export versions.

    ``serving_tree`` plugs into the masks slot of prefill/decode_step; its
    leaves are ``repro.sparse.formats`` objects and
    repro.models.layers.linear dispatches on their type. ``export_calls``
    counts per-stack leaf (re)builds over the plan's lifetime — the
    incremental-export tests assert it only grows by the number of CHANGED
    stacks.
    """
    cfg: object
    registry: list
    path: str                      # requested path ("auto" or a fixed rep)
    batch_size: int
    profile: HardwareProfile
    decisions: dict[str, StackDecision]
    serving_tree: dict
    mask_versions: dict[str, int]  # stack name -> version at last export
    values_dtype: str | None = None  # canonical quantize spec (None = param dtype)
    tp: int = 1                    # model-axis size the plan was built for
    export_calls: int = 0
    value_refreshes: int = 0       # cheap values-only regathers (no re-sort)

    def representation_of(self, name: str) -> str:
        return self.decisions[name].representation

    def format_of(self, name: str) -> type[F.SparseFormat]:
        return F.FORMATS[self.decisions[name].representation]

    def refresh(self, params: dict, masks: dict, mask_versions: dict, *,
                refresh_values: bool = True, donate: bool = True,
                export_cache: dict | None = None) -> list[str]:
        """Incremental re-export: re-condense ONLY stacks whose version moved.

        ``mask_versions`` is the trainer's per-stack counter pytree (host ints
        or device scalars; fetched with one device_get). Changed stacks get
        fresh realized stats (one fused program over just those stacks), a
        re-run of the cost model (ablation appearing mid-training can flip
        condensed -> condensed_over_active), and a rebuilt leaf. Returns the
        names of the stacks that were re-exported.

        Version counters only track TOPOLOGY: between DST steps the weights
        keep training for every stack, so with ``refresh_values=True``
        (default) the unchanged condensed-family stacks get a values-only
        regather at their stored indices (``formats.*.refresh_values``) —
        cheap (no argsort, no stats sync, indices reused verbatim) but
        necessary for the serving snapshot to be coherent with ``params``.
        Masked/structured leaves need nothing: they read the live weights
        from ``params`` at execution time. Pass ``refresh_values=False``
        only when params are frozen (serving a fixed checkpoint).

        Memory/host-transfer contract (a live serving job refreshes in
        place): the re-condense and the regather run as jitted device
        programs with the plan's OLD format buffers DONATED
        (``formats.*.donate_refresh``) — whenever the new leaf's shapes
        match (topology rewired at unchanged fan-in, or values-only), the
        new arrays are written into the old buffers, so the refresh never
        doubles the plan's weight footprint. No weight data is fetched to
        the host: the only device_get traffic is the version counters and
        (for changed stacks) the per-stack scalar stats. ``donate=False``
        preserves the old leaf arrays for callers that still hold
        references to them.

        ``export_cache`` dedupes the donated re-export ACROSS plans: an
        engine holding N cached plans that reference the same stack passes
        one dict for the whole refresh sweep, the first plan to reach a
        (stack, representation, tp, values_dtype, version) computes the
        leaf (donating ITS old buffers), and every later plan adopts the
        same leaf object — stacks export once per generation, not once per
        plan key. The cache is scoped to ONE refresh sweep; plans that
        share leaf objects this way must keep refreshing through the same
        engine (a lone ``plan.refresh(donate=True)`` would invalidate
        buffers its siblings still reference).
        """
        versions = _host_versions(mask_versions)
        by_name = {s.name: s for s in self.registry}
        changed = [by_name[n] for n, v in versions.items()
                   if n in by_name and v != self.mask_versions.get(n)]
        changed_names = {s.name for s in changed}
        if changed:
            stats = COND.export_stats(self.registry, masks, stacks=changed)
            itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
            for s in changed:
                dec = _decide(s, self.path, batch_size=self.batch_size,
                              itemsize=itemsize, stats=stats[s.name],
                              profile=self.profile,
                              values_dtype=self.values_dtype, tp=self.tp)
                old_rep = self.decisions[s.name].representation
                old_leaf = REG.get_path(self.serving_tree, s.path)
                weight = REG.get_path(params, s.path)
                mask = REG.get_path(masks, s.path)
                rep = dec.representation
                cache_key = (s.name, rep, dec.tp, self.values_dtype,
                             versions[s.name])
                if export_cache is not None and cache_key in export_cache:
                    # another plan already exported this stack at this
                    # version/layout: adopt the shared leaf (the old leaf is
                    # simply dropped — only the FIRST exporter donates)
                    leaf = export_cache[cache_key]
                elif (rep in ("condensed", "condensed_over_active")
                        and rep == old_rep):
                    leaf = COND.recondense_stack_leaf(
                        weight, mask, stats[s.name], old_leaf,
                        over_active=(rep == "condensed_over_active"),
                        donate=donate, quantize_spec=self.values_dtype,
                        tp=dec.tp)
                else:
                    leaf = _build_leaf(rep, weight, mask, stats[s.name],
                                       self.values_dtype, tp=dec.tp)
                if export_cache is not None:
                    export_cache[cache_key] = leaf
                self.decisions[s.name] = dec
                REG.set_path(self.serving_tree, s.path, leaf)
                self.mask_versions[s.name] = versions[s.name]
                self.export_calls += 1
        if refresh_values:
            for s in self.registry:
                if s.name in changed_names:
                    continue
                leaf = REG.get_path(self.serving_tree, s.path)
                if not isinstance(leaf, F.CONDENSED_FAMILY):
                    continue
                val_key = (s.name, type(leaf).__name__,
                           getattr(leaf, "tp", 1), self.values_dtype,
                           "values")
                if export_cache is not None and val_key in export_cache:
                    fresh = export_cache[val_key]
                else:
                    fresh = leaf.refresh_values(
                        REG.get_path(params, s.path),
                        REG.get_path(masks, s.path), donate=donate)
                    if export_cache is not None:
                        export_cache[val_key] = fresh
                REG.set_path(self.serving_tree, s.path, fresh)
                self.value_refreshes += 1
        return [s.name for s in changed]

    def weight_bytes(self) -> tuple[int, int]:
        """(serving weight bytes under this plan, masked-path weight bytes).

        The reference is the masked-dense serving path's traffic — dense
        weights PLUS the bool mask it also reads — so a plan that resolves
        every stack to masked reports exactly the reference (ratio 1.0).
        Each format prices its own exported size
        (``formats.*.estimate_weight_bytes``); condensed_over_active is
        priced at max_active rows per replica (stack-wide max, padding
        included), not the mean active fraction.
        """
        itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
        masked_ref = serving = 0
        for s in self.registry:
            dec = self.decisions[s.name]
            spec = F.spec_for_stack(s, dec.stats, itemsize, self.values_dtype)
            serving += F.FORMATS[dec.representation].estimate_weight_bytes(spec)
            masked_ref += F.MaskedDense.estimate_weight_bytes(spec)
        return serving, masked_ref

    def describe(self, requested_batch: int | None = None) -> str:
        """Human-readable plan table.

        ``requested_batch`` is the caller's ACTUAL batch; when it differs
        from the bucket the plan was priced/compiled at, both are printed —
        "batch=2 (bucket 8)" — so a bucketed engine never silently reports
        a batch the user did not ask for.
        """
        vd = f" values_dtype={self.values_dtype}" if self.values_dtype else ""
        tp_s = f" tp={self.tp}" if self.tp > 1 else ""
        batch_s = f"batch={self.batch_size}"
        if requested_batch is not None and int(requested_batch) != self.batch_size:
            batch_s = f"batch={int(requested_batch)} (bucket {self.batch_size})"
        lines = [f"[plan] path={self.path} {batch_s} "
                 f"profile={self.profile.name}{tp_s}{vd}"]
        for name, dec in self.decisions.items():
            est = dec.est_s.get(dec.cost_key, dec.est_s[dec.representation])
            rep_s = (f"{dec.representation}@tp{dec.tp}" if dec.tp > 1
                     else dec.representation)
            lines.append(
                f"[plan]   {name:24s} -> {rep_s:22s} "
                f"(est {est * 1e6:8.3f} us/step, k={dec.stats.k}, "
                f"active={dec.active_fraction:.2f})")
        return "\n".join(lines)


def build_plan(cfg, registry, params: dict, masks: dict, *,
               batch_size: int = 1, path: str = "auto",
               mask_versions: dict | None = None,
               profile: HardwareProfile = DEFAULT_PROFILE,
               values_dtype: str | None = None, tp: int = 1) -> Plan:
    """Build the per-stack execution plan for a request batch shape.

    ``path="auto"`` selects per stack by the cost model; a fixed path name
    forces that representation everywhere (the pre-plan ``--path`` behavior).
    ``mask_versions`` snapshots the trainer's counters so a later ``refresh``
    only re-exports stacks whose counter moved.

    ``values_dtype`` (``"bf16"``/``"int8"``/``"fp8"``; None keeps the param
    dtype) quantizes every value-storing leaf at export time and feeds the
    real byte width into both the cost model and ``weight_bytes`` pricing.
    The choice is part of the PLAN, not the per-request key: ``refresh``
    re-exports under the same spec, so a live job never silently changes
    serving precision.

    ``tp`` is the mesh's model-axis size: each stack's decision then also
    carries a per-stack shard count (``StackDecision.tp`` — the collective-
    priced cost model can keep individual stacks replicated), and sharded
    leaves are exported in their block layout so ``ShardingRules`` can
    partition them over the model axis.
    """
    if path not in PATHS:
        raise ValueError(f"unknown serving path {path!r}; expected one of {PATHS}")
    vd = F.resolve_quantize_spec(values_dtype)
    tp = max(int(tp), 1)
    registry = list(registry or [])
    versions = (_host_versions(mask_versions) if mask_versions is not None
                else {s.name: 0 for s in registry})
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    stats = COND.export_stats(registry, masks)

    decisions: dict[str, StackDecision] = {}
    tree: dict = {}
    calls = 0
    for s in registry:
        dec = _decide(s, path, batch_size=batch_size, itemsize=itemsize,
                      stats=stats[s.name], profile=profile, values_dtype=vd,
                      tp=tp)
        decisions[s.name] = dec
        REG.set_path(tree, s.path,
                     _build_leaf(dec.representation,
                                 REG.get_path(params, s.path),
                                 REG.get_path(masks, s.path), stats[s.name],
                                 vd, tp=dec.tp))
        calls += 1
    return Plan(cfg=cfg, registry=registry, path=path, batch_size=batch_size,
                profile=profile, decisions=decisions, serving_tree=tree,
                mask_versions={s.name: versions.get(s.name, 0) for s in registry},
                values_dtype=vd, tp=tp, export_calls=calls)


# ---------------------------------------------------------------------------
# allocation-free variants (dry-run / compile-only consumers)
# ---------------------------------------------------------------------------

def plan_for_shape(cfg, registry, *, batch_size: int,
                   profile: HardwareProfile = DEFAULT_PROFILE,
                   tp: int = 1) -> dict[str, str]:
    """Representation choice per stack from STATIC info only (target ERK
    densities, no realized masks — so no ablation is assumed). Used by the
    dry-run to pick what to lower for a given serving shape. ``tp`` prices
    the choice on a model mesh (collective included)."""
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    out = {}
    for s in registry:
        stats = COND.ExportStats(k=D.fan_in_from_density(s.d_in, s.density),
                                 max_active=s.d_out, active_fraction=1.0)
        dec = select_representation(s, batch_size=batch_size, itemsize=itemsize,
                                    stats=stats, profile=profile, tp=tp)
        out[s.name] = dec.representation
    return out


def tp_crossover_batch(stack, *, itemsize: int, stats: COND.ExportStats,
                       tp: int, profile: HardwareProfile = DEFAULT_PROFILE,
                       values_dtype: str | None = None,
                       max_batch: int = 4096) -> int | None:
    """Smallest power-of-two batch at which the collective-priced cost model
    stops sharding this stack — i.e. the auto decision's ``tp`` falls back
    to 1 (replicate, ride HBM/MXU) instead of paying the all-gather.

    At decode shapes sharding wins (1/tp of the weight stream against a
    tiny collective); the collective term grows linearly in batch while the
    replicated MXU path amortizes, so past the crossover replication wins.
    Returns None when sharding still wins at ``max_batch`` (collective
    cheaper than the per-shard saving throughout). This is the per-arch
    prediction benchmarks/serve_paths.py records (schema v6).
    """
    b = 1
    while b <= max_batch:
        dec = select_representation(stack, batch_size=b, itemsize=itemsize,
                                    stats=stats, profile=profile,
                                    values_dtype=values_dtype, tp=tp)
        if dec.tp == 1:
            return b
        b *= 2
    return None


# ---------------------------------------------------------------------------
# self-draft speculative decoding: draft-tree derivation + pricing
#
# SRigL's neuron ablation means every served model already CONTAINS a cheaper
# subnetwork: the draft model for speculative decoding is the SAME trained
# weights at a higher neuron ablation fraction, so draft and target share one
# weight residency and verification is one batched full-network call over the
# gamma+1 drafted positions. Derivation is format-aware:
#
# * ``Condensed``      -> ``CondensedOverActive`` wrapping the target's
#   values/indices/scales buffers VERBATIM (asserted shared), with an
#   ``out_index`` that sentinels the dropped neurons — their rows are dropped
#   at the in-kernel scatter, so the draft output has exact zeros there.
# * ``CondensedOverActive`` -> the same leaf with MORE rows sentineled.
# * ``StructuredFanIn`` (live-weight, tp=1) -> a genuine column SUBSET
#   (shorter ``active_index``): the column-gathered kernel's bytes and FLOPs
#   shrink with the draft fraction — this is where the draft's measured
#   speedup comes from (PR 5: 0.21x step at 0.25 active). Quantized/TP
#   instances keep their panel layout and sentinel dropped columns instead.
# * ``MaskedDense`` on ablation-only stacks -> a ``StructuredFanIn`` subset
#   reading the live weights; fine-sparse masked stacks draft at identity
#   (no exact column subnetwork exists — the stack contributes no saving
#   and no acceptance loss).
#
# Dropped neurons are chosen by SALIENCY (sum |values| per output neuron,
# dequantized when scales exist; column L1 norm of the live weight for
# live-weight formats) — the channel-importance heuristic Chase (PAPERS.md)
# uses for channel-level subnetworks.
# ---------------------------------------------------------------------------


def _draft_keep(n: int, draft_ablation: float) -> int:
    """Rows/columns the draft keeps out of ``n`` at ablation ``F``."""
    f = min(max(float(draft_ablation), 0.0), 1.0)
    return max(int(math.ceil(n * (1.0 - f))), 1)


def _keep_top_rows(saliency, valid, keep: int):
    """Bool mask keeping the top-``keep`` valid entries of the last axis per
    lead replica (ties broken by position via top_k's stable order)."""
    s = jnp.where(valid, saliency.astype(jnp.float32), -jnp.inf)
    flat = s.reshape(-1, s.shape[-1])
    idx = jax.lax.top_k(flat, min(keep, s.shape[-1]))[1]
    km = jnp.zeros(flat.shape, bool)
    km = km.at[jnp.arange(flat.shape[0])[:, None], idx].set(True)
    return km.reshape(s.shape) & valid


def _row_saliency(values, scales):
    s = jnp.sum(jnp.abs(values.astype(jnp.float32)), axis=-1)
    return s * scales if scales is not None else s


def _is_ablation_only(mask) -> bool:
    """Does every surviving column keep full fan-in? (one host sync; draft
    derivation is host-driven like the exports)."""
    act = jnp.any(mask, axis=-2)
    full = jnp.all(mask == act[..., None, :])
    return bool(jax.device_get(full))


def _structured_subset(weight, neuron_active, keep: int, leaf_tpl):
    """Live-weight column-subset StructuredFanIn draft (tp=1)."""
    d_out = neuron_active.shape[-1]
    sal = jnp.sum(jnp.abs(weight.astype(jnp.float32)), axis=-2)
    km = _keep_top_rows(sal, neuron_active, keep)
    a_pad = F.padded_active_count(min(keep, d_out), d_out)
    ai = F.active_index_from_bools(km, a_pad)
    return F.StructuredFanIn(neuron_active=km, active_index=ai,
                             d_in=int(weight.shape[-2]),
                             weight_itemsize=leaf_tpl.weight_itemsize)


def derive_draft_leaf(leaf, weight, mask,
                      draft_ablation: float) -> tuple[F.SparseFormat, str]:
    """One stack's draft leaf from its target serving leaf.

    Returns (draft_leaf, kind): ``"subset"`` drafts genuinely execute fewer
    columns, ``"sentinel"`` drafts share the target's buffers and drop rows
    at scatter (exact-zero outputs, no compute saving under the current
    kernels — priced honestly), ``"identity"`` stacks draft as themselves.
    Value-bearing arrays are NEVER copied: sentinel drafts alias the
    target's buffers by object identity, subset/identity drafts read the
    live weights the target already reads.
    """
    if isinstance(leaf, F.Condensed):
        d_out = leaf.values.shape[-2]
        wloc = d_out // leaf.tp
        sal = _row_saliency(leaf.values, leaf.scales)
        km = _keep_top_rows(sal, jnp.ones(sal.shape, bool),
                            _draft_keep(d_out, draft_ablation))
        local = jnp.arange(d_out, dtype=jnp.int32) % wloc
        oi = jnp.where(km, jnp.broadcast_to(local, sal.shape),
                       wloc).astype(jnp.int32)
        return F.CondensedOverActive(
            values=leaf.values, indices=leaf.indices, out_index=oi,
            d_in=leaf.d_in, d_out=d_out, scales=leaf.scales,
            values_dtype=leaf.values_dtype, tp=leaf.tp), "sentinel"
    if isinstance(leaf, F.CondensedOverActive):
        bound = leaf.d_out // leaf.tp
        valid = leaf.out_index < bound
        sal = _row_saliency(leaf.values, leaf.scales)
        km = _keep_top_rows(sal, valid,
                            _draft_keep(leaf.values.shape[-2], draft_ablation))
        oi = jnp.where(km, leaf.out_index,
                       bound).astype(leaf.out_index.dtype)
        return dataclasses.replace(leaf, out_index=oi), "sentinel"
    if isinstance(leaf, F.StructuredFanIn):
        d_out = leaf.neuron_active.shape[-1]
        if leaf.values is not None and leaf.active_index is not None:
            # quantized: the stored panel is POSITION-indexed by
            # active_index, so the layout must stay — sentinel the dropped
            # columns (active_index is scatter-only on the gathered path)
            bound = d_out // leaf.tp
            valid = leaf.active_index < bound
            sal = jnp.sum(jnp.abs(leaf.values.astype(jnp.float32)), axis=-2)
            if leaf.scales is not None:
                sal = sal * leaf.scales
            km = _keep_top_rows(
                sal, valid,
                _draft_keep(leaf.active_index.shape[-1], draft_ablation))
            ai = jnp.where(km, leaf.active_index,
                           bound).astype(leaf.active_index.dtype)
            return dataclasses.replace(leaf, active_index=ai), "sentinel"
        if leaf.tp > 1 or leaf.active_index is None:
            # per-block subsets would need equal padded widths per shard;
            # not worth the layout machinery for a draft heuristic
            return leaf, "identity"
        keep = _draft_keep(leaf.active_index.shape[-1], draft_ablation)
        return _structured_subset(weight, leaf.neuron_active, keep,
                                  leaf), "subset"
    if isinstance(leaf, F.MaskedDense):
        if not _is_ablation_only(mask):
            return leaf, "identity"
        act = jnp.any(mask, axis=-2)
        a = max(int(jax.device_get(
            jnp.max(jnp.sum(act.astype(jnp.int32), axis=-1)))), 1)
        tpl = F.StructuredFanIn(neuron_active=act, active_index=None,
                                weight_itemsize=leaf.weight_itemsize)
        return _structured_subset(weight, act,
                                  _draft_keep(a, draft_ablation), tpl), "subset"
    raise ValueError(f"cannot derive a draft from {type(leaf).__name__}")


def derive_draft_tree(registry, serving_tree, params, masks,
                      draft_ablation: float) -> tuple[dict, dict[str, str]]:
    """Draft serving pytree for a target plan's ``serving_tree``.

    Returns (draft_tree, per-stack kind report). The draft tree plugs into
    the same masks slot of the paged decode step; non-stack params
    (embeddings, norms, attention projections outside the registry) are the
    model's own and shared by construction.
    """
    tree: dict = {}
    report: dict[str, str] = {}
    for s in registry:
        leaf = REG.get_path(serving_tree, s.path)
        if not isinstance(leaf, F.SparseFormat):
            raise ValueError(
                f"stack {s.name!r} serves a raw mask leaf "
                f"({type(leaf).__name__}); speculative drafting needs a "
                f"format-typed plan (any engine path except 'masked')")
        draft, kind = derive_draft_leaf(
            leaf, REG.get_path(params, s.path), REG.get_path(masks, s.path),
            draft_ablation)
        REG.set_path(tree, s.path, draft)
        report[s.name] = kind
    return tree, report


def draft_weight_overhead_bytes(registry, target_tree,
                                draft_tree) -> tuple[int, int]:
    """(shared_bytes, extra_bytes) of VALUE storage in a draft tree.

    ``shared`` counts draft value/scale buffers that are the target's own
    device arrays (object identity — the zero-weight-residency contract);
    ``extra`` counts freshly allocated value storage, which the engine
    asserts to be ZERO. Index/bool metadata (active_index, out_index,
    neuron_active) is excluded: it is not weight data and is O(d_out) int32
    per stack against O(d_out * k) values.
    """
    shared = extra = 0
    for s in registry:
        t = REG.get_path(target_tree, s.path)
        d = REG.get_path(draft_tree, s.path)
        target_ids = {id(getattr(t, f)) for f in t._array_fields
                      if getattr(t, f, None) is not None}
        for f in ("values", "scales"):
            arr = getattr(d, f, None)
            if arr is None:
                continue
            nbytes = int(arr.size) * jnp.dtype(arr.dtype).itemsize
            if id(arr) in target_ids:
                shared += nbytes
            else:
                extra += nbytes
    return shared, extra


def expected_tokens_per_dispatch(acceptance: float, gamma: int) -> float:
    """E[committed tokens per verify dispatch] under per-token acceptance
    probability ``acceptance``: 1 + a + a^2 + ... + a^gamma — the standard
    speculative-decoding expectation (the verify step always commits at
    least the current token, plus every accepted draft prefix token)."""
    a = min(max(float(acceptance), 0.0), 1.0)
    g = max(int(gamma), 0)
    if a >= 1.0:
        return float(g + 1)
    return (1.0 - a ** (g + 1)) / (1.0 - a)


@dataclasses.dataclass(frozen=True)
class SpecEstimate:
    """Priced speculation decision for one plan key.

    All costs are the sparse-stack sums the plan's own decisions are priced
    with (attention/dense layers cost the same under draft and target and
    cancel in the comparison; the verify dispatch prices the full network
    at ``batch * (gamma + 1)`` rows, which upper-bounds its extra cost).
    """
    gamma: int
    acceptance: float            # assumed per-token acceptance (pre-measure)
    expected_tokens: float       # committed tokens per verify dispatch
    target_step_s: float         # full-network step at the bucket batch
    draft_step_s: float          # draft-tree step at the bucket batch
    verify_s: float              # one (gamma+1)-position verify dispatch
    @property
    def spec_s_per_token(self) -> float:
        return ((self.gamma * self.draft_step_s + self.verify_s)
                / max(self.expected_tokens, 1e-9))
    @property
    def base_s_per_token(self) -> float:
        return self.target_step_s
    @property
    def worthwhile(self) -> bool:
        return self.spec_s_per_token < self.base_s_per_token


def _tree_step_cost(registry, tree, batch: int,
                    profile: HardwareProfile) -> float:
    total = 0.0
    for s in registry:
        leaf = REG.get_path(tree, s.path)
        cls, spec = type(leaf), leaf.spec()
        tp = getattr(leaf, "tp", 1)
        total += (cls.estimate_cost_sharded(spec, batch, profile, tp)
                  if tp > 1 else cls.estimate_cost(spec, batch, profile))
    return total


def price_speculation(registry, target_tree, draft_tree, *, batch_size: int,
                      gamma: int, acceptance: float = 0.7,
                      profile: HardwareProfile = DEFAULT_PROFILE,
                      ) -> SpecEstimate:
    """Expected tokens/dispatch = f(acceptance, gamma) against the cost of
    gamma draft steps + one batched verify — the pricing ``--path auto``
    uses to DECLINE speculation when the draft is too slow (sentinel drafts
    save no compute under the current kernels) or acceptance is assumed too
    low for the dispatch amortization to win."""
    b = max(int(batch_size), 1)
    return SpecEstimate(
        gamma=int(gamma), acceptance=float(acceptance),
        expected_tokens=expected_tokens_per_dispatch(acceptance, gamma),
        target_step_s=_tree_step_cost(registry, target_tree, b, profile),
        draft_step_s=_tree_step_cost(registry, draft_tree, b, profile),
        verify_s=_tree_step_cost(registry, target_tree, b * (int(gamma) + 1),
                                 profile))


def abstract_serving_tree(cfg, registry, reps: dict[str, str],
                          param_dtype=None, tp: int = 1) -> dict:
    """ShapeDtypeStruct serving pytree for ``reps`` (no allocation).

    Leaves are format objects with ShapeDtypeStruct fields (each format's
    ``abstract`` classmethod owns its own leaf schema). condensed-over-
    active uses a = d_out as the static bound (the dry-run has no realized
    ablation counts); the concrete export shrinks a to the real max
    active-neuron count.

    ``tp > 1`` builds every non-masked leaf in its block layout (stacks
    whose ``d_out`` the shard count does not divide stay replicated, as in
    ``build_plan``).
    """
    dt = jnp.dtype(param_dtype or cfg.param_dtype)
    tp = max(int(tp), 1)
    out: dict = {}
    for s in registry:
        rep = reps[s.name]
        try:
            cls = F.FORMATS[rep]
        except KeyError:
            raise ValueError(f"unknown representation {rep!r}") from None
        k = D.fan_in_from_density(s.d_in, s.density)
        tp_s = tp if (rep != "masked" and s.d_out % tp == 0) else 1
        if tp_s > 1:
            leaf = cls.abstract(s.lead, s.d_in, s.d_out, k, dt, tp=tp_s)
        else:
            leaf = cls.abstract(s.lead, s.d_in, s.d_out, k, dt)
        REG.set_path(out, s.path, leaf)
    return out
