"""Serving execution plans: per-stack representation selection + refresh.

The paper's headline serving result (Sec. 4.4) is that the SAME trained
constant-fan-in weights can execute under multiple representations, and which
one wins depends on the request's batch shape and the hardware balance:
masked-dense rides the MXU at large batch, the condensed gather rides HBM
bandwidth at decode/B=1, and the best Fig. 4 point COMPOSES neuron ablation
with the condensed layout (condensed-over-active). This module is the single
place that decision lives:

* ``build_plan`` turns a trained (params, masks) pair into a ``Plan`` — a
  per-``SparseStack`` representation choice (made by a bytes/FLOPs cost model
  when ``path="auto"``, or forced by a fixed path name) plus the serving
  pytree that plugs into the masks slot of prefill/decode_step.
* ``Plan.refresh`` is the incremental export: given the trainer's per-stack
  mask-version counters, only stacks whose version changed since the last
  export are re-condensed — a live training job can serve without paying a
  full re-export every delta_t steps.
* ``plan_for_shape`` / ``abstract_serving_tree`` are the allocation-free
  variants the dry-run uses to lower a planned decode program.

Consumers: repro.launch.serve (``--path auto``), repro.launch.dryrun
(``serve_plan`` program), benchmarks/serve_paths.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.sparse import condensed as COND
from repro.sparse import registry as REG

REPRESENTATIONS = ("masked", "condensed", "structured", "condensed_over_active")
PATHS = REPRESENTATIONS + ("auto",)

# fraction below 1.0 at which a stack counts as having ablated neurons (guards
# against float fuzz in the mean-active reduction)
_ABLATION_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Throughput balance the cost model prices representations against.

    Defaults are TPU-v5e-like and deliberately coarse: the model only needs
    the RATIOS right (MXU ~50x the gather unit, arithmetic-intensity knee
    around B~100 for 10%-dense stacks) to reproduce the paper's batch-1 vs
    batch-256 crossover. Real-hardware calibration is a follow-up (see
    ROADMAP: TPU block-size validation).
    """
    name: str = "tpu-v5e-like"
    hbm_bytes_per_s: float = 8.19e11     # ~819 GB/s HBM
    mxu_flops_per_s: float = 1.97e14     # dense MXU matmul throughput
    gather_flops_per_s: float = 3.9e12   # VPU gather-multiply-accumulate


DEFAULT_PROFILE = HardwareProfile()


@dataclasses.dataclass(frozen=True)
class StackDecision:
    """One stack's chosen representation + the cost table that chose it."""
    name: str
    representation: str
    est_s: dict[str, float]       # representation -> est. seconds per step
    stats: COND.ExportStats       # realized fan-in / ablation at export time

    @property
    def active_fraction(self) -> float:
        return self.stats.active_fraction


def stack_costs(stack, *, batch_size: int, itemsize: int, k: int,
                active_fraction: float,
                profile: HardwareProfile = DEFAULT_PROFILE) -> dict[str, float]:
    """Estimated seconds per serving step for each representation.

    Each representation's time is the roofline max of its HBM-byte term and
    its compute term on the unit that executes it:

    * masked     — reads the full dense weight + bool mask; dense MXU matmul.
    * condensed  — reads n_out*k (values + int32 indices); VPU gather-MAC,
                   so its compute term grows with batch ~50x faster than the
                   MXU's (the reason masked wins back at large batch).
    * structured — priced at what kernels.ops.structured_dense actually
                   executes: a FULL dense matmul over the full weight (only
                   the bool fan-in mask read is saved; neuron_active is
                   n_out bools). A true column-gathered kernel that delivers
                   the active-fraction saving is a ROADMAP follow-up — do
                   not price savings the code doesn't deliver.
    * condensed_over_active — the condensed terms scaled by the active
                   fraction (gather over surviving rows only; the kernel
                   really does run over a <= n_out rows).
    """
    b = max(int(batch_size), 1)
    n = stack.n_replicas
    act = min(max(active_fraction, 0.0), 1.0)
    dense_bytes = n * stack.d_in * stack.d_out * itemsize
    mask_bytes = n * stack.d_in * stack.d_out          # bool mask, 1 byte
    cond_bytes = n * stack.d_out * k * (itemsize + 4)  # values + int32 idx
    dense_flops = 2.0 * b * n * stack.d_in * stack.d_out
    gather_flops = 2.0 * b * n * stack.d_out * k
    return {
        "masked": max((dense_bytes + mask_bytes) / profile.hbm_bytes_per_s,
                      dense_flops / profile.mxu_flops_per_s),
        "condensed": max(cond_bytes / profile.hbm_bytes_per_s,
                         gather_flops / profile.gather_flops_per_s),
        "structured": max((dense_bytes + n * stack.d_out) / profile.hbm_bytes_per_s,
                          dense_flops / profile.mxu_flops_per_s),
        "condensed_over_active": max(
            act * cond_bytes / profile.hbm_bytes_per_s,
            act * gather_flops / profile.gather_flops_per_s),
    }


def select_representation(stack, *, batch_size: int, itemsize: int,
                          stats: COND.ExportStats,
                          profile: HardwareProfile = DEFAULT_PROFILE) -> StackDecision:
    """Cost-model choice among EXACT representations for one stack.

    ``structured`` is never auto-selected: it keeps active columns dense, so
    it is only output-equivalent for ablation-only masks (Fig. 4 ablation, on
    request via a fixed path). The exact candidates are masked, and the
    gather family — plain condensed when every neuron is active, condensed-
    over-active once ablation has created dead rows to drop.
    """
    costs = stack_costs(stack, batch_size=batch_size, itemsize=itemsize,
                        k=max(stats.k, 1),
                        active_fraction=stats.active_fraction, profile=profile)
    has_ablation = stats.active_fraction < 1.0 - _ABLATION_EPS
    gather_rep = "condensed_over_active" if has_ablation else "condensed"
    rep = min(("masked", gather_rep), key=lambda r: costs[r])
    return StackDecision(name=stack.name, representation=rep, est_s=costs,
                         stats=stats)


def _build_leaf(rep: str, weight, mask, stats: COND.ExportStats):
    if rep == "masked":
        return mask
    if rep == "condensed":
        return COND.condense_stack_leaf(weight, mask, stats)
    if rep == "condensed_over_active":
        return COND.condense_active_stack_leaf(weight, mask, stats)
    if rep == "structured":
        return COND.structured_stack_leaf(mask)
    raise ValueError(f"unknown representation {rep!r}")


def _decide(stack, path: str, *, batch_size: int, itemsize: int,
            stats: COND.ExportStats, profile: HardwareProfile) -> StackDecision:
    """One stack's decision: cost-model choice for "auto", forced otherwise.
    Shared by build_plan and Plan.refresh so the two can never diverge."""
    if path == "auto":
        return select_representation(stack, batch_size=batch_size,
                                     itemsize=itemsize, stats=stats,
                                     profile=profile)
    costs = stack_costs(stack, batch_size=batch_size, itemsize=itemsize,
                        k=max(stats.k, 1),
                        active_fraction=stats.active_fraction, profile=profile)
    return StackDecision(name=stack.name, representation=path, est_s=costs,
                         stats=stats)


def _host_versions(mask_versions: dict) -> dict[str, int]:
    """Trainer counters (host ints or device scalars) -> plain int dict,
    fetched with one device_get."""
    return {k: int(v) for k, v in jax.device_get(dict(mask_versions)).items()}


@dataclasses.dataclass
class Plan:
    """A built execution plan: decisions + serving pytree + export versions.

    ``serving_tree`` plugs into the masks slot of prefill/decode_step;
    repro.models.layers.linear dispatches per leaf. ``export_calls`` counts
    per-stack leaf (re)builds over the plan's lifetime — the incremental-
    export tests assert it only grows by the number of CHANGED stacks.
    """
    cfg: object
    registry: list
    path: str                      # requested path ("auto" or a fixed rep)
    batch_size: int
    profile: HardwareProfile
    decisions: dict[str, StackDecision]
    serving_tree: dict
    mask_versions: dict[str, int]  # stack name -> version at last export
    export_calls: int = 0
    value_refreshes: int = 0       # cheap values-only regathers (no re-sort)

    def representation_of(self, name: str) -> str:
        return self.decisions[name].representation

    def refresh(self, params: dict, masks: dict, mask_versions: dict, *,
                refresh_values: bool = True) -> list[str]:
        """Incremental re-export: re-condense ONLY stacks whose version moved.

        ``mask_versions`` is the trainer's per-stack counter pytree (host ints
        or device scalars; fetched with one device_get). Changed stacks get
        fresh realized stats (one fused program over just those stacks), a
        re-run of the cost model (ablation appearing mid-training can flip
        condensed -> condensed_over_active), and a rebuilt leaf. Returns the
        names of the stacks that were re-exported.

        Version counters only track TOPOLOGY: between DST steps the weights
        keep training for every stack, so with ``refresh_values=True``
        (default) the unchanged condensed-family stacks get a values-only
        regather at their stored indices — cheap (no argsort, no stats sync,
        indices reused verbatim) but necessary for the serving snapshot to be
        coherent with ``params``. Masked/structured leaves need nothing: they
        read the live weights from ``params`` at execution time. Pass
        ``refresh_values=False`` only when params are frozen (serving a fixed
        checkpoint).
        """
        versions = _host_versions(mask_versions)
        by_name = {s.name: s for s in self.registry}
        changed = [by_name[n] for n, v in versions.items()
                   if n in by_name and v != self.mask_versions.get(n)]
        changed_names = {s.name for s in changed}
        if changed:
            stats = COND.export_stats(self.registry, masks, stacks=changed)
            itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
            for s in changed:
                dec = _decide(s, self.path, batch_size=self.batch_size,
                              itemsize=itemsize, stats=stats[s.name],
                              profile=self.profile)
                self.decisions[s.name] = dec
                REG._set_path(self.serving_tree, s.path,
                              _build_leaf(dec.representation,
                                          REG.get_path(params, s.path),
                                          REG.get_path(masks, s.path),
                                          stats[s.name]))
                self.mask_versions[s.name] = versions[s.name]
                self.export_calls += 1
        if refresh_values:
            for s in self.registry:
                if s.name in changed_names:
                    continue
                rep = self.decisions[s.name].representation
                if rep not in ("condensed", "condensed_over_active"):
                    continue
                leaf = REG.get_path(self.serving_tree, s.path)
                REG._set_path(self.serving_tree, s.path,
                              COND.revalue_stack_leaf(
                                  REG.get_path(params, s.path),
                                  REG.get_path(masks, s.path), leaf))
                self.value_refreshes += 1
        return [s.name for s in changed]

    def weight_bytes(self) -> tuple[int, int]:
        """(serving weight bytes under this plan, masked-path weight bytes).

        The reference is the masked-dense serving path's traffic — dense
        weights PLUS the bool mask it also reads — so a plan that resolves
        every stack to masked reports exactly the reference (ratio 1.0).
        condensed_over_active is priced at its EXPORTED size: max_active rows
        per replica (stack-wide max, padding included) of k*(values+idx)
        plus the 4-byte out_index per row — not the mean active fraction,
        which would understate the footprint under uneven ablation.
        """
        itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
        masked_ref = serving = 0
        for s in self.registry:
            dec = self.decisions[s.name]
            n = s.n_replicas
            k = max(dec.stats.k, 1)
            a = max(dec.stats.max_active, 1)
            d_bytes = n * s.d_in * s.d_out * itemsize
            m_bytes = d_bytes + n * s.d_in * s.d_out          # + bool mask
            serving += {
                "masked": m_bytes,
                # structured_dense still reads the FULL dense weight (plus
                # n_out neuron_active bools); only the fan-in mask is saved
                "structured": d_bytes + n * s.d_out,
                "condensed": n * s.d_out * k * (itemsize + 4),
                "condensed_over_active": n * a * (k * (itemsize + 4) + 4),
            }[dec.representation]
            masked_ref += m_bytes
        return serving, masked_ref

    def describe(self) -> str:
        lines = [f"[plan] path={self.path} batch={self.batch_size} "
                 f"profile={self.profile.name}"]
        for name, dec in self.decisions.items():
            est = dec.est_s[dec.representation]
            lines.append(
                f"[plan]   {name:24s} -> {dec.representation:22s} "
                f"(est {est * 1e6:8.3f} us/step, k={dec.stats.k}, "
                f"active={dec.active_fraction:.2f})")
        return "\n".join(lines)


def build_plan(cfg, registry, params: dict, masks: dict, *,
               batch_size: int = 1, path: str = "auto",
               mask_versions: dict | None = None,
               profile: HardwareProfile = DEFAULT_PROFILE) -> Plan:
    """Build the per-stack execution plan for a request batch shape.

    ``path="auto"`` selects per stack by the cost model; a fixed path name
    forces that representation everywhere (the pre-plan ``--path`` behavior).
    ``mask_versions`` snapshots the trainer's counters so a later ``refresh``
    only re-exports stacks whose counter moved.
    """
    if path not in PATHS:
        raise ValueError(f"unknown serving path {path!r}; expected one of {PATHS}")
    registry = list(registry or [])
    versions = (_host_versions(mask_versions) if mask_versions is not None
                else {s.name: 0 for s in registry})
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    stats = COND.export_stats(registry, masks)

    decisions: dict[str, StackDecision] = {}
    tree: dict = {}
    calls = 0
    for s in registry:
        dec = _decide(s, path, batch_size=batch_size, itemsize=itemsize,
                      stats=stats[s.name], profile=profile)
        decisions[s.name] = dec
        REG._set_path(tree, s.path,
                      _build_leaf(dec.representation,
                                  REG.get_path(params, s.path),
                                  REG.get_path(masks, s.path), stats[s.name]))
        calls += 1
    return Plan(cfg=cfg, registry=registry, path=path, batch_size=batch_size,
                profile=profile, decisions=decisions, serving_tree=tree,
                mask_versions={s.name: versions.get(s.name, 0) for s in registry},
                export_calls=calls)


# ---------------------------------------------------------------------------
# allocation-free variants (dry-run / compile-only consumers)
# ---------------------------------------------------------------------------

def plan_for_shape(cfg, registry, *, batch_size: int,
                   profile: HardwareProfile = DEFAULT_PROFILE) -> dict[str, str]:
    """Representation choice per stack from STATIC info only (target ERK
    densities, no realized masks — so no ablation is assumed). Used by the
    dry-run to pick what to lower for a given serving shape."""
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    out = {}
    for s in registry:
        stats = COND.ExportStats(k=D.fan_in_from_density(s.d_in, s.density),
                                 max_active=s.d_out, active_fraction=1.0)
        dec = select_representation(s, batch_size=batch_size, itemsize=itemsize,
                                    stats=stats, profile=profile)
        out[s.name] = dec.representation
    return out


def abstract_serving_tree(cfg, registry, reps: dict[str, str],
                          param_dtype=None) -> dict:
    """ShapeDtypeStruct serving pytree for ``reps`` (no allocation).

    condensed-over-active uses a = d_out as the static bound (the dry-run has
    no realized ablation counts); the concrete export shrinks a to the real
    max active-neuron count.
    """
    dt = jnp.dtype(param_dtype or cfg.param_dtype)
    out: dict = {}
    for s in registry:
        rep = reps[s.name]
        k = D.fan_in_from_density(s.d_in, s.density)
        if rep == "masked":
            leaf = jax.ShapeDtypeStruct((*s.lead, s.d_in, s.d_out), jnp.bool_)
        elif rep == "condensed":
            shape = (*s.lead, s.d_out, k)
            leaf = {"values": jax.ShapeDtypeStruct(shape, dt),
                    "indices": jax.ShapeDtypeStruct(shape, jnp.int32)}
        elif rep == "condensed_over_active":
            shape = (*s.lead, s.d_out, k)
            leaf = {"values": jax.ShapeDtypeStruct(shape, dt),
                    "indices": jax.ShapeDtypeStruct(shape, jnp.int32),
                    "out_index": jax.ShapeDtypeStruct((*s.lead, s.d_out),
                                                      jnp.int32)}
        elif rep == "structured":
            leaf = {"neuron_active": jax.ShapeDtypeStruct((*s.lead, s.d_out),
                                                          jnp.bool_)}
        else:
            raise ValueError(f"unknown representation {rep!r}")
        REG._set_path(out, s.path, leaf)
    return out
