"""Serving execution plans: per-stack representation selection + refresh.

The paper's headline serving result (Sec. 4.4) is that the SAME trained
constant-fan-in weights can execute under multiple representations, and which
one wins depends on the request's batch shape and the hardware balance:
masked-dense rides the MXU at large batch, the condensed gather rides HBM
bandwidth at decode/B=1, and the best Fig. 4 point COMPOSES neuron ablation
with the condensed layout (condensed-over-active). This module is the single
place that decision lives:

* ``build_plan`` turns a trained (params, masks) pair into a ``Plan`` — a
  per-``SparseStack`` representation choice (priced by each format's
  ``estimate_cost`` from repro.sparse.formats when ``path="auto"``, or
  forced by a fixed path name) plus the serving pytree (format-object
  leaves) that plugs into the masks slot of prefill/decode_step.
* ``Plan.refresh`` is the incremental export: given the trainer's per-stack
  mask-version counters, only stacks whose version changed since the last
  export are re-condensed — a live training job can serve without paying a
  full re-export every delta_t steps.
* ``plan_for_shape`` / ``abstract_serving_tree`` are the allocation-free
  variants the dry-run uses to lower a planned decode program.

Consumers: repro.launch.engine (``ServingEngine`` builds one plan per
request group), repro.launch.serve (the thin CLI over the engine),
repro.launch.dryrun (``serve_plan``/``serve_engine`` programs),
benchmarks/serve_paths.py.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import registry as REG

REPRESENTATIONS = ("masked", "condensed", "structured", "condensed_over_active")
PATHS = REPRESENTATIONS + ("auto",)

# fraction below 1.0 at which a stack counts as having ablated neurons (guards
# against float fuzz in the mean-active reduction)
_ABLATION_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Throughput balance the format cost models price against.

    Defaults are TPU-v5e-like and deliberately coarse: the model only needs
    the RATIOS right (MXU ~50x the gather unit, arithmetic-intensity knee
    around B~100 for 10%-dense stacks) to reproduce the paper's batch-1 vs
    batch-256 crossover. ``HardwareProfile.measure()`` replaces the
    constants with rates microbenchmarked on the live backend, so the auto
    crossover batch is derived from THIS machine (serve.py --profile
    measured; benchmarks/kernel_autotune.py validates predicted-vs-measured
    crossover).

    The gather unit is calibrated at TWO batch points (``gather_flops_per_s``
    at ``gather_small_batch``, ``gather_flops_per_s_large`` at
    ``gather_large_batch``): the condensed gather's ACTIVATION traffic
    (b*n_out*k gathered elements) falls off a cache cliff at large batch
    that a single scalar rate cannot express. ``gather_rate(batch)``
    log-interpolates between the two measured points; profiles with
    ``gather_flops_per_s_large=None`` (e.g. the built-in default) behave as
    the old single-rate model.
    """
    name: str = "tpu-v5e-like"
    hbm_bytes_per_s: float = 8.19e11     # ~819 GB/s HBM
    mxu_flops_per_s: float = 1.97e14     # dense MXU matmul throughput
    gather_flops_per_s: float = 3.9e12   # VPU gather-MAC at the SMALL point
    gather_flops_per_s_large: float | None = None  # large-batch point (cliff)
    gather_small_batch: int = 8
    gather_large_batch: int = 512

    def gather_rate(self, batch: int) -> float:
        """Gather throughput at ``batch``: log-log interpolation between the
        two calibration points, clamped outside them. Falls back to the
        single small-point rate when no large-point calibration exists."""
        small, large = self.gather_flops_per_s, self.gather_flops_per_s_large
        if not large or self.gather_large_batch <= self.gather_small_batch:
            return small
        b = int(batch)
        if b <= self.gather_small_batch:
            return small
        if b >= self.gather_large_batch:
            return large
        t = ((math.log(b) - math.log(self.gather_small_batch))
             / (math.log(self.gather_large_batch)
                - math.log(self.gather_small_batch)))
        return math.exp((1.0 - t) * math.log(small) + t * math.log(large))

    @classmethod
    def measure(cls, *, stream_mb: float = 96.0,
                matmul_shape: tuple[int, int, int] = (128, 2048, 1024),
                gather_shape: tuple[int, int, int, int] = (8, 2048, 1024, 205),
                gather_large_shape: tuple[int, int, int, int] = (512, 2048,
                                                                 1024, 205),
                reps: int = 5, use_cache: bool = True,
                save: bool = True) -> "HardwareProfile":
        """Microbenchmark the cost-model rates on the live backend.

        * ``hbm_bytes_per_s``    — streaming ``x + 1`` over ``stream_mb`` of
                                   f32 (reads + writes both counted; the
                                   default comfortably exceeds CPU last-level
                                   caches so the rate is main-memory, and the
                                   MEDIAN rep is used — a buffer that half
                                   fits LLC makes the fastest rep a cache
                                   burst, not the steady-state rate a serving
                                   step streams weights at);
        * ``mxu_flops_per_s``    — f32 matmul at ``matmul_shape = (b, d_in,
                                   d_out)``, a rectangular serving-batch
                                   shape rather than a peak-friendly square;
        * ``gather_flops_per_s`` / ``gather_flops_per_s_large`` — the
                                   condensed gather-MAC in its jnp
                                   formulation (kernels.ref) at TWO batch
                                   points: ``gather_shape`` sits at the top
                                   of the small-batch bucket (~10% density,
                                   the regime where the masked/condensed
                                   crossover is decided) and
                                   ``gather_large_shape`` at a batch whose
                                   gathered-activation working set blows the
                                   cache — together they bound the cache
                                   cliff the ROADMAP documents, so crossover
                                   prediction tightens beyond one-bucket
                                   accuracy.

        Each timing is the best of ``reps`` runs after a compile+warmup pass
        (min is the noise-robust estimator on shared hosts — see
        autotune._time_us). With ``use_cache`` the measured rates persist per
        backend in the autotune cache file (see
        repro.sparse.autotune.cache_path) and later calls return the stored
        profile without re-measuring; ``measure(use_cache=False)`` forces a
        fresh measurement, and ``save=False`` keeps it out of the cache.
        """
        import jax.random as jrandom

        from repro.kernels import ref as REF
        from repro.sparse import autotune as AT  # lazy: no module cycle

        backend = jax.default_backend()
        # the cache entry records its measurement settings: a profile
        # calibrated with different shapes/reps (e.g. a quick low-fidelity
        # test run) must not be silently substituted for this request
        params = {"stream_mb": stream_mb, "matmul_shape": list(matmul_shape),
                  "gather_shape": list(gather_shape),
                  "gather_large_shape": list(gather_large_shape),
                  "reps": reps}
        if use_cache:
            cached = AT.cached_profile(backend)
            if cached and cached.get("params") == params:
                return cls(name=cached["name"],
                           hbm_bytes_per_s=cached["hbm_bytes_per_s"],
                           mxu_flops_per_s=cached["mxu_flops_per_s"],
                           gather_flops_per_s=cached["gather_flops_per_s"],
                           gather_flops_per_s_large=cached.get(
                               "gather_flops_per_s_large"),
                           gather_small_batch=cached.get("gather_small_batch",
                                                         gather_shape[0]),
                           gather_large_batch=cached.get(
                               "gather_large_batch", gather_large_shape[0]))

        import statistics

        n = max(int(stream_mb * 2**20 / 4), 1024)
        xs = jnp.full((n,), 1.5, jnp.float32)
        t_stream = AT._time_us(jax.jit(lambda x: x + 1.0), xs, reps=reps,
                               agg=statistics.median)
        hbm = 8.0 * n / (t_stream * 1e-6)            # 4B read + 4B write

        key = jrandom.PRNGKey(0)
        mb, md_in, md_out = matmul_shape
        a = jrandom.normal(key, (mb, md_in), jnp.float32)
        b_ = jrandom.normal(jrandom.fold_in(key, 1), (md_in, md_out),
                            jnp.float32)
        t_mm = AT._time_us(jax.jit(jnp.matmul), a, b_, reps=reps)
        mxu = 2.0 * mb * md_in * md_out / (t_mm * 1e-6)

        def gather_point(shape, salt):
            gb, gd, gn, gk = shape
            x = jrandom.normal(jrandom.fold_in(key, salt), (gb, gd),
                               jnp.float32)
            vals = jrandom.normal(jrandom.fold_in(key, salt + 1), (gn, gk),
                                  jnp.float32)
            idx = jrandom.randint(jrandom.fold_in(key, salt + 2), (gn, gk),
                                  0, gd)
            t_g = AT._time_us(jax.jit(REF.condensed_matmul_ref), x, vals, idx,
                              reps=reps)
            return 2.0 * gb * gn * gk / (t_g * 1e-6)

        gather = gather_point(gather_shape, 2)
        gather_large = gather_point(gather_large_shape, 5)

        prof = cls(name=f"measured-{backend}", hbm_bytes_per_s=hbm,
                   mxu_flops_per_s=mxu, gather_flops_per_s=gather,
                   gather_flops_per_s_large=gather_large,
                   gather_small_batch=gather_shape[0],
                   gather_large_batch=gather_large_shape[0])
        if save:
            AT.store_profile({"name": prof.name,
                              "hbm_bytes_per_s": prof.hbm_bytes_per_s,
                              "mxu_flops_per_s": prof.mxu_flops_per_s,
                              "gather_flops_per_s": prof.gather_flops_per_s,
                              "gather_flops_per_s_large":
                                  prof.gather_flops_per_s_large,
                              "gather_small_batch": prof.gather_small_batch,
                              "gather_large_batch": prof.gather_large_batch,
                              "params": params},
                             backend=backend)
        return prof


DEFAULT_PROFILE = HardwareProfile()


@dataclasses.dataclass(frozen=True)
class StackDecision:
    """One stack's chosen representation + the cost table that chose it."""
    name: str
    representation: str
    est_s: dict[str, float]       # representation -> est. seconds per step
    stats: COND.ExportStats       # realized fan-in / ablation at export time

    @property
    def active_fraction(self) -> float:
        return self.stats.active_fraction


def stack_costs(stack, *, batch_size: int, itemsize: int, k: int,
                active_fraction: float,
                profile: HardwareProfile = DEFAULT_PROFILE,
                max_active_fraction: float | None = None,
                values_dtype: str | None = None) -> dict[str, float]:
    """Estimated seconds per serving step for each representation.

    Pricing lives with the formats themselves now: each representation's
    ``estimate_cost`` (repro.sparse.formats) is the roofline max of its
    HBM-byte term (``estimate_weight_bytes``) and its compute term on the
    unit that executes it. This wrapper builds the ``FormatSpec`` each class
    prices from — ``max_active_fraction`` is the EXPORTED row fraction for
    condensed_over_active (the leaf carries max_active rows per replica,
    padding included; the mean ``active_fraction`` is the documented
    fallback and would under-price the path under uneven ablation).
    ``values_dtype`` (a canonical name from ``formats.VALUES_DTYPES``) lets
    each format price its REAL stored byte width — a quantized export
    shrinks the HBM roofline term, which can move the masked/condensed
    crossover batch.
    """
    b = max(int(batch_size), 1)
    act = min(max(active_fraction, 0.0), 1.0)
    row_frac = act if max_active_fraction is None else \
        min(max(max_active_fraction, 0.0), 1.0)
    spec = F.FormatSpec(d_in=stack.d_in, d_out=stack.d_out,
                        n_replicas=stack.n_replicas, itemsize=itemsize,
                        k=max(k, 1), max_active=row_frac * stack.d_out,
                        active_fraction=act,
                        values_dtype=F.resolve_quantize_spec(values_dtype))
    return {name: cls.estimate_cost(spec, b, profile)
            for name, cls in F.FORMATS.items()}


def select_representation(stack, *, batch_size: int, itemsize: int,
                          stats: COND.ExportStats,
                          profile: HardwareProfile = DEFAULT_PROFILE,
                          values_dtype: str | None = None) -> StackDecision:
    """Cost-model choice among EXACT representations for one stack.

    The always-exact candidates are masked, plain condensed, and — once
    ablation has created dead rows to drop — condensed-over-active. Plain
    condensed stays a candidate even with ablation: under UNEVEN ablation
    the exported condensed-over-active leaf still carries max_active rows
    (plus out_index bytes) and can price ABOVE plain condensed, which is
    exact for any mask.

    ``structured`` joins the candidate set only for ABLATION-ONLY stacks
    (``stats.min_fan_in == d_in``: every surviving column fully dense —
    structured keeps active columns dense, so that is the one regime where
    it is output-equivalent). With the column-gathered kernel its weight
    bytes and MXU FLOPs scale with the active fraction, so it wins the
    bandwidth-bound shapes of ablation-only stacks outright and cedes to
    masked at large batch where its fused scatter epilogue's extra MXU term
    outweighs the column saving.
    """
    costs = stack_costs(stack, batch_size=batch_size, itemsize=itemsize,
                        k=max(stats.k, 1),
                        active_fraction=stats.active_fraction, profile=profile,
                        max_active_fraction=_max_active_fraction(stack, stats),
                        values_dtype=values_dtype)
    has_ablation = stats.active_fraction < 1.0 - _ABLATION_EPS
    cands = ("masked", "condensed")
    if has_ablation:
        cands += ("condensed_over_active",)
        if stats.min_fan_in >= stack.d_in:
            cands += ("structured",)
    rep = min(cands, key=lambda r: costs[r])
    return StackDecision(name=stack.name, representation=rep, est_s=costs,
                         stats=stats)


def _max_active_fraction(stack, stats: COND.ExportStats) -> float:
    """Exported-row fraction pricing condensed_over_active: the leaf carries
    max_active rows per replica (stack-wide max, padding included)."""
    return max(stats.max_active, 1) / max(stack.d_out, 1)


def _build_leaf(rep: str, weight, mask, stats: COND.ExportStats,
                values_dtype: str | None = None) -> F.SparseFormat:
    """Construct the format object for one stack (export_from_dense).

    ``values_dtype`` becomes the export's ``quantize_spec`` for the formats
    that store values; masked-dense reads the live dense weights at
    execution time and has nothing to quantize, so it ignores the request
    (documented engine behavior: a quantized plan serves masked stacks at
    the param dtype).
    """
    try:
        cls = F.FORMATS[rep]
    except KeyError:
        raise ValueError(f"unknown representation {rep!r}") from None
    if values_dtype is not None and rep != "masked":
        return cls.export_from_dense(weight, mask, stats,
                                     quantize_spec=values_dtype)
    return cls.export_from_dense(weight, mask, stats)


def _decide(stack, path: str, *, batch_size: int, itemsize: int,
            stats: COND.ExportStats, profile: HardwareProfile,
            values_dtype: str | None = None) -> StackDecision:
    """One stack's decision: cost-model choice for "auto", forced otherwise.
    Shared by build_plan and Plan.refresh so the two can never diverge."""
    if path == "auto":
        return select_representation(stack, batch_size=batch_size,
                                     itemsize=itemsize, stats=stats,
                                     profile=profile, values_dtype=values_dtype)
    costs = stack_costs(stack, batch_size=batch_size, itemsize=itemsize,
                        k=max(stats.k, 1),
                        active_fraction=stats.active_fraction, profile=profile,
                        max_active_fraction=_max_active_fraction(stack, stats),
                        values_dtype=values_dtype)
    return StackDecision(name=stack.name, representation=path, est_s=costs,
                         stats=stats)


def _host_versions(mask_versions: dict) -> dict[str, int]:
    """Trainer counters (host ints or device scalars) -> plain int dict,
    fetched with one device_get."""
    return {k: int(v) for k, v in jax.device_get(dict(mask_versions)).items()}


@dataclasses.dataclass
class Plan:
    """A built execution plan: decisions + serving pytree + export versions.

    ``serving_tree`` plugs into the masks slot of prefill/decode_step; its
    leaves are ``repro.sparse.formats`` objects and
    repro.models.layers.linear dispatches on their type. ``export_calls``
    counts per-stack leaf (re)builds over the plan's lifetime — the
    incremental-export tests assert it only grows by the number of CHANGED
    stacks.
    """
    cfg: object
    registry: list
    path: str                      # requested path ("auto" or a fixed rep)
    batch_size: int
    profile: HardwareProfile
    decisions: dict[str, StackDecision]
    serving_tree: dict
    mask_versions: dict[str, int]  # stack name -> version at last export
    values_dtype: str | None = None  # canonical quantize spec (None = param dtype)
    export_calls: int = 0
    value_refreshes: int = 0       # cheap values-only regathers (no re-sort)

    def representation_of(self, name: str) -> str:
        return self.decisions[name].representation

    def format_of(self, name: str) -> type[F.SparseFormat]:
        return F.FORMATS[self.decisions[name].representation]

    def refresh(self, params: dict, masks: dict, mask_versions: dict, *,
                refresh_values: bool = True, donate: bool = True) -> list[str]:
        """Incremental re-export: re-condense ONLY stacks whose version moved.

        ``mask_versions`` is the trainer's per-stack counter pytree (host ints
        or device scalars; fetched with one device_get). Changed stacks get
        fresh realized stats (one fused program over just those stacks), a
        re-run of the cost model (ablation appearing mid-training can flip
        condensed -> condensed_over_active), and a rebuilt leaf. Returns the
        names of the stacks that were re-exported.

        Version counters only track TOPOLOGY: between DST steps the weights
        keep training for every stack, so with ``refresh_values=True``
        (default) the unchanged condensed-family stacks get a values-only
        regather at their stored indices (``formats.*.refresh_values``) —
        cheap (no argsort, no stats sync, indices reused verbatim) but
        necessary for the serving snapshot to be coherent with ``params``.
        Masked/structured leaves need nothing: they read the live weights
        from ``params`` at execution time. Pass ``refresh_values=False``
        only when params are frozen (serving a fixed checkpoint).

        Memory/host-transfer contract (a live serving job refreshes in
        place): the re-condense and the regather run as jitted device
        programs with the plan's OLD format buffers DONATED
        (``formats.*.donate_refresh``) — whenever the new leaf's shapes
        match (topology rewired at unchanged fan-in, or values-only), the
        new arrays are written into the old buffers, so the refresh never
        doubles the plan's weight footprint. No weight data is fetched to
        the host: the only device_get traffic is the version counters and
        (for changed stacks) the per-stack scalar stats. ``donate=False``
        preserves the old leaf arrays for callers that still hold
        references to them.
        """
        versions = _host_versions(mask_versions)
        by_name = {s.name: s for s in self.registry}
        changed = [by_name[n] for n, v in versions.items()
                   if n in by_name and v != self.mask_versions.get(n)]
        changed_names = {s.name for s in changed}
        if changed:
            stats = COND.export_stats(self.registry, masks, stacks=changed)
            itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
            for s in changed:
                dec = _decide(s, self.path, batch_size=self.batch_size,
                              itemsize=itemsize, stats=stats[s.name],
                              profile=self.profile,
                              values_dtype=self.values_dtype)
                old_rep = self.decisions[s.name].representation
                old_leaf = REG.get_path(self.serving_tree, s.path)
                weight = REG.get_path(params, s.path)
                mask = REG.get_path(masks, s.path)
                rep = dec.representation
                if (rep in ("condensed", "condensed_over_active")
                        and rep == old_rep):
                    leaf = COND.recondense_stack_leaf(
                        weight, mask, stats[s.name], old_leaf,
                        over_active=(rep == "condensed_over_active"),
                        donate=donate, quantize_spec=self.values_dtype)
                else:
                    leaf = _build_leaf(rep, weight, mask, stats[s.name],
                                       self.values_dtype)
                self.decisions[s.name] = dec
                REG.set_path(self.serving_tree, s.path, leaf)
                self.mask_versions[s.name] = versions[s.name]
                self.export_calls += 1
        if refresh_values:
            for s in self.registry:
                if s.name in changed_names:
                    continue
                leaf = REG.get_path(self.serving_tree, s.path)
                if not isinstance(leaf, F.CONDENSED_FAMILY):
                    continue
                REG.set_path(self.serving_tree, s.path,
                             leaf.refresh_values(REG.get_path(params, s.path),
                                                 REG.get_path(masks, s.path),
                                                 donate=donate))
                self.value_refreshes += 1
        return [s.name for s in changed]

    def weight_bytes(self) -> tuple[int, int]:
        """(serving weight bytes under this plan, masked-path weight bytes).

        The reference is the masked-dense serving path's traffic — dense
        weights PLUS the bool mask it also reads — so a plan that resolves
        every stack to masked reports exactly the reference (ratio 1.0).
        Each format prices its own exported size
        (``formats.*.estimate_weight_bytes``); condensed_over_active is
        priced at max_active rows per replica (stack-wide max, padding
        included), not the mean active fraction.
        """
        itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
        masked_ref = serving = 0
        for s in self.registry:
            dec = self.decisions[s.name]
            spec = F.spec_for_stack(s, dec.stats, itemsize, self.values_dtype)
            serving += F.FORMATS[dec.representation].estimate_weight_bytes(spec)
            masked_ref += F.MaskedDense.estimate_weight_bytes(spec)
        return serving, masked_ref

    def describe(self) -> str:
        vd = f" values_dtype={self.values_dtype}" if self.values_dtype else ""
        lines = [f"[plan] path={self.path} batch={self.batch_size} "
                 f"profile={self.profile.name}{vd}"]
        for name, dec in self.decisions.items():
            est = dec.est_s[dec.representation]
            lines.append(
                f"[plan]   {name:24s} -> {dec.representation:22s} "
                f"(est {est * 1e6:8.3f} us/step, k={dec.stats.k}, "
                f"active={dec.active_fraction:.2f})")
        return "\n".join(lines)


def build_plan(cfg, registry, params: dict, masks: dict, *,
               batch_size: int = 1, path: str = "auto",
               mask_versions: dict | None = None,
               profile: HardwareProfile = DEFAULT_PROFILE,
               values_dtype: str | None = None) -> Plan:
    """Build the per-stack execution plan for a request batch shape.

    ``path="auto"`` selects per stack by the cost model; a fixed path name
    forces that representation everywhere (the pre-plan ``--path`` behavior).
    ``mask_versions`` snapshots the trainer's counters so a later ``refresh``
    only re-exports stacks whose counter moved.

    ``values_dtype`` (``"bf16"``/``"int8"``/``"fp8"``; None keeps the param
    dtype) quantizes every value-storing leaf at export time and feeds the
    real byte width into both the cost model and ``weight_bytes`` pricing.
    The choice is part of the PLAN, not the per-request key: ``refresh``
    re-exports under the same spec, so a live job never silently changes
    serving precision.
    """
    if path not in PATHS:
        raise ValueError(f"unknown serving path {path!r}; expected one of {PATHS}")
    vd = F.resolve_quantize_spec(values_dtype)
    registry = list(registry or [])
    versions = (_host_versions(mask_versions) if mask_versions is not None
                else {s.name: 0 for s in registry})
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    stats = COND.export_stats(registry, masks)

    decisions: dict[str, StackDecision] = {}
    tree: dict = {}
    calls = 0
    for s in registry:
        dec = _decide(s, path, batch_size=batch_size, itemsize=itemsize,
                      stats=stats[s.name], profile=profile, values_dtype=vd)
        decisions[s.name] = dec
        REG.set_path(tree, s.path,
                     _build_leaf(dec.representation,
                                 REG.get_path(params, s.path),
                                 REG.get_path(masks, s.path), stats[s.name],
                                 vd))
        calls += 1
    return Plan(cfg=cfg, registry=registry, path=path, batch_size=batch_size,
                profile=profile, decisions=decisions, serving_tree=tree,
                mask_versions={s.name: versions.get(s.name, 0) for s in registry},
                values_dtype=vd, export_calls=calls)


# ---------------------------------------------------------------------------
# allocation-free variants (dry-run / compile-only consumers)
# ---------------------------------------------------------------------------

def plan_for_shape(cfg, registry, *, batch_size: int,
                   profile: HardwareProfile = DEFAULT_PROFILE) -> dict[str, str]:
    """Representation choice per stack from STATIC info only (target ERK
    densities, no realized masks — so no ablation is assumed). Used by the
    dry-run to pick what to lower for a given serving shape."""
    itemsize = jnp.dtype(cfg.param_dtype).itemsize
    out = {}
    for s in registry:
        stats = COND.ExportStats(k=D.fan_in_from_density(s.d_in, s.density),
                                 max_active=s.d_out, active_fraction=1.0)
        dec = select_representation(s, batch_size=batch_size, itemsize=itemsize,
                                    stats=stats, profile=profile)
        out[s.name] = dec.representation
    return out


def abstract_serving_tree(cfg, registry, reps: dict[str, str],
                          param_dtype=None) -> dict:
    """ShapeDtypeStruct serving pytree for ``reps`` (no allocation).

    Leaves are format objects with ShapeDtypeStruct fields (each format's
    ``abstract`` classmethod owns its own leaf schema). condensed-over-
    active uses a = d_out as the static bound (the dry-run has no realized
    ablation counts); the concrete export shrinks a to the real max
    active-neuron count.
    """
    dt = jnp.dtype(param_dtype or cfg.param_dtype)
    out: dict = {}
    for s in registry:
        rep = reps[s.name]
        try:
            cls = F.FORMATS[rep]
        except KeyError:
            raise ValueError(f"unknown representation {rep!r}") from None
        k = D.fan_in_from_density(s.d_in, s.density)
        REG.set_path(out, s.path, cls.abstract(s.lead, s.d_in, s.d_out, k, dt))
    return out
