"""Kernel autotuning: timed block-shape search + persistent measured cache.

The condensed Pallas kernel's block shape is a pure performance knob (every
VMEM-fitting shape computes the same result), so the right shape is a
MEASURED property of the machine, not a constant. This module owns that
measurement:

* ``autotune_blocks`` times every VMEM-budget candidate from
  ``kernels.condensed_matmul.block_candidates`` — plus the decode-specialized
  variant for small-batch buckets and the legacy 128x128 default as the
  baseline — on the live backend, and records the winner.
  ``autotune_structured_blocks`` / ``autotune_coa_blocks`` run the same
  search for the ablation-aware kernels (kernels.structured_matmul) under
  their own key spaces (kind="structured"/"coa" — entries are only valid
  for the kernel they were timed on).
* Results persist in a JSON cache keyed by ``backend + shape + batch
  bucket`` (``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune.json``),
  so tuning survives process restarts and ships with a deployment image.
* ``lookup_blocks`` is the cheap read path consumed by
  ``kernels.ops.condensed_linear`` at trace time: cached winner if present,
  None otherwise (callers fall back to the untimed VMEM-budget default).
* The same cache file stores measured ``HardwareProfile`` rates per backend
  (see ``plan.HardwareProfile.measure``), so the ``--path auto`` cost model
  and the kernel blocks are calibrated by one artifact.

Batch sizes are bucketed (``BATCH_BUCKETS``): a tuned entry for bucket 32
serves every batch in (8, 32]. Entries record the full timing table, not
just the winner, so benchmarks can report default-vs-tuned from a single
measurement pass.
"""
from __future__ import annotations

import json
import os
import time
import typing

import jax
import jax.numpy as jnp

from repro.kernels import condensed_matmul as cm
from repro.kernels import structured_matmul as sm

# Batch buckets for tuning keys AND for the predicted-vs-measured crossover
# comparison in benchmarks/kernel_autotune.py. Geometric (x4) so a roofline
# estimate and a wall-clock measurement of the same machine land in the same
# bucket even when they disagree by up to ~2x.
BATCH_BUCKETS = (1, 8, 32, 128, 512, 2048)

# v2: profiles record the TWO-POINT gather calibration
# (gather_flops_per_s_large + the calibration batches) — see
# plan.HardwareProfile.measure; v1 single-rate entries are discarded
_CACHE_VERSION = 2
_STATE: dict = {"path": None, "data": None}


def batch_bucket(b: int) -> int:
    """Smallest bucket >= b; above the table the geometric x4 progression
    continues unbounded. The bucket is a CEILING by contract — plans are
    priced, kernels tuned and (since the continuous-batching engine) slabs
    padded at the bucket, so silently clamping an oversized batch DOWN
    would price/tune/pad it at a bucket smaller than its real shape."""
    for v in BATCH_BUCKETS:
        if b <= v:
            return v
    v = BATCH_BUCKETS[-1]
    while v < b:
        v *= 4
    return v


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _load() -> dict:
    path = cache_path()
    if _STATE["data"] is None or _STATE["path"] != path:
        data = {"version": _CACHE_VERSION, "kernels": {}, "profiles": {}}
        try:
            with open(path) as f:
                on_disk = json.load(f)
            if on_disk.get("version") == _CACHE_VERSION:
                data.update(on_disk)
        except (OSError, ValueError):
            pass
        _STATE["path"], _STATE["data"] = path, data
    return _STATE["data"]


def _save() -> None:
    path = _STATE["path"] or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(_STATE["data"], f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def reset_cache_state() -> None:
    """Drop the in-memory cache view (tests repoint $REPRO_AUTOTUNE_CACHE)."""
    _STATE["path"] = _STATE["data"] = None


def kernel_key(d_in: int, n_out: int, k: int, batch: int, *,
               backend: str | None = None, itemsize: int = 4) -> str:
    """Cache key for one kernel dispatch shape. The canonical definition
    lives with the formats (``formats.shape_tuning_key`` — every consumer
    derives keys from the format protocol's ``tuning_key``); this delegate
    keeps the long-standing autotune-level name working."""
    from repro.sparse import formats as F  # lazy: formats imports this module
    return F.shape_tuning_key(d_in, n_out, k, batch, backend=backend,
                              itemsize=itemsize)


class TuneResult(typing.NamedTuple):
    key: str
    block_b: int | None      # None -> decode-specialized variant
    block_n: int
    us: float                # median us of the winner
    default_us: float        # median us of the untimed default blocks (the
    #                          legacy 128x128 general kernel for condensed)
    interpret: bool
    table: dict[str, float]  # candidate label -> median us

    @property
    def speedup_vs_default(self) -> float:
        return self.default_us / max(self.us, 1e-12)


def lookup_entry(key: str | None) -> dict | None:
    """Cached winner under a ``tuning_key``-derived cache key, or None
    (read-only, never times). ``None`` keys — formats with no tunable
    kernel — always miss. Returns ``{"block_b": int | None, "block_n":
    int}``; ``block_b=None`` means the decode-specialized variant won."""
    if key is None:
        return None
    entry = _load()["kernels"].get(key)
    if not entry:
        return None
    return {"block_b": entry["block_b"], "block_n": entry["block_n"]}


def lookup_blocks(batch: int, d_in: int, n_out: int, k: int, *,
                  backend: str | None = None,
                  itemsize: int = 4) -> dict | None:
    """Shape-level convenience over ``lookup_entry`` (same key derivation)."""
    return lookup_entry(kernel_key(d_in, n_out, k, batch, backend=backend,
                                   itemsize=itemsize))


def store_profile(rates: dict, *, backend: str | None = None) -> None:
    backend = backend or jax.default_backend()
    _load()["profiles"][backend] = dict(rates)
    _save()


def cached_profile(backend: str | None = None) -> dict | None:
    return _load()["profiles"].get(backend or jax.default_backend())


# ---------------------------------------------------------------------------
# timed search
# ---------------------------------------------------------------------------


def _time_us(fn, *args, reps: int = 3, agg=min) -> float:
    """Aggregated wall time in us over ``reps`` runs (after a compile/warmup
    pass).

    Default min, not median: on a shared/noisy host the minimum is the
    standard robust estimator of a COMPUTE kernel's intrinsic cost —
    interference only ever ADDS time, so the smallest observation is the
    least-contaminated one. Pass a different ``agg`` (e.g. median) for
    bandwidth measurements, where the fast tail is a cache-residency burst
    rather than the steady-state rate.
    """
    jax.block_until_ready(fn(*args))  # compile + warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return agg(ts) * 1e6


def _label(block_b: int | None, block_n: int) -> str:
    return f"decode x{block_n}" if block_b is None else f"{block_b}x{block_n}"


def _quantized_operands(vals_f32, values_dtype: str | None):
    """(values, scales) timing operands: quantize the f32 representative
    values when a quantized ``values_dtype`` is requested (the timed kernel
    must be the dequant-fused one the serving dispatch will run)."""
    from repro.sparse import formats as F  # lazy: formats imports this module
    vd = F.resolve_quantize_spec(values_dtype)
    if vd not in F.QUANTIZED_DTYPES:
        return vals_f32, None
    return F.quantize_values(vals_f32, vd)


def autotune_blocks(batch: int, d_in: int, n_out: int, k: int, *,
                    dtype=jnp.float32, reps: int = 3, seed: int = 0,
                    backend: str | None = None, interpret: bool | None = None,
                    values_dtype: str | None = None,
                    save: bool = True) -> TuneResult:
    """Timed search over candidate block shapes for one (shape, batch bucket).

    The representative batch is the BUCKET size (an entry must be no worse
    than default for every batch it serves, and the bucket top is the
    hardest). Candidates: every VMEM-budget (block_b, block_n) from the
    kernel module, the decode-specialized variant when the bucket is small,
    and always the legacy 128x128 general-kernel default as the baseline —
    so the winner is never slower than the default on the measured table.
    ``values_dtype`` ("int8"/"fp8") times the dequant-fused quantized kernel
    on quantized operands and records the entry under the quantized key.
    """
    b = batch_bucket(batch)
    itemsize = jnp.dtype(dtype).itemsize
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, d_in), jnp.float32).astype(dtype)
    vals = jax.random.normal(jax.random.fold_in(key, 1), (n_out, k),
                             jnp.float32).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(key, 2), (n_out, k), 0, d_in)
    vals, scales = _quantized_operands(vals, values_dtype)
    if interpret is None:
        interpret = cm.default_interpret(backend)

    cands: list[tuple[int | None, int]] = [(128, 128)]  # legacy default first
    cands += [c for c in cm.block_candidates(b, d_in, n_out, k,
                                             backend=backend)
              if c not in cands]
    if b <= cm.SMALL_BATCH_MAX:
        seen_n = {bn for _, bn in cands}
        cands += [(None, bn) for bn in sorted(seen_n)]

    table: dict[str, float] = {}
    for bb, bn in cands:
        if bb is None:
            fn = lambda x, v, i, bn=bn: cm.condensed_matmul_decode(
                x, v, i, scales=scales, block_n=bn, interpret=interpret)
        else:
            fn = lambda x, v, i, bb=bb, bn=bn: cm.condensed_matmul(
                x, v, i, scales=scales, block_b=bb, block_n=bn,
                interpret=interpret)
        table[_label(bb, bn)] = _time_us(fn, x, vals, idx, reps=reps)

    from repro.sparse import formats as F
    return _finish_result(
        F.shape_tuning_key(d_in, n_out, k, b, backend=backend,
                           itemsize=itemsize, values_dtype=values_dtype),
        cands, table, default_label=_label(128, 128), interpret=interpret,
        save=save)


def _finish_result(key: str, cands, table: dict[str, float], *,
                   default_label: str, interpret: bool,
                   save: bool) -> TuneResult:
    """Pick the table's argmin, package the TuneResult, persist the entry.
    The winner is the argmin of the SAME measured table the default sits in,
    so ``speedup_vs_default >= 1.0`` holds by construction."""
    best_label = min(table, key=table.get)
    best = dict(zip((_label(bb, bn) for bb, bn in cands), cands))[best_label]
    res = TuneResult(
        key=key, block_b=best[0], block_n=best[1], us=table[best_label],
        default_us=table[default_label], interpret=interpret, table=table)
    if save:
        _load()["kernels"][res.key] = {
            "block_b": res.block_b, "block_n": res.block_n,
            "us": round(res.us, 3), "default_us": round(res.default_us, 3),
            "interpret": interpret,
            "table": {k_: round(v, 3) for k_, v in table.items()},
        }
        _save()
    return res


def _sorted_active_index(key, a: int, d_out: int) -> jax.Array:
    """Representative surviving-column vector: a random size-``min(a, d_out)``
    subset in increasing order, padded to ``a`` with the d_out sentinel."""
    a_real = min(a, d_out)
    ai = jnp.sort(jax.random.permutation(key, d_out)[:a_real]).astype(jnp.int32)
    return jnp.pad(ai, (0, a - a_real), constant_values=d_out)


def autotune_structured_blocks(batch: int, d_in: int, a: int, d_out: int, *,
                               dtype=jnp.float32, reps: int = 3, seed: int = 0,
                               backend: str | None = None,
                               interpret: bool | None = None,
                               values_dtype: str | None = None,
                               save: bool = True) -> TuneResult:
    """Timed block search for the column-gathered structured kernel at one
    (shape, batch bucket). ``a`` is the padded active-column count the
    exported ``active_index`` carries; the baseline is the untimed
    VMEM-budget default (``structured_matmul.default_structured_blocks``).
    ``values_dtype`` only tags the cache key (quantized StructuredFanIn
    dequantizes its panel in XLA, so the kernel timing is dtype-invariant —
    but the key must match what the quantized format's ``tuning_key``
    derives)."""
    from repro.sparse import formats as F  # lazy: formats imports this module
    b = batch_bucket(batch)
    itemsize = jnp.dtype(dtype).itemsize
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, d_in), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (d_in, d_out),
                          jnp.float32).astype(dtype)
    ai = _sorted_active_index(jax.random.fold_in(key, 2), a, d_out)
    if interpret is None:
        interpret = cm.default_interpret(backend)

    default = sm.default_structured_blocks(b, d_in, a, d_out, backend=backend)
    cands: list[tuple[int | None, int]] = [default]
    cands += [c for c in sm.structured_block_candidates(b, d_in, a, d_out,
                                                        backend=backend)
              if c not in cands]
    if b <= cm.SMALL_BATCH_MAX:
        cands += [(None, bn) for bn in sorted({bn for _, bn in cands})]

    table: dict[str, float] = {}
    for bb, bn in cands:
        if bb is None:
            fn = lambda x, w, ai, bn=bn: sm.structured_matmul_decode(
                x, w, ai, block_n=bn, interpret=interpret)
        else:
            fn = lambda x, w, ai, bb=bb, bn=bn: sm.structured_matmul(
                x, w, ai, block_b=bb, block_n=bn, interpret=interpret)
        table[_label(bb, bn)] = _time_us(fn, x, w, ai, reps=reps)

    return _finish_result(
        F.shape_tuning_key(d_in, a, 0, b, backend=backend, itemsize=itemsize,
                           kind="structured", scatter_width=d_out,
                           values_dtype=values_dtype),
        cands, table, default_label=_label(*default), interpret=interpret,
        save=save)


def autotune_coa_blocks(batch: int, d_in: int, a: int, k: int, d_out: int, *,
                        dtype=jnp.float32, reps: int = 3, seed: int = 0,
                        backend: str | None = None,
                        interpret: bool | None = None,
                        values_dtype: str | None = None,
                        save: bool = True) -> TuneResult:
    """Timed block search for the FUSED condensed-over-active kernel at one
    (shape, batch bucket): ``a`` surviving rows of fan-in ``k``, scattered
    into a ``d_out``-wide output block in-kernel. ``values_dtype``
    ("int8"/"fp8") times the dequant-fused variant under the quantized key."""
    from repro.sparse import formats as F  # lazy: formats imports this module
    b = batch_bucket(batch)
    itemsize = jnp.dtype(dtype).itemsize
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, d_in), jnp.float32).astype(dtype)
    vals = jax.random.normal(jax.random.fold_in(key, 1), (a, k),
                             jnp.float32).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(key, 2), (a, k), 0, d_in)
    oi = _sorted_active_index(jax.random.fold_in(key, 3), a, d_out)
    vals, scales = _quantized_operands(vals, values_dtype)
    if interpret is None:
        interpret = cm.default_interpret(backend)

    default = sm.default_coa_blocks(b, d_in, a, k, d_out, backend=backend)
    cands: list[tuple[int | None, int]] = [default]
    cands += [c for c in sm.coa_block_candidates(b, d_in, a, k, d_out,
                                                 backend=backend)
              if c not in cands]
    if b <= cm.SMALL_BATCH_MAX:
        cands += [(None, bn) for bn in sorted({bn for _, bn in cands})]

    table: dict[str, float] = {}
    for bb, bn in cands:
        if bb is None:
            fn = lambda x, v, i, o, bn=bn: sm.condensed_over_active_matmul_decode(
                x, v, i, o, d_out, scales=scales, block_n=bn,
                interpret=interpret)
        else:
            fn = lambda x, v, i, o, bb=bb, bn=bn: sm.condensed_over_active_matmul(
                x, v, i, o, d_out, scales=scales, block_b=bb, block_n=bn,
                interpret=interpret)
        table[_label(bb, bn)] = _time_us(fn, x, vals, idx, oi, reps=reps)

    return _finish_result(
        F.shape_tuning_key(d_in, a, k, b, backend=backend, itemsize=itemsize,
                           kind="coa", scatter_width=d_out,
                           values_dtype=values_dtype),
        cands, table, default_label=_label(*default), interpret=interpret,
        save=save)


def tune_registry(registry, stats: dict, *, batch: int, dtype=jnp.float32,
                  reps: int = 3, backend: str | None = None,
                  values_dtype: str | None = None,
                  tp: int = 1) -> dict[str, TuneResult]:
    """Tune every DISTINCT kernel-dispatch shape among ``registry``'s stacks
    at their realized fan-in (``stats`` from condensed.export_stats).

    Cache keys are derived from the FORMAT protocol's ``spec_tuning_key``
    (the same derivation ``kernels.ops`` uses at trace time), and each key
    kind is tuned on the kernel that will consume it: plain ``Condensed``
    keys on the condensed gather over the full d_out rows; stacks with
    ablated neurons are ALSO tuned under ``CondensedOverActive``'s key on
    the FUSED scatter-epilogue kernel (its leaves carry (max_active, k)
    arrays scattered into the d_out-wide output); ablation-ONLY stacks
    (``min_fan_in == d_in``) additionally tune ``StructuredFanIn``'s key on
    the column-gathered structured kernel — the representation the auto
    plan can now pick for them. Already-cached shapes are skipped. Used by
    ``serve --autotune``. ``values_dtype`` ("int8"/"fp8") tunes the
    dequant-fused kernels on quantized operands under the quantized keys —
    the registry a quantized-serving engine consumes.

    ``tp > 1`` tunes at the PER-SHARD shapes a tensor-parallel engine
    dispatches (output width and active-row bound shrink by ``1/tp``; the
    keys come out of the same ``spec_tuning_key`` derivation the formats
    use, which folds ``tp`` in). Stacks whose ``d_out`` the shard count
    does not divide stay at their replicated shapes, matching the plan's
    per-stack fallback."""
    from repro.sparse import formats as F  # lazy: formats imports this module
    out: dict[str, TuneResult] = {}
    seen: set[str] = set()
    itemsize = jnp.dtype(dtype).itemsize
    vd = F.resolve_quantize_spec(values_dtype)
    tp = max(int(tp), 1)
    for s in registry:
        st = stats[s.name]
        tp_s = tp if s.d_out % tp == 0 else 1
        spec = F.spec_for_stack(s, st, itemsize, vd, tp=tp_s)
        a = spec.max_active
        n_loc = s.d_out // tp_s           # shard-local output width
        a_loc = -(-a // tp_s)             # shard-local active-row bound

        def tuners():
            yield (s.name, F.Condensed,
                   lambda: autotune_blocks(batch, s.d_in, n_loc, spec.k,
                                           dtype=dtype, reps=reps,
                                           backend=backend, values_dtype=vd))
            if a < s.d_out:
                yield (f"{s.name}@a{a}", F.CondensedOverActive,
                       lambda: autotune_coa_blocks(batch, s.d_in, a_loc,
                                                   spec.k, n_loc, dtype=dtype,
                                                   reps=reps, backend=backend,
                                                   values_dtype=vd))
                if st.min_fan_in >= s.d_in:
                    a_pad = sm.padded_active_count(a_loc, n_loc)
                    yield (f"{s.name}@structured",
                           F.StructuredFanIn,
                           lambda: autotune_structured_blocks(
                               batch, s.d_in, a_pad, n_loc, dtype=dtype,
                               reps=reps, backend=backend, values_dtype=vd))

        for label, cls, tune in tuners():
            key = cls.spec_tuning_key(spec, batch, backend=backend)
            if key in seen:
                continue
            seen.add(key)
            if lookup_entry(key) is None:
                out[label] = tune()
    return out
