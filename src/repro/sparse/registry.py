"""Registry of sparsifiable layers per architecture + pytree-level DST update.

The registry enumerates every sparse weight *stack* (a scanned group of
identically-shaped layers, e.g. ``("blocks", "w_gate")`` with leading dims
``(L,)`` or ``(L, E)`` for MoE experts). The ERK distribution is solved over
stacks; masks are initialized and updated with the leading dims vmapped so a
single jit covers all layers of a stack.

Paper-faithful defaults (DESIGN.md §5): MLP / attention-output / SSM in-out
projections are sparse; QKV input projections, router, norms, embeddings and
the final head stay dense (the paper's ViT recipe).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import distributions as D
from repro.core import rigl as R
from repro.core import set_sparse as SS
from repro.core import srigl as S
from repro.core import topology


@dataclasses.dataclass(frozen=True)
class SparseStack:
    path: tuple[str, ...]       # location in the params pytree
    d_in: int
    d_out: int
    lead: tuple[int, ...]       # leading (stack) dims, e.g. (L,) or (L, E)
    density: float = 1.0        # filled by ERK solve

    @property
    def n_replicas(self) -> int:
        return int(math.prod(self.lead)) if self.lead else 1

    @property
    def name(self) -> str:
        return "/".join(self.path)

    def srigl_spec(self, cfg) -> S.SRigLSpec:
        sp = cfg.sparsity
        return S.SRigLSpec(
            name=self.name, d_in=self.d_in, d_out=self.d_out,
            density=self.density, gamma_sal=sp.gamma_sal, ablation=sp.ablation)

    def rigl_spec(self) -> R.RigLSpec:
        return R.RigLSpec(name=self.name, d_in=self.d_in, d_out=self.d_out,
                          density=self.density)


# ---------------------------------------------------------------------------
# enumeration
# ---------------------------------------------------------------------------

def _attn_stacks(cfg, prefix: tuple, lead: tuple, with_mlp=True) -> list[SparseStack]:
    d, qd, kvd, ff = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
    out = [SparseStack(prefix + ("wo",), qd, d, lead)]
    if cfg.sparsity.sparse_qkv:
        out += [
            SparseStack(prefix + ("wq",), d, qd, lead),
            SparseStack(prefix + ("wk",), d, kvd, lead),
            SparseStack(prefix + ("wv",), d, kvd, lead),
        ]
    if with_mlp and ff:
        out += [
            SparseStack(prefix + ("w_gate",), d, ff, lead),
            SparseStack(prefix + ("w_up",), d, ff, lead),
            SparseStack(prefix + ("w_down",), ff, d, lead),
        ]
    return out


def _moe_stacks(cfg, prefix: tuple, lead: tuple) -> list[SparseStack]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    out = _attn_stacks(cfg, prefix, lead, with_mlp=False)
    out += [
        SparseStack(prefix + ("w_gate",), d, ff, lead + (e,)),
        SparseStack(prefix + ("w_up",), d, ff, lead + (e,)),
        SparseStack(prefix + ("w_down",), ff, d, lead + (e,)),
    ]
    return out


def _ssm_stacks(cfg, prefix: tuple, lead: tuple) -> list[SparseStack]:
    d, di = cfg.d_model, cfg.d_inner
    return [
        SparseStack(prefix + ("in_z",), d, di, lead),
        SparseStack(prefix + ("in_x",), d, di, lead),
        SparseStack(prefix + ("out_proj",), di, d, lead),
    ]


def build_registry(cfg) -> list[SparseStack]:
    """All sparse stacks of ``cfg`` with ERK/uniform densities solved."""
    if cfg.sparsity.method == "dense":
        return []
    fam = cfg.family
    stacks: list[SparseStack] = []
    if fam in ("dense", "vlm", "audio", "vit") and not cfg.local_global_ratio:
        stacks = _attn_stacks(cfg, ("blocks",), (cfg.n_layers,))
    elif cfg.local_global_ratio:
        r = cfg.local_global_ratio
        g = cfg.n_layers // (r + 1)
        rem = cfg.n_layers - g * (r + 1)
        stacks = _attn_stacks(cfg, ("g_local",), (g, r))
        stacks += _attn_stacks(cfg, ("g_global",), (g,))
        if rem:
            stacks += _attn_stacks(cfg, ("g_rem",), (rem,))
    elif fam == "moe":
        stacks = _moe_stacks(cfg, ("blocks",), (cfg.n_layers,))
    elif fam == "ssm":
        stacks = _ssm_stacks(cfg, ("blocks",), (cfg.n_layers,))
    elif fam == "hybrid":
        r = cfg.hybrid_attn_every
        g = cfg.n_layers // r
        rem = cfg.n_layers - g * r
        stacks = _ssm_stacks(cfg, ("m_groups",), (g, r))
        if rem:
            stacks += _ssm_stacks(cfg, ("m_rem",), (rem,))
        stacks += _attn_stacks(cfg, ("shared_attn",), ())
    else:
        raise ValueError(fam)

    # solve the per-stack densities
    shapes = [D.LayerShape(s.name, s.d_in, s.d_out, s.n_replicas) for s in stacks]
    solver = D.erk_densities if cfg.sparsity.distribution == "erk" else D.uniform_densities
    dens = solver(shapes, cfg.sparsity.sparsity)
    return [dataclasses.replace(s, density=dens[s.name]) for s in stacks]


def k_fan_map(cfg, registry: Sequence[SparseStack]) -> dict[str, int]:
    """layer-name -> constant fan-in (for init scaling). Last path element keys."""
    return {s.path[-1]: D.fan_in_from_density(s.d_in, s.density) for s in registry}


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def set_path(tree: dict, path: tuple, leaf) -> None:
    node = tree
    for p in path[:-1]:
        node = node.setdefault(p, {})
    node[path[-1]] = leaf


# pre-formats-API name; the serving/plan/export modules now use set_path
_set_path = set_path


def get_path(tree: dict, path: tuple):
    node = tree
    for p in path:
        node = node[p]
    return node


# ---------------------------------------------------------------------------
# state init + update
# ---------------------------------------------------------------------------

def _vmap_over_lead(fn, n_lead: int):
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    return fn


def _map_over_lead(fn, n_lead: int, constraint=None):
    """Sequential lax.map over the FIRST leading axis (layers), vmap the rest.

    Keeps topology-update temp memory at one layer-slab instead of the whole
    stack (a 123B-arch stack would not fit HBM). ``constraint`` optionally
    re-shards each slab for the update (row-parallel weights have their fan-in
    axis TP-sharded in storage, but the per-column selection sorts along
    fan-in — constraining the slab to neuron-sharded layout keeps the sort
    shard-local; see DESIGN.md §3).
    """
    inner = _vmap_over_lead(fn, max(n_lead - 1, 0))

    def constrained(*args):
        if constraint is not None:
            nd = len(constraint)
            out = []
            for a in args:
                if hasattr(a, "ndim") and a.ndim == nd:        # weight-like
                    a = jax.lax.with_sharding_constraint(a, constraint)
                elif hasattr(a, "ndim") and a.ndim == nd - 1:  # neuron-like
                    from jax.sharding import PartitionSpec as P
                    a = jax.lax.with_sharding_constraint(
                        a, P(*constraint[:-2], constraint[-1]))
                out.append(a)
            args = tuple(out)
        return inner(*args)

    if n_lead == 0:
        return constrained
    return lambda *args: jax.lax.map(lambda xs: constrained(*xs), args)


def init_sparsity_state(cfg, key: jax.Array, registry: Sequence[SparseStack]) -> dict:
    """Returns {"masks": pytree, "neuron_active": pytree} (paths mirror params)."""
    masks: dict = {}
    active: dict = {}
    method = cfg.sparsity.method
    keys = jax.random.split(key, max(len(registry), 1))
    for s, k in zip(registry, keys):
        if method in ("srigl",):
            kk = D.fan_in_from_density(s.d_in, s.density)
            init = lambda key_: topology.random_constant_fan_in_mask(key_, s.d_in, s.d_out, kk)
        else:  # rigl / set: unstructured
            nnz = max(1, round(s.density * s.d_in * s.d_out))
            init = lambda key_: topology.random_unstructured_mask(key_, s.d_in, s.d_out, nnz)
        lead_keys = jax.random.split(k, max(s.n_replicas, 1)).reshape(*(s.lead or (1,)), 2)
        mask = _vmap_over_lead(init, max(len(s.lead), 1))(lead_keys)
        if not s.lead:
            mask = mask[0] if mask.ndim == 3 else mask
        _set_path(masks, s.path, mask.reshape(*s.lead, s.d_in, s.d_out))
        _set_path(active, s.path, jnp.ones((*s.lead, s.d_out), bool))
    return {"masks": masks, "neuron_active": active}


def dst_update(cfg, registry: Sequence[SparseStack], params: dict, grads: dict,
               state: dict, drop_fraction, rng: jax.Array,
               compute_specs: dict | None = None):
    """One topology update across every sparse stack. Pure/jit-able.

    Run as its OWN program every delta_t steps (not fused into train_step):
    the selection temporaries then never contribute to the hot path's peak
    memory, and lax.map over the layer axis bounds them to one layer-slab.
    ``compute_specs`` optionally maps stack-name -> PartitionSpec for the
    per-layer slab (see _map_over_lead).

    Returns (new_state, stats dict keyed by stack name).
    """
    method = cfg.sparsity.method
    compute_specs = compute_specs or {}
    new_masks, new_active, stats = {}, {}, {}
    rngs = jax.random.split(rng, max(len(registry), 1))
    for s, key in zip(registry, rngs):
        w = get_path(params, s.path)
        g = get_path(grads, s.path)
        m = get_path(state["masks"], s.path)
        a = get_path(state["neuron_active"], s.path)
        nl = len(s.lead)
        cspec = compute_specs.get(s.name)

        if method == "srigl":
            spec = s.srigl_spec(cfg)
            # f32 casts happen per-slab INSIDE the layer map: casting the
            # whole stacked tensor up front would materialize a full f32
            # copy of the (possibly 100B+-param) stack
            fn = lambda w_, g_, m_, a_: S.srigl_update(
                spec, w_.astype(jnp.float32), g_.astype(jnp.float32),
                S.LayerState(m_, a_), drop_fraction)
            fn = _map_over_lead(fn, nl, cspec)
            st, sts = fn(w, g, m, a)
            _set_path(new_masks, s.path, st.mask)
            _set_path(new_active, s.path, st.neuron_active)
            stats[s.name] = {k: v for k, v in sts._asdict().items()}
        elif method == "rigl":
            spec = s.rigl_spec()
            fn = lambda w_, g_, m_: R.rigl_update(spec, w_, g_, R.RigLState(m_), drop_fraction)
            fn = _vmap_over_lead(fn, nl)
            st, sts = fn(w.astype(jnp.float32), g.astype(jnp.float32), m)
            _set_path(new_masks, s.path, st.mask)
            _set_path(new_active, s.path, a)
            stats[s.name] = sts
        elif method == "set":
            spec = s.rigl_spec()
            lead_keys = jax.random.split(key, max(s.n_replicas, 1)).reshape(*(s.lead or (1,)), 2)
            if not s.lead:
                lead_keys = lead_keys[0]
            fn = lambda w_, k_, m_: SS.set_update(spec, w_, k_, R.RigLState(m_), drop_fraction)
            fn = _vmap_over_lead(fn, nl)
            st, sts = fn(w.astype(jnp.float32), lead_keys, m)
            _set_path(new_masks, s.path, st.mask)
            _set_path(new_active, s.path, a)
            stats[s.name] = sts
        else:
            raise ValueError(method)
    return {"masks": new_masks, "neuron_active": new_active}, stats


def init_itop(registry: Sequence[SparseStack], state: dict) -> dict:
    """In-Time Overparameterization tracker (Liu et al. 2021c; paper App. H):
    the union of all masks seen so far — ITOP rate = |union| / |weights|."""
    return jax.tree.map(lambda m: m, state["masks"])


def update_itop(itop: dict, masks: dict) -> dict:
    return jax.tree.map(lambda u, m: u | m, itop, masks)


def itop_rate(registry: Sequence[SparseStack], itop: dict) -> dict:
    return {s.name: float(jnp.mean(get_path(itop, s.path).astype(jnp.float32)))
            for s in registry}


def sparsity_summary(registry: Sequence[SparseStack], state: dict) -> dict:
    """Host-side summary: realized sparsity + ablation fraction per stack."""
    out = {}
    for s in registry:
        m = get_path(state["masks"], s.path)
        a = get_path(state["neuron_active"], s.path)
        out[s.name] = {
            "density": float(jnp.mean(m.astype(jnp.float32))),
            "target_density": s.density,
            "active_neurons": float(jnp.mean(a.astype(jnp.float32))),
        }
    return out
