"""Unified decoder-LM model zoo (dense / MoE / SSM / hybrid / VLM / audio / ViT)."""
