"""Unified decoder LM covering all assigned families.

One functional model with per-family block stacks:

  dense   — [attn + SwiGLU MLP] x L, scanned          (mistral, qwen3, internlm2,
            qwen2-vl backbone (M-RoPE), musicgen backbone (multi-codebook))
  gemma   — grouped scan: (5 local + 1 global) x G + remainder local layers,
            ring-buffer caches for local layers
  moe     — [attn + top-k MoE] x L, scanned            (granite, kimi)
  ssm     — [Mamba2/SSD mixer] x L, scanned            (mamba2-130m)
  hybrid  — groups of R Mamba2 blocks + one *shared* attention+MLP block
            applied after each group (zamba2)
  vit     — encoder-only (non-causal) [attn + MLP] x L, class head (paper arch)

All layer stacks are ``lax.scan``-ed (stacked params) so HLO size and compile
time stay O(1) in depth — essential for the 512-device dry-runs. Sparse layers
receive boolean masks (same pytree layout as the stacked weights) and use the
straight-through masked matmul from repro.models.layers.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = dict
Masks = dict


def _mesh_ok():
    """Abstract mesh of the current trace, or None (via the compat shim —
    jax.sharding.get_abstract_mesh only exists on newer JAX)."""
    return compat.get_abstract_mesh()


def shard_hint(x: jax.Array, *spec):
    """with_sharding_constraint iff tracing under a mesh with these axes and
    every constrained dim is divisible by its axis product (no-op on CPU
    tests / decode T=1 / odd shapes)."""
    mesh = _mesh_ok()
    if mesh is None:
        return x
    names = mesh.axis_names
    for dim, a in zip(x.shape, spec):
        if a is None:
            continue
        axes = a if isinstance(a, tuple) else (a,)
        n = 1
        for ax in axes:
            if ax not in names:
                return x
            n *= mesh.shape[ax]
        if n == 0 or dim % n:
            return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# ZeRO-3 / Megatron-SP compute-layout hints
# ---------------------------------------------------------------------------
# When cfg.fsdp is on, weights are STORED with their non-TP dim sharded over
# 'data'. GSPMD, left alone, may resolve the (data-sharded weight x
# data-sharded batch) contraction by replicating the *batch* — catastrophic
# for activation memory (observed: kimi attention tensors at full
# global-batch). ZeRO-3 semantics require the WEIGHT to be all-gathered at
# use instead; we pin that choice by constraining each weight slab to its
# TP-only layout inside the layer scans. Masks follow their weights.

_COL_TP = {"wq": "attn", "wk": "kv", "wv": "kv", "w_gate": "ff", "w_up": "ff",
           "in_z": "ssm", "in_x": "ssm"}
_ROW_TP = {"wo": "attn", "w_down": "ff", "out_proj": "ssm"}


def _tp_ok(cfg, kind: str, tp: int) -> bool:
    return {
        "attn": cfg.n_heads_padded % tp == 0,
        "kv": cfg.n_kv_heads_padded % tp == 0,
        "ff": bool(cfg.d_ff) and cfg.d_ff % tp == 0,
        "ssm": cfg.ssm_state > 0 and cfg.ssm_n_heads % tp == 0,
    }[kind]


def gather_weights(cfg, tree: dict) -> dict:
    """Constrain weight/mask slabs to TP-only sharding (fsdp axis gathered)."""
    mesh = _mesh_ok()
    if mesh is None or "model" not in mesh.axis_names or not cfg.fsdp:
        return tree
    tp = mesh.shape["model"]
    from jax.sharding import PartitionSpec as P

    def spec_for(name, ndim):
        is_expert = cfg.is_moe and name in ("w_gate", "w_up", "w_down")
        if is_expert:  # slab (E, d, ff): E over model, rest gathered
            ep = "model" if cfg.n_experts % tp == 0 else None
            return P(*([None] * (ndim - 3) + [ep, None, None]))
        if name in _COL_TP:
            t = "model" if _tp_ok(cfg, _COL_TP[name], tp) else None
            return P(*([None] * (ndim - 2) + [None, t]))
        if name in _ROW_TP:
            t = "model" if _tp_ok(cfg, _ROW_TP[name], tp) else None
            return P(*([None] * (ndim - 2) + [t, None]))
        return None

    out = {}
    for k, v in tree.items():
        sp = spec_for(k, getattr(v, "ndim", 0)) if hasattr(v, "ndim") else None
        out[k] = jax.lax.with_sharding_constraint(v, sp) if sp is not None else v
    return out


def _any_tp(cfg) -> bool:
    """Does this arch use the 'model' axis for tensor parallelism at all?
    (pure-DP archs carry batch on 'model'; vocab hints must not steal it)"""
    mesh = _mesh_ok()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    tp = mesh.shape["model"]
    kinds = any(_tp_ok(cfg, k, tp) for k in ("attn", "kv", "ff", "ssm"))
    return kinds or (cfg.is_moe and cfg.n_experts % tp == 0)


def vocab_hint(cfg, head: jax.Array) -> jax.Array:
    """Shard the LM head's vocab dim over 'model' (TP archs only)."""
    if not _any_tp(cfg):
        return head
    return shard_hint(head, *([None] * (head.ndim - 1) + ["model"]))


def seq_shard(cfg, x: jax.Array) -> jax.Array:
    """Megatron-SP: residual stream (B, T, d) sharded over 'model' on T at
    block boundaries — remat-saved activations shrink by the TP degree; the
    partitioner inserts the all-gather/reduce-scatter pair around attention
    and MLP (same bytes as the classic per-block all-reduces)."""
    if cfg.family in ("ssm", "hybrid"):  # SSD scans need the full sequence
        return x
    if x.ndim != 3 or x.shape[1] < 2:
        return x
    return shard_hint(x, None, "model", None)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# init
# ===========================================================================

def _init_attn_block(key, cfg, dtype, k_fan: dict, with_mlp: bool = True) -> dict:
    ks = jax.random.split(key, 8)
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim

    def maybe_sparse(k, a, b, name):
        fan = k_fan.get(name)
        return L.sparse_init(k, a, b, fan, dtype) if fan else L.dense_init(k, a, b, dtype)

    p = {
        "ln1": jnp.zeros((d,), dtype),
        "wq": maybe_sparse(ks[0], d, qd, "wq"),
        "wk": maybe_sparse(ks[1], d, kvd, "wk"),
        "wv": maybe_sparse(ks[2], d, kvd, "wv"),
        "wo": maybe_sparse(ks[3], qd, d, "wo"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    if with_mlp:
        p["ln2"] = jnp.zeros((d,), dtype)
        p["w_gate"] = maybe_sparse(ks[4], d, cfg.d_ff, "w_gate")
        p["w_up"] = maybe_sparse(ks[5], d, cfg.d_ff, "w_up")
        p["w_down"] = maybe_sparse(ks[6], cfg.d_ff, d, "w_down")
    return p


def _init_moe_block(key, cfg, dtype, k_fan: dict) -> dict:
    k1, k2 = jax.random.split(key)
    p = _init_attn_block(k1, cfg, dtype, k_fan, with_mlp=False)
    p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    moe = MOE.init_moe_params(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                              {k: v for k, v in k_fan.items() if v}, dtype)
    p.update(moe._asdict())
    return p


def _init_ssm_block(key, cfg, dtype, k_fan: dict) -> dict:
    p = SSM.init_ssm_params(key, cfg, dtype, k_fan)._asdict()
    p["ln"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _stack(init_fn, key, n: int):
    """Initialize ``n`` blocks with independent keys, stacked on axis 0."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg, key: jax.Array, k_fan: dict | None = None) -> Params:
    """Initialize the full parameter pytree for ``cfg``.

    ``k_fan`` maps sparse layer names (wq/wo/w_gate/... ) to their constant
    fan-in k so sparse layers get 1/sqrt(k)-scaled init (Evci et al. 2022);
    produced by repro.sparse.registry.
    """
    k_fan = k_fan or {}
    dtype = _pdt(cfg)
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    params: Params = {"final_norm": jnp.zeros((d,), dtype)}

    # --- embeddings / heads -------------------------------------------------
    vp = cfg.vocab_padded
    if cfg.family == "audio":
        params["embed"] = jax.vmap(lambda k: L.embed_init(k, vp, d, dtype))(
            jax.random.split(keys[0], cfg.n_codebooks))
        params["lm_head"] = jax.vmap(lambda k: L.dense_init(k, d, vp, dtype))(
            jax.random.split(keys[1], cfg.n_codebooks))
    elif cfg.family == "vit":
        params["embed"] = L.embed_init(keys[0], 1, d, dtype)  # CLS token
        params["lm_head"] = L.dense_init(keys[1], d, cfg.n_classes, dtype)
    else:
        params["embed"] = L.embed_init(keys[0], vp, d, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(keys[1], d, vp, dtype)

    # --- block stacks -------------------------------------------------------
    if cfg.family in ("dense", "vlm", "audio", "vit"):
        if cfg.local_global_ratio:  # gemma3 grouped layout
            r = cfg.local_global_ratio
            n_groups = cfg.n_layers // (r + 1)
            rem = cfg.n_layers - n_groups * (r + 1)
            init = lambda k: _init_attn_block(k, cfg, dtype, k_fan)
            params["g_local"] = jax.vmap(lambda ks: jax.vmap(init)(ks))(
                jax.random.split(keys[2], n_groups * r).reshape(n_groups, r, 2))
            params["g_global"] = _stack(init, keys[3], n_groups)
            if rem:
                params["g_rem"] = _stack(init, keys[4], rem)
        else:
            params["blocks"] = _stack(
                lambda k: _init_attn_block(k, cfg, dtype, k_fan), keys[2], cfg.n_layers)
    elif cfg.family == "moe":
        params["blocks"] = _stack(
            lambda k: _init_moe_block(k, cfg, dtype, k_fan), keys[2], cfg.n_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stack(
            lambda k: _init_ssm_block(k, cfg, dtype, k_fan), keys[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        r = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // r
        rem = cfg.n_layers - n_groups * r
        init = lambda k: _init_ssm_block(k, cfg, dtype, k_fan)
        params["m_groups"] = jax.vmap(lambda ks: jax.vmap(init)(ks))(
            jax.random.split(keys[2], n_groups * r).reshape(n_groups, r, 2))
        if rem:
            params["m_rem"] = _stack(init, keys[4], rem)
        params["shared_attn"] = _init_attn_block(keys[3], cfg, dtype, k_fan)
    else:
        raise ValueError(cfg.family)
    return params


# ===========================================================================
# sublayer applies
# ===========================================================================

def _heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def attn_sublayer(cfg, p: dict, m: dict, x: jax.Array, *,
                  positions, window: int, q_offset: int = 0,
                  cache: tuple | None = None, decode: bool = False,
                  paged: tuple | None = None):
    """Pre-norm attention sublayer (residual added by caller).

    cache: (k_cache, v_cache, cache_len) for decode / prefill-write.
    paged: (k_pool, v_pool, block_table, lengths) — one layer's paged KV
    pool slice instead of a contiguous cache (``supports_paged`` families
    only; window must be 0). Prefill writes positions [0, T) through the
    table; decode writes one token per stream at its own length.
    Returns (out, new_cache_kv or None).
    """
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = _heads(L.linear(h, p["wq"], m.get("wq")), cfg.n_heads_padded, cfg.head_dim)
    k = _heads(L.linear(h, p["wk"], m.get("wk")), cfg.n_kv_heads_padded, cfg.head_dim)
    v = _heads(L.linear(h, p["wv"], m.get("wv")), cfg.n_kv_heads_padded, cfg.head_dim)

    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)

    if cfg.mrope:
        q = L.apply_mrope(q, positions, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.rope_theta)
    elif cfg.causal:  # ViT uses learned-free identity positions
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if paged is not None:
        k_pool, v_pool, block_table, lengths = paged
        if decode:
            t = k.shape[1]
            if t == 1:
                k_pool, v_pool = A.paged_cache_write(
                    k_pool, v_pool, k, v, block_table, lengths[:, None])
                attn = A.paged_decode_attention(q, k_pool, v_pool,
                                                block_table, lengths + 1,
                                                head_to_kv=cfg.head_to_kv)
            else:
                # speculative verify: T consecutive tokens per stream, token
                # i written at slot lengths[b] + i, each query attending its
                # own causal prefix (one batched dispatch instead of T)
                pos = lengths[:, None] + jnp.arange(t)[None]
                k_pool, v_pool = A.paged_cache_write(
                    k_pool, v_pool, k, v, block_table, pos)
                attn = A.paged_verify_attention(q, k_pool, v_pool,
                                                block_table, lengths,
                                                head_to_kv=cfg.head_to_kv)
        else:
            # prefill: attention over the in-flight k/v (chunked, causal —
            # right-padded rows' pads sit after every real token, so real
            # rows never attend them); the pool write covers all T slots,
            # pad slots hold garbage until decode overwrites them and are
            # masked by ``lengths`` meanwhile
            attn = A.chunked_attention(
                q, k, v, head_to_kv=cfg.head_to_kv, causal=cfg.causal,
                window=window, q_offset=q_offset, q_chunk=cfg.attn_q_chunk,
                kv_chunk=cfg.attn_kv_chunk)
            t = k.shape[1]
            pos = jnp.broadcast_to(jnp.arange(t)[None], (k.shape[0], t))
            k_pool, v_pool = A.paged_cache_write(k_pool, v_pool, k, v,
                                                 block_table, pos)
        new_cache = (k_pool, v_pool)
    elif decode:
        k_cache, v_cache, cache_len = cache
        k_cache, v_cache = A.cache_write(k_cache, v_cache, k, v, cache_len)
        attn = A.decode_attention(q, k_cache, v_cache, cache_len + 1,
                                  head_to_kv=cfg.head_to_kv, window=window)
        new_cache = (k_cache, v_cache)
    else:
        attn = A.chunked_attention(
            q, k, v, head_to_kv=cfg.head_to_kv, causal=cfg.causal, window=window,
            q_offset=q_offset, q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
        if cache is not None:  # prefill: fill the cache
            k_cache, v_cache, cache_len = cache
            k_cache, v_cache = A.cache_write(k_cache, v_cache, k, v, cache_len)
            new_cache = (k_cache, v_cache)

    if cfg.n_heads_padded != cfg.n_heads:  # zero padded heads (bit-exactness)
        head_mask = (jnp.arange(cfg.n_heads_padded) < cfg.n_heads)
        attn = attn * head_mask[None, None, :, None].astype(attn.dtype)
    out = L.linear(attn.reshape(*x.shape[:-1], cfg.q_dim), p["wo"], m.get("wo"))
    return out, new_cache


def mlp_sublayer(cfg, p: dict, m: dict, x: jax.Array):
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    gate = L.linear(h, p["w_gate"], m.get("w_gate"))
    up = L.linear(h, p["w_up"], m.get("w_up"))
    return L.linear(L.swiglu(gate, up), p["w_down"], m.get("w_down"))


def moe_sublayer(cfg, p: dict, m: dict, x: jax.Array):
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    moe_p = MOE.MoEParams(router=p["router"], w_gate=p["w_gate"],
                          w_up=p["w_up"], w_down=p["w_down"])
    y, aux = MOE.moe_block(cfg, moe_p, h, m, group_size=cfg.moe_group_size)
    return y, aux


def ssm_sublayer(cfg, p: dict, m: dict, x: jax.Array, *,
                 state=None, decode: bool = False):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    sp = SSM.SSMParams(**{f: p[f] for f in SSM.SSMParams._fields})
    y, new_state = SSM.ssm_block(cfg, sp, h, m, state=state,
                                 chunk=cfg.ssd_chunk, decode=decode)
    return y, new_state


# ===========================================================================
# full blocks (residual wiring) — used by the scans below
# ===========================================================================

def attn_mlp_block(cfg, p, m, x, *, positions, window, q_offset=0,
                   cache=None, decode=False, paged=None):
    p, m = gather_weights(cfg, p), gather_weights(cfg, m)
    a, new_cache = attn_sublayer(cfg, p, m, x, positions=positions, window=window,
                                 q_offset=q_offset, cache=cache, decode=decode,
                                 paged=paged)
    x = x + a
    x = x + mlp_sublayer(cfg, p, m, x)
    return seq_shard(cfg, x), new_cache


def attn_moe_block(cfg, p, m, x, *, positions, window, q_offset=0,
                   cache=None, decode=False, paged=None):
    p, m = gather_weights(cfg, p), gather_weights(cfg, m)
    a, new_cache = attn_sublayer(cfg, p, m, x, positions=positions, window=window,
                                 q_offset=q_offset, cache=cache, decode=decode,
                                 paged=paged)
    x = x + a
    y, aux = moe_sublayer(cfg, p, m, x)
    return seq_shard(cfg, x + y), new_cache, aux


def ssm_res_block(cfg, p, m, x, *, state=None, decode=False):
    p, m = gather_weights(cfg, p), gather_weights(cfg, m)
    y, new_state = ssm_sublayer(cfg, p, m, x, state=state, decode=decode)
    return x + y, new_state


# ===========================================================================
# forward (training / scoring): returns final hidden states
# ===========================================================================

def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat == "block" else fn


def backbone(cfg, params: Params, masks: Masks, x: jax.Array, *,
             positions) -> tuple[jax.Array, jax.Array]:
    """Run the block stacks. x: (B, T, d). Returns (hidden, aux_loss)."""
    masks = masks or {}
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "audio", "vit") and not cfg.local_global_ratio:
        mstack = masks.get("blocks", {})

        def body(carry, xs):
            h = carry
            p_i, m_i = xs
            h, _ = _maybe_remat(cfg, functools.partial(
                attn_mlp_block, cfg, positions=positions,
                window=cfg.sliding_window))(p_i, m_i, h)
            return h, None

        x, _ = jax.lax.scan(body, x, (params["blocks"], _expand_masks(mstack, cfg.n_layers)))

    elif cfg.local_global_ratio:  # gemma3

        def group_body(carry, xs):
            h = carry
            pl_g, ml_g, pg_g, mg_g = xs

            def local_body(hh, ys):
                p_i, m_i = ys
                hh, _ = _maybe_remat(cfg, functools.partial(
                    attn_mlp_block, cfg, positions=positions,
                    window=cfg.sliding_window))(p_i, m_i, hh)
                return hh, None

            h, _ = jax.lax.scan(local_body, h, (pl_g, ml_g))
            h, _ = _maybe_remat(cfg, functools.partial(
                attn_mlp_block, cfg, positions=positions, window=0))(pg_g, mg_g, h)
            return h, None

        x, _ = jax.lax.scan(
            group_body, x,
            (params["g_local"], _expand_masks(masks.get("g_local", {}), None),
             params["g_global"], _expand_masks(masks.get("g_global", {}), None)))
        if "g_rem" in params:
            def rem_body(carry, xs):
                p_i, m_i = xs
                h, _ = _maybe_remat(cfg, functools.partial(
                    attn_mlp_block, cfg, positions=positions,
                    window=cfg.sliding_window))(p_i, m_i, carry)
                return h, None
            x, _ = jax.lax.scan(rem_body, x,
                                (params["g_rem"], _expand_masks(masks.get("g_rem", {}), None)))

    elif cfg.family == "moe":
        def body(carry, xs):
            h, aux = carry
            p_i, m_i = xs
            h, _, a = _maybe_remat(cfg, functools.partial(
                attn_moe_block, cfg, positions=positions,
                window=cfg.sliding_window))(p_i, m_i, h)
            return (h, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total),
            (params["blocks"], _expand_masks(masks.get("blocks", {}), cfg.n_layers)))

    elif cfg.family == "ssm":
        def body(carry, xs):
            p_i, m_i = xs
            h, _ = _maybe_remat(cfg, functools.partial(ssm_res_block, cfg))(p_i, m_i, carry)
            return h, None

        x, _ = jax.lax.scan(body, x,
                            (params["blocks"], _expand_masks(masks.get("blocks", {}), cfg.n_layers)))

    elif cfg.family == "hybrid":
        sh_p = params["shared_attn"]
        sh_m = masks.get("shared_attn", {})

        def group_body(carry, xs):
            h = carry
            p_g, m_g = xs

            def mamba_body(hh, ys):
                p_i, m_i = ys
                hh, _ = _maybe_remat(cfg, functools.partial(ssm_res_block, cfg))(p_i, m_i, hh)
                return hh, None

            h, _ = jax.lax.scan(mamba_body, h, (p_g, m_g))
            h, _ = _maybe_remat(cfg, functools.partial(
                attn_mlp_block, cfg, positions=positions,
                window=cfg.sliding_window))(sh_p, sh_m, h)
            return h, None

        x, _ = jax.lax.scan(group_body, x,
                            (params["m_groups"], _expand_masks(masks.get("m_groups", {}), None)))
        if "m_rem" in params:
            def rem_body(carry, xs):
                p_i, m_i = xs
                h, _ = _maybe_remat(cfg, functools.partial(ssm_res_block, cfg))(p_i, m_i, carry)
                return h, None
            x, _ = jax.lax.scan(rem_body, x,
                                (params["m_rem"], _expand_masks(masks.get("m_rem", {}), None)))
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def _expand_masks(mstack: dict, n_layers):
    """Masks pytree for scan xs — an empty dict scans fine (no leaves)."""
    return mstack


# ===========================================================================
# embedding / loss heads
# ===========================================================================

def embed_inputs(cfg, params: Params, batch: dict) -> tuple[jax.Array, Any]:
    """Token/frontend embedding. Returns (x (B,T,d), positions)."""
    dt = _dt(cfg)
    if cfg.family == "audio":
        # tokens: (B, K, T) — sum codebook embeddings (EnCodec frontend stub)
        toks = batch["tokens"]
        x = sum(params["embed"][k][toks[:, k]] for k in range(cfg.n_codebooks))
        bsz, t = toks.shape[0], toks.shape[2]
    elif cfg.family == "vit":
        x = batch["frontend_embeds"]  # precomputed patch embeddings (stub)
        bsz, t = x.shape[0], x.shape[1]
    else:
        toks = batch["tokens"]
        x = params["embed"][toks]
        if "frontend_embeds" in batch:  # VLM: add precomputed patch embeds
            x = x + batch["frontend_embeds"].astype(x.dtype)
        bsz, t = toks.shape
    x = x.astype(dt)

    if cfg.mrope:
        positions = batch.get("mrope_positions")
        if positions is None:
            p = jnp.broadcast_to(jnp.arange(t)[None], (bsz, t))
            positions = jnp.stack([p, p, p])
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (bsz, t))
    return x, positions


def cross_entropy_chunked(hidden: jax.Array, lm_head: jax.Array,
                          targets: jax.Array, chunk: int,
                          loss_mask: jax.Array | None = None,
                          valid_vocab: int = 0, cfg=None) -> jax.Array:
    """Mean token CE without materializing (B, T, V) logits.

    hidden: (B, T, d); lm_head: (d, V); targets: (B, T) int32.
    Scans over T chunks; each chunk computes (B, Tc, V) f32 logits.
    """
    b, t, d = hidden.shape
    chunk = min(chunk, t)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        lm = jnp.pad(loss_mask, ((0, 0), (0, pad))) if loss_mask is not None \
            else jnp.pad(jnp.ones((b, t), jnp.float32), ((0, 0), (0, pad)))
    else:
        lm = loss_mask if loss_mask is not None else jnp.ones((b, t), jnp.float32)

    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = lm.reshape(b, nc, chunk).transpose(1, 0, 2)

    # vocab-shard the head over the TP axis (tied embeddings arrive d-sharded;
    # without this the per-chunk logits would be replicated over 'model' and
    # the partial-sum all-reduce costs chunks x B x Tc x V f32 — the single
    # largest collective in the naive lowering)
    v_total = lm_head.shape[-1]
    if cfg is not None:
        lm_head = vocab_hint(cfg, lm_head)
    n_valid = valid_vocab if valid_vocab else v_total

    # remat the chunk body: without it the scan stacks every chunk's (B,Tc,V)
    # f32 logits as backward residuals — i.e. the full (B,T,V) logits tensor
    # this function exists to avoid (40 GB/device for a 152k vocab at 4k seq).
    @jax.checkpoint
    def step(carry, xs):
        tot, cnt = carry
        h_i, t_i, m_i = xs
        logits = (h_i @ lm_head.astype(h_i.dtype)).astype(jnp.float32)
        if n_valid != v_total:  # mask padded vocab columns
            logits = jnp.where(jnp.arange(v_total) < n_valid, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_i[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_i
        return (tot + jnp.sum(nll), cnt + jnp.sum(m_i)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg, params: Params, masks: Masks, batch: dict) -> tuple[jax.Array, dict]:
    """Training loss (next-token CE, or classification CE for ViT)."""
    x, positions = embed_inputs(cfg, params, batch)
    hidden, aux = backbone(cfg, params, masks, x, positions=positions)

    if cfg.family == "vit":
        pooled = jnp.mean(hidden, axis=1)
        logits = (pooled @ params["lm_head"].astype(pooled.dtype)).astype(jnp.float32)
        labels = batch["labels"]
        loss = jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, labels[:, None], -1)[:, 0])
    elif cfg.family == "audio":
        losses = [
            cross_entropy_chunked(hidden, params["lm_head"][k],
                                  batch["targets"][:, k], cfg.ce_chunk,
                                  valid_vocab=cfg.vocab_size, cfg=cfg)
            for k in range(cfg.n_codebooks)
        ]
        loss = sum(losses) / cfg.n_codebooks
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        loss = cross_entropy_chunked(hidden, head, batch["targets"], cfg.ce_chunk,
                                     batch.get("loss_mask"),
                                     valid_vocab=cfg.vocab_size, cfg=cfg)

    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ===========================================================================
# serving: KV / SSM caches + single-token decode
# ===========================================================================

def _attn_cache(cfg, n: int, bsz: int, s: int, dtype):
    hkv, hd = cfg.n_kv_heads_padded, cfg.head_dim
    shape = (n, bsz, s, hkv, hd) if n else (bsz, s, hkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _ssm_cache(cfg, n: int, bsz: int, dtype):
    w = cfg.ssm_conv_width - 1
    lead = (n,) if n else ()
    return {
        "conv_x": jnp.zeros((*lead, bsz, w, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((*lead, bsz, w, 2 * cfg.ssm_state), dtype),
        "h": jnp.zeros((*lead, bsz, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                       jnp.float32),
    }


def init_cache(cfg, bsz: int, max_len: int) -> dict:
    """Decode-state pytree for a batch of ``bsz`` streams of up to ``max_len``.

    Windowed (local) attention layers get ring buffers of size ``window``
    instead of ``max_len`` — for gemma3's 5:1 local:global pattern this cuts
    long-context cache memory by ~5x (the 500k cell relies on it).
    """
    dt = _dt(cfg)
    cache: dict = {"len": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "audio") and not cfg.local_global_ratio:
        s = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
        cache["blocks"] = _attn_cache(cfg, cfg.n_layers, bsz, s, dt)
    elif cfg.local_global_ratio:
        r = cfg.local_global_ratio
        g = cfg.n_layers // (r + 1)
        rem = cfg.n_layers - g * (r + 1)
        w = min(cfg.sliding_window, max_len)
        loc = _attn_cache(cfg, g * r, bsz, w, dt)
        cache["g_local"] = jax.tree.map(lambda a: a.reshape(g, r, *a.shape[1:]), loc)
        cache["g_global"] = _attn_cache(cfg, g, bsz, max_len, dt)
        if rem:
            cache["g_rem"] = _attn_cache(cfg, rem, bsz, w, dt)
    elif cfg.family == "moe":
        cache["blocks"] = _attn_cache(cfg, cfg.n_layers, bsz, max_len, dt)
    elif cfg.family == "ssm":
        cache["blocks"] = _ssm_cache(cfg, cfg.n_layers, bsz, dt)
    elif cfg.family == "hybrid":
        r = cfg.hybrid_attn_every
        g = cfg.n_layers // r
        rem = cfg.n_layers - g * r
        mg = _ssm_cache(cfg, g * r, bsz, dt)
        cache["m_groups"] = jax.tree.map(lambda a: a.reshape(g, r, *a.shape[1:]), mg)
        if rem:
            cache["m_rem"] = _ssm_cache(cfg, rem, bsz, dt)
        cache["shared_attn"] = _attn_cache(cfg, g, bsz, max_len, dt)
    return cache


# ---------------------------------------------------------------------------
# paged serving (continuous batching): shared page pool + per-stream tables
# ---------------------------------------------------------------------------

def supports_paged(cfg) -> bool:
    """Can this arch decode against a paged KV pool?

    The paged read/write path covers the uniform full-attention stacks
    (dense/vlm/moe "blocks" layouts). Windowed ring buffers, gemma's
    local/global grouping, M-RoPE position triples, multi-codebook audio
    and SSM state are served by the legacy contiguous-cache path.
    """
    return (cfg.family in ("dense", "vlm", "moe")
            and cfg.causal
            and not cfg.local_global_ratio
            and not cfg.sliding_window
            and not cfg.mrope)


def init_paged_pool(cfg, num_blocks: int, block_size: int) -> dict:
    """Layer-stacked page pool: {"pk"/"pv": (L, P, bs, Hkv, D)}.

    Page 0 is reserved as the garbage page (see repro.models.paged) —
    allocators must never hand it out.
    """
    dt = _dt(cfg)
    shape = (cfg.n_layers, num_blocks, block_size,
             cfg.n_kv_heads_padded, cfg.head_dim)
    return {"pk": jnp.zeros(shape, dt), "pv": jnp.zeros(shape, dt)}


def _paged_attn_scan(cfg, x, params, masks, pool, block_table, lengths,
                     positions, decode: bool):
    """Scan the attention(+mlp/moe) stack with per-layer pool slices as
    scan xs/ys (same structure the contiguous k/v caches use)."""
    has_moe = cfg.family == "moe"

    def body(carry, xs):
        h = carry
        p_i, m_i, kp, vp = xs
        pg = (kp, vp, block_table, lengths)
        if has_moe:
            h, (nk, nv), _aux = attn_moe_block(
                cfg, p_i, m_i, h, positions=positions, window=0,
                paged=pg, decode=decode)
        else:
            h, (nk, nv) = attn_mlp_block(
                cfg, p_i, m_i, h, positions=positions, window=0,
                paged=pg, decode=decode)
        return h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["blocks"], masks.get("blocks", {}),
                  pool["pk"], pool["pv"]))
    return x, {"pk": nk, "pv": nv}


def _lm_logits(cfg, params, last: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    head = vocab_hint(cfg, head)
    logits = (last @ head.astype(last.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                           logits, -jnp.inf)
    return logits


def paged_prefill_step(cfg, params: Params, masks: Masks, batch: dict,
                       pool: dict, block_table: jax.Array,
                       prompt_lens: jax.Array):
    """Prefill right-padded prompts into a paged KV pool.

    batch["tokens"]: (B, T) right-padded to the prompt bucket;
    prompt_lens: (B,) real lengths (0 for idle rows, whose all-zero table
    rows point at the reserved garbage page). Causal chunked attention means
    real tokens never attend a pad; each row's logits are read at its OWN
    last real token, so results are bitwise those of an unpadded prefill.
    Returns (logits (B, V), new pool).
    """
    masks = masks or {}
    x, positions = embed_inputs(cfg, params, batch)
    x, new_pool = _paged_attn_scan(cfg, x, params, masks, pool, block_table,
                                   prompt_lens, positions, decode=False)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[jnp.arange(x.shape[0]), jnp.maximum(prompt_lens - 1, 0)]
    return _lm_logits(cfg, params, last), new_pool


def paged_decode_step(cfg, params: Params, masks: Masks, batch: dict,
                      pool: dict, block_table: jax.Array, lengths: jax.Array):
    """One-token decode against the paged pool, per-stream positions.

    batch["tokens"]: (B, 1); lengths: (B,) tokens already present per
    stream (the new token is written at slot ``lengths[b]`` and attends
    ``lengths[b] + 1`` slots — exactly the contiguous decode_step math with
    the scalar cache length replaced by a vector). Returns (logits, pool).
    """
    masks = masks or {}
    x, positions = embed_inputs(cfg, params, batch)
    positions = positions + lengths[:, None]
    x, new_pool = _paged_attn_scan(cfg, x, params, masks, pool, block_table,
                                   lengths, positions, decode=True)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _lm_logits(cfg, params, x[:, 0]), new_pool


def paged_verify_step(cfg, params: Params, masks: Masks, batch: dict,
                      pool: dict, block_table: jax.Array, lengths: jax.Array):
    """Multi-position decode (speculative verification).

    batch["tokens"]: (B, T) — token ``i`` is written at slot
    ``lengths[b] + i`` and attends ``lengths[b] + i + 1`` slots, exactly
    the visibility of T sequential ``paged_decode_step`` calls, collapsed
    into ONE full-network dispatch. Returns (logits (B, T, V), pool);
    ``argmax(logits[:, i])`` is the model's next token after consuming
    ``batch["tokens"][:, :i + 1]`` — what a sequential greedy decode would
    emit at that position.
    """
    masks = masks or {}
    x, positions = embed_inputs(cfg, params, batch)
    positions = positions + lengths[:, None]
    x, new_pool = _paged_attn_scan(cfg, x, params, masks, pool, block_table,
                                   lengths, positions, decode=True)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _lm_logits(cfg, params, x), new_pool


def _decode_attn_scan(cfg, stack_p, stack_m, kc, vc, x, positions, window, cache_len):
    """Scan attention(+mlp/moe) layers for one decode step, updating caches."""
    has_moe = cfg.family == "moe"

    def body(carry, xs):
        h = carry
        p_i, m_i, k_i, v_i = xs
        if has_moe:
            h, (nk, nv), _aux = attn_moe_block(
                cfg, p_i, m_i, h, positions=positions, window=window,
                cache=(k_i, v_i, cache_len), decode=True)
        else:
            h, (nk, nv) = attn_mlp_block(
                cfg, p_i, m_i, h, positions=positions, window=window,
                cache=(k_i, v_i, cache_len), decode=True)
        return h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (stack_p, stack_m, kc, vc))
    return x, nk, nv


def _decode_ssm_scan(cfg, stack_p, stack_m, st, x):
    def body(carry, xs):
        p_i, m_i, s_i = xs
        h, ns = ssm_res_block(cfg, p_i, m_i, carry,
                              state=(s_i["conv_x"], s_i["conv_bc"], s_i["h"]),
                              decode=True)
        return h, {"conv_x": ns[0], "conv_bc": ns[1], "h": ns[2]}

    x, new_st = jax.lax.scan(body, x, (stack_p, stack_m, st))
    return x, new_st


def prefill_step(cfg, params: Params, masks: Masks, batch: dict, cache: dict):
    """Process a full prompt, fill the decode caches, return last-token logits.

    batch["tokens"]: (B, T) (audio: (B, K, T)). Returns (logits, cache).
    """
    masks = masks or {}
    x, positions = embed_inputs(cfg, params, batch)
    pos0 = cache["len"]
    t = x.shape[1]
    new_cache: dict = {"len": pos0 + t}

    def attn_scan(stack_p, stack_m, kc, vc, h, window):
        has_moe = cfg.family == "moe"

        def body(carry, xs):
            hh = carry
            p_i, m_i, k_i, v_i = xs
            if has_moe:
                hh, (nk, nv), _aux = attn_moe_block(
                    cfg, p_i, m_i, hh, positions=positions, window=window,
                    cache=(k_i, v_i, pos0), decode=False)
            else:
                hh, (nk, nv) = attn_mlp_block(
                    cfg, p_i, m_i, hh, positions=positions, window=window,
                    cache=(k_i, v_i, pos0), decode=False)
            return hh, (nk, nv)

        h, (nk, nv) = jax.lax.scan(body, h, (stack_p, stack_m, kc, vc))
        return h, nk, nv

    def ssm_scan(stack_p, stack_m, st, h):
        def body(carry, xs):
            p_i, m_i, s_i = xs
            hh, ns = ssm_res_block(cfg, p_i, m_i, carry,
                                   state=(s_i["conv_x"], s_i["conv_bc"], s_i["h"]),
                                   decode=False)
            return hh, {"conv_x": ns[0], "conv_bc": ns[1], "h": ns[2]}

        h, new_st = jax.lax.scan(body, h, (stack_p, stack_m, st))
        return h, new_st

    if cfg.family in ("dense", "vlm", "audio", "moe") and not cfg.local_global_ratio:
        c = cache["blocks"]
        x, nk, nv = attn_scan(params["blocks"], masks.get("blocks", {}),
                              c["k"], c["v"], x, cfg.sliding_window)
        new_cache["blocks"] = {"k": nk, "v": nv}
    elif cfg.local_global_ratio:
        w = cfg.sliding_window

        def group_body(carry, xs):
            h = carry
            pl, ml, kcl, vcl, pg, mg, kcg, vcg = xs
            h, nkl, nvl = attn_scan(pl, ml, kcl, vcl, h, w)
            h, (nkg, nvg) = attn_mlp_block(cfg, pg, mg, h, positions=positions,
                                           window=0, cache=(kcg, vcg, pos0),
                                           decode=False)
            return h, (nkl, nvl, nkg, nvg)

        cl, cg = cache["g_local"], cache["g_global"]
        x, (nkl, nvl, nkg, nvg) = jax.lax.scan(
            group_body, x,
            (params["g_local"], masks.get("g_local", {}), cl["k"], cl["v"],
             params["g_global"], masks.get("g_global", {}), cg["k"], cg["v"]))
        new_cache["g_local"] = {"k": nkl, "v": nvl}
        new_cache["g_global"] = {"k": nkg, "v": nvg}
        if "g_rem" in params:
            cr = cache["g_rem"]
            x, nk, nv = attn_scan(params["g_rem"], masks.get("g_rem", {}),
                                  cr["k"], cr["v"], x, w)
            new_cache["g_rem"] = {"k": nk, "v": nv}
    elif cfg.family == "ssm":
        x, new_st = ssm_scan(params["blocks"], masks.get("blocks", {}),
                             cache["blocks"], x)
        new_cache["blocks"] = new_st
    elif cfg.family == "hybrid":
        sh_p, sh_m = params["shared_attn"], masks.get("shared_attn", {})
        ca = cache["shared_attn"]

        def group_body(carry, xs):
            h = carry
            p_g, m_g, st_g, ka, va = xs
            h, new_st = ssm_scan(p_g, m_g, st_g, h)
            h, (nka, nva) = attn_mlp_block(cfg, sh_p, sh_m, h, positions=positions,
                                           window=0, cache=(ka, va, pos0),
                                           decode=False)
            return h, (new_st, nka, nva)

        x, (new_st, nka, nva) = jax.lax.scan(
            group_body, x,
            (params["m_groups"], masks.get("m_groups", {}), cache["m_groups"],
             ca["k"], ca["v"]))
        new_cache["m_groups"] = new_st
        new_cache["shared_attn"] = {"k": nka, "v": nva}
        if "m_rem" in params:
            x, new_rem = ssm_scan(params["m_rem"], masks.get("m_rem", {}),
                                  cache["m_rem"], x)
            new_cache["m_rem"] = new_rem
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1]
    if cfg.family == "audio":
        logits = jnp.stack(
            [(last @ vocab_hint(cfg, params["lm_head"][k]).astype(x.dtype)
              ).astype(jnp.float32) for k in range(cfg.n_codebooks)], axis=1)
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        head = vocab_hint(cfg, head)
        logits = (last @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                           logits, -jnp.inf)
    return logits, new_cache


def decode_step(cfg, params: Params, masks: Masks, batch: dict, cache: dict):
    """One-token decode. batch["tokens"]: (B, 1) (audio: (B, K, 1)).

    Returns (logits (B, V) [audio: (B, K, V)], new_cache).
    """
    masks = masks or {}
    x, positions = embed_inputs(cfg, params, batch)
    pos = cache["len"]
    if cfg.mrope:
        positions = positions + pos  # all three streams advance in time
    else:
        positions = positions + pos
    new_cache: dict = {"len": pos + 1}

    if cfg.family in ("dense", "vlm", "audio", "moe") and not cfg.local_global_ratio:
        c = cache["blocks"]
        x, nk, nv = _decode_attn_scan(
            cfg, params["blocks"], masks.get("blocks", {}), c["k"], c["v"], x,
            positions, cfg.sliding_window, pos)
        new_cache["blocks"] = {"k": nk, "v": nv}

    elif cfg.local_global_ratio:  # gemma3
        w = cfg.sliding_window

        def group_body(carry, xs):
            h = carry
            pl, ml, kcl, vcl, pg, mg, kcg, vcg = xs
            h, nkl, nvl = _decode_attn_scan(cfg, pl, ml, kcl, vcl, h, positions, w, pos)
            h, (nkg, nvg) = attn_mlp_block(cfg, pg, mg, h, positions=positions,
                                           window=0, cache=(kcg, vcg, pos), decode=True)
            return h, (nkl, nvl, nkg, nvg)

        cl, cg = cache["g_local"], cache["g_global"]
        x, (nkl, nvl, nkg, nvg) = jax.lax.scan(
            group_body, x,
            (params["g_local"], masks.get("g_local", {}), cl["k"], cl["v"],
             params["g_global"], masks.get("g_global", {}), cg["k"], cg["v"]))
        new_cache["g_local"] = {"k": nkl, "v": nvl}
        new_cache["g_global"] = {"k": nkg, "v": nvg}
        if "g_rem" in params:
            cr = cache["g_rem"]
            x, nk, nv = _decode_attn_scan(
                cfg, params["g_rem"], masks.get("g_rem", {}), cr["k"], cr["v"], x,
                positions, w, pos)
            new_cache["g_rem"] = {"k": nk, "v": nv}

    elif cfg.family == "ssm":
        x, new_st = _decode_ssm_scan(cfg, params["blocks"], masks.get("blocks", {}),
                                     cache["blocks"], x)
        new_cache["blocks"] = new_st

    elif cfg.family == "hybrid":
        sh_p, sh_m = params["shared_attn"], masks.get("shared_attn", {})
        ca = cache["shared_attn"]

        def group_body(carry, xs):
            h = carry
            p_g, m_g, st_g, ka, va = xs
            h, new_st = _decode_ssm_scan(cfg, p_g, m_g, st_g, h)
            h, (nka, nva) = attn_mlp_block(cfg, sh_p, sh_m, h, positions=positions,
                                           window=0, cache=(ka, va, pos), decode=True)
            return h, (new_st, nka, nva)

        x, (new_st, nka, nva) = jax.lax.scan(
            group_body, x,
            (params["m_groups"], masks.get("m_groups", {}), cache["m_groups"],
             ca["k"], ca["v"]))
        new_cache["m_groups"] = new_st
        new_cache["shared_attn"] = {"k": nka, "v": nva}
        if "m_rem" in params:
            x, new_rem = _decode_ssm_scan(cfg, params["m_rem"], masks.get("m_rem", {}),
                                          cache["m_rem"], x)
            new_cache["m_rem"] = new_rem
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "audio":
        logits = jnp.stack(
            [(x[:, 0] @ vocab_hint(cfg, params["lm_head"][k]).astype(x.dtype)
              ).astype(jnp.float32)
             for k in range(cfg.n_codebooks)], axis=1)
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        head = vocab_hint(cfg, head)
        logits = (x[:, 0] @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:  # mask padded vocab columns
        logits = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                           logits, -jnp.inf)
    return logits, new_cache
