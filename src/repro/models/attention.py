"""GQA attention: chunked (flash-style) prefill/train + KV-cache decode.

Design points (see DESIGN.md §4):

* **Chunked attention**: queries are processed in *statically unrolled* chunks;
  each q-chunk attends only to the kv prefix it can causally see (exact static
  slice), with an inner ``lax.scan`` over kv chunks carrying online-softmax
  stats. No O(T^2) score tensor is ever live, and — unlike a masked full scan —
  no FLOPs are spent above the diagonal at the chunk level.
* **GQA via gather-expand**: kv heads are expanded to the query-head axis with
  a static ``head_to_kv`` gather. Under TP the q-head axis is sharded and kv is
  replicated (GQA kv counts rarely divide the TP degree), so the gather is
  shard-local and each device materializes only its own heads' kv — the
  standard Megatron/MaxText GQA-TP layout. When head counts don't divide the
  TP degree they are padded (configs.base.ArchConfig.pad_heads_to) and a
  ``head_mask`` zeroes padded heads' outputs, keeping results bit-exact.
* **Sliding window**: windowed layers slice a static ``(q_chunk + window)`` kv
  slab per q-chunk → O(T·window) compute, and use a **ring-buffer KV cache** of
  size ``window`` at decode time (gemma3's 5:1 local:global pattern makes the
  500k-context cell affordable: only the rare global layers keep full caches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def expand_kv(k: jax.Array, head_to_kv: tuple) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, H, D) by the static q-head -> kv-head map.

    Identity maps (MHA) are returned untouched (no gather in the HLO).
    """
    if head_to_kv == tuple(range(k.shape[2])):
        return k
    idx = jnp.asarray(head_to_kv, jnp.int32)
    return jnp.take(k, idx, axis=2)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    head_to_kv: tuple,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Memory-efficient attention.

    q: (B, Tq, H, D); k, v: (B, S, Hkv, D). Returns (B, Tq, H, D).
    ``q_offset`` is the absolute position of q[0] (for prefill continuation).
    """
    b, tq, h, d = q.shape
    s = k.shape[1]
    scale = d ** -0.5
    q = q * scale
    k = expand_kv(k, head_to_kv)
    v = expand_kv(v, head_to_kv)

    q_chunk = min(q_chunk, tq)
    n_q = -(-tq // q_chunk)
    pad_q = n_q * q_chunk - tq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))

    outs = []
    for i in range(n_q):  # static unroll: exact causal kv extent per chunk
        q_i = q[:, i * q_chunk: (i + 1) * q_chunk]
        q_lo = q_offset + i * q_chunk
        q_hi = q_lo + q_chunk
        kv_hi = min(s, q_hi) if causal else s
        kv_lo = max(0, q_lo - window + 1) if (window and causal) else 0
        kv_lo = (kv_lo // kv_chunk) * kv_chunk
        kv_hi = min(s, -(-kv_hi // kv_chunk) * kv_chunk)
        if kv_hi <= kv_lo:  # fully masked chunk (can happen with offsets)
            outs.append(jnp.zeros((b, q_chunk, h, d), v.dtype))
            continue
        outs.append(
            _attend_one_q_chunk(
                q_i, k[:, kv_lo:kv_hi], v[:, kv_lo:kv_hi],
                q_pos0=q_lo, kv_pos0=kv_lo, causal=causal,
                window=window, kv_chunk=kv_chunk,
            )
        )
    out = jnp.concatenate(outs, axis=1)[:, :tq]
    return out


def _attend_one_q_chunk(q_i, k_i, v_i, *, q_pos0, kv_pos0, causal, window, kv_chunk):
    """Online-softmax scan over kv chunks for one q chunk.

    q_i: (B, Qc, H, D); k_i/v_i: (B, Skv, H, D) — the causal slab, kv expanded.
    """
    b, qc, h, d = q_i.shape
    skv = k_i.shape[1]
    kv_chunk = min(kv_chunk, skv)
    n_kv = -(-skv // kv_chunk)
    pad = n_kv * kv_chunk - skv
    if pad:
        k_i = jnp.pad(k_i, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_i = jnp.pad(v_i, ((0, 0), (0, pad), (0, 0), (0, 0)))

    k_c = k_i.reshape(b, n_kv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    v_c = v_i.reshape(b, n_kv, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_pos0 + jnp.arange(qc)

    def step(carry, xs):
        acc, m, l = carry
        k_blk, v_blk, blk_idx = xs
        kv_pos = kv_pos0 + blk_idx * kv_chunk + jnp.arange(kv_chunk)
        s_blk = jnp.einsum("bqhd,bshd->bhqs", q_i, k_blk,
                           preferred_element_type=jnp.float32)
        mask = jnp.ones((qc, kv_chunk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= kv_pos[None, :] < kv_pos0 + skv  # padded kv tail
        s_blk = jnp.where(mask[None, None], s_blk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bhqs,bshd->bhqd", p.astype(v_blk.dtype), v_blk)
        acc_new = acc * corr[..., None].astype(acc.dtype) + upd.astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, qc, d), jnp.float32)
    m0 = jnp.full((b, h, qc), NEG_INF)
    l0 = jnp.zeros((b, h, qc), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (k_c, v_c, jnp.arange(n_kv)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(v_i.dtype)  # (B, Qc, H, D)


# ---------------------------------------------------------------------------
# decode (KV cache)
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    head_to_kv: tuple,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, Hkv, D); cache_len: () int32 —
    total tokens *including* the one just written. For windowed layers
    S == window and slot j holds the most recent absolute position
    t < cache_len with t % S == j.
    """
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    scale = d ** -0.5

    k_exp = expand_kv(k_cache, head_to_kv)
    v_exp = expand_kv(v_cache, head_to_kv)
    scores = jnp.einsum("bqhd,bshd->bhqs", q * scale, k_exp,
                        preferred_element_type=jnp.float32)[:, :, 0]  # (B, H, S)

    slots = jnp.arange(s)
    if window:
        # absolute position held by each ring slot
        t = cache_len - 1 - ((cache_len - 1 - slots) % s)
        valid = (t >= 0) & (t < cache_len) & (t > cache_len - 1 - window)
    else:
        valid = slots < cache_len
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(v_exp.dtype), v_exp)
    return out[:, None].transpose(0, 1, 2, 3).reshape(b, 1, h, d)


def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_table: jax.Array,
    lengths: jax.Array,
    *,
    head_to_kv: tuple,
) -> jax.Array:
    """Single-token attention against a paged KV pool with per-stream lengths.

    q: (B, 1, H, D); k_pool/v_pool: (P, bs, Hkv, D) — one layer's page pool;
    block_table: (B, NB) int32 page ids in position order; lengths: (B,)
    int32 tokens per stream *including* the one just written. Token ``t`` of
    stream ``b`` lives at ``(block_table[b, t // bs], t % bs)``.

    Slots at or beyond a stream's length are masked to ``NEG_INF`` before
    the softmax, so their weights underflow to exact 0.0 — results are
    bitwise independent of whatever garbage the masked pages hold (pad rows
    point their whole table at the reserved page 0). This is the same
    exact-zero argument ``chunked_attention`` uses for its kv-tail padding.
    """
    b, _, h, d = q.shape
    nb = block_table.shape[1]
    bs = k_pool.shape[1]
    scale = d ** -0.5

    # gather each stream's pages; position order is the table's entry order
    k = k_pool[block_table].reshape(b, nb * bs, *k_pool.shape[2:])
    v = v_pool[block_table].reshape(b, nb * bs, *v_pool.shape[2:])
    k_exp = expand_kv(k, head_to_kv)
    v_exp = expand_kv(v, head_to_kv)
    scores = jnp.einsum("bqhd,bshd->bhqs", q * scale, k_exp,
                        preferred_element_type=jnp.float32)[:, :, 0]  # (B, H, S)

    valid = jnp.arange(nb * bs)[None, :] < lengths[:, None]          # (B, S)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(v_exp.dtype), v_exp)
    return out[:, None]


def paged_verify_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_table: jax.Array,
    lengths: jax.Array,
    *,
    head_to_kv: tuple,
) -> jax.Array:
    """Multi-position attention against a paged KV pool (speculative verify).

    q: (B, T, H, D) — T consecutive tokens per stream, token ``i`` sitting
    at absolute slot ``lengths[b] + i`` (already written to the pool);
    lengths: (B,) tokens committed per stream BEFORE this dispatch. Query
    ``i`` attends slots ``< lengths[b] + i + 1`` — exactly the visibility a
    sequential chain of ``paged_decode_attention`` calls would give it, so
    one batched dispatch scores every drafted position. Masked slots hit
    ``NEG_INF`` before the softmax (exact-zero weights), so results are
    bitwise independent of garbage beyond each query's own prefix.
    """
    b, t, h, d = q.shape
    nb = block_table.shape[1]
    bs = k_pool.shape[1]
    scale = d ** -0.5

    k = k_pool[block_table].reshape(b, nb * bs, *k_pool.shape[2:])
    v = v_pool[block_table].reshape(b, nb * bs, *v_pool.shape[2:])
    k_exp = expand_kv(k, head_to_kv)
    v_exp = expand_kv(v, head_to_kv)
    scores = jnp.einsum("bqhd,bshd->bhqs", q * scale, k_exp,
                        preferred_element_type=jnp.float32)    # (B, H, T, S)

    visible = lengths[:, None] + 1 + jnp.arange(t)[None]               # (B, T)
    valid = jnp.arange(nb * bs)[None, None, :] < visible[:, :, None]   # (B, T, S)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v_exp.dtype), v_exp)


def paged_cache_write(k_pool, v_pool, k_new, v_new, block_table, positions):
    """Scatter T new tokens per stream into a paged pool.

    k_pool/v_pool: (P, bs, Hkv, D); k_new/v_new: (B, T, Hkv, D);
    block_table: (B, NB) int32; positions: (B, T) int32 absolute token slots.
    Positions past a stream's table extent clamp into its last table entry —
    idle rows keep an all-zero table, so overshooting writes land in the
    reserved garbage page 0 and never touch a live stream's pages.
    """
    bs = k_pool.shape[1]
    nb = block_table.shape[1]
    page = jnp.minimum(positions // bs, nb - 1)                       # (B, T)
    blk = jnp.take_along_axis(block_table, page, axis=1)              # (B, T)
    off = positions % bs
    k_pool = k_pool.at[blk, off].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v_new.astype(v_pool.dtype))
    return k_pool, v_pool


def cache_write(k_cache, v_cache, k_new, v_new, cache_len):
    """Write T_new tokens into the cache (ring semantics if cache is smaller).

    k_cache: (B, S, Hkv, D); k_new: (B, T, Hkv, D); cache_len: tokens already
    present. Returns updated caches.
    """
    s = k_cache.shape[1]
    t = k_new.shape[1]
    if t >= s:  # only the trailing window survives a big prefill
        k_new, v_new = k_new[:, -s:], v_new[:, -s:]
        off = t - s
        pos = (cache_len + off + jnp.arange(s)) % s
    else:
        pos = (cache_len + jnp.arange(t)) % s
    k_cache = k_cache.at[:, pos].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[:, pos].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache
