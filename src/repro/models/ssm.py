"""Mamba2 / SSD (state-space duality) mixer — chunked train/prefill + decode.

Implements the SSD "chunked" algorithm (Dao & Gu 2024, arXiv:2405.21060):
intra-chunk attention-like quadratic term + inter-chunk recurrent state carried
by a ``lax.scan`` — O(T·chunk) compute, O(state) memory across chunks, which is
what makes the 500k-token long-context cell affordable for SSM archs.

TP note: the input projection is split into separate z / x / BC / dt matmuls so
each output can carry its own sharding (z, x, dt are head-sharded over 'model';
B, C are n_groups=1 and replicated). The depthwise conv and the SSD scan are
channel-/head-local, so the whole mixer needs **zero collectives** between the
in- and out-projections — the same property that makes SRigL's per-neuron
constant fan-in DST update collective-free (DESIGN.md §3).

Decode maintains (conv_state, ssm_state) per layer:
  h <- exp(dt·A) h + dt · B x^T ;  y = C·h + D·x
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


class SSMParams(NamedTuple):
    in_z: jax.Array       # (d_model, d_inner)
    in_x: jax.Array       # (d_model, d_inner)
    in_bc: jax.Array      # (d_model, 2*ssm_state)
    in_dt: jax.Array      # (d_model, H)
    conv_x: jax.Array     # (conv_width, d_inner)  depthwise
    conv_bc: jax.Array    # (conv_width, 2*ssm_state)
    conv_b: jax.Array     # (d_inner,)
    conv_bc_b: jax.Array  # (2*ssm_state,)
    a_log: jax.Array      # (H,)
    d_skip: jax.Array     # (H,)
    dt_bias: jax.Array    # (H,)
    norm_scale: jax.Array  # (d_inner,)
    out_proj: jax.Array   # (d_inner, d_model)


def init_ssm_params(key: jax.Array, cfg, dtype=jnp.float32,
                    k_fan_in: dict | None = None) -> SSMParams:
    ks = jax.random.split(key, 6)
    h = cfg.ssm_n_heads
    kf = k_fan_in or {}

    def sp(k, a, b, name):
        return L.sparse_init(k, a, b, kf.get(name, a), dtype)

    return SSMParams(
        in_z=sp(ks[0], cfg.d_model, cfg.d_inner, "in_z"),
        in_x=sp(ks[1], cfg.d_model, cfg.d_inner, "in_x"),
        in_bc=L.dense_init(ks[2], cfg.d_model, 2 * cfg.ssm_state, dtype),
        in_dt=L.dense_init(ks[3], cfg.d_model, h, dtype),
        conv_x=(jax.random.normal(ks[4], (cfg.ssm_conv_width, cfg.d_inner)) * 0.1).astype(dtype),
        conv_bc=(jax.random.normal(ks[5], (cfg.ssm_conv_width, 2 * cfg.ssm_state)) * 0.1).astype(dtype),
        conv_b=jnp.zeros((cfg.d_inner,), dtype),
        conv_bc_b=jnp.zeros((2 * cfg.ssm_state,), dtype),
        a_log=jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        d_skip=jnp.ones((h,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        norm_scale=jnp.zeros((cfg.d_inner,), dtype),
        out_proj=sp(ks[3], cfg.d_inner, cfg.d_model, "ssm_out"),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv over time. x: (B, T, C), w: (width, C).

    Returns (silu(conv(x)+b), new_state) where state is the trailing width-1
    inputs (for decode continuation).
    """
    w = w.astype(x.dtype)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+w-1, C)
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(width))
    y = jax.nn.silu(y + b.astype(x.dtype))
    new_state = xp[:, x.shape[1]:] if width > 1 else pad
    return y, new_state


def ssd_chunked(x, dt, a, b, c, *, chunk: int = 256, h0: jax.Array | None = None):
    """Chunked SSD scan.

    x : (B, T, H, P)   inputs per head
    dt: (B, T, H)      positive step sizes (softplus already applied)
    a : (H,)           negative decay rates (A = -exp(a_log))
    b : (B, T, N)      input projection (shared across heads, n_groups=1)
    c : (B, T, N)      output projection
    h0: (B, H, P, N)   initial state (decode/prefill continuation)
    Returns y: (B, T, H, P), h_last: (B, H, P, N) float32.
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    f32 = jnp.float32
    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3).astype(f32)
    bc = b.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    cum = jnp.cumsum(dtc * a.astype(f32)[None, None, None, :], axis=2)  # (nc,B,Q,H)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)

    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]

    def step(h_prev, xs):
        x_k, dt_k, b_k, c_k, cum_k = xs
        # intra-chunk: y_i = sum_{j<=i} (c_i.b_j) exp(cum_i - cum_j) dt_j x_j
        seg = cum_k[:, :, None, :] - cum_k[:, None, :, :]           # (B,Q,Q,H)
        l_mat = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", c_k.astype(f32), b_k.astype(f32))
        w_ij = cb[..., None] * l_mat * dt_k[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w_ij, x_k.astype(f32))
        # inter-chunk: y_i += exp(cum_i) c_i . h_prev
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", c_k.astype(f32), h_prev,
                             jnp.exp(cum_k))
        # state: h = exp(cum_last) h_prev + sum_j exp(cum_last - cum_j) dt_j b_j x_j^T
        total = cum_k[:, -1, :]
        decay_j = jnp.exp(total[:, None, :] - cum_k) * dt_k
        h_new = jnp.exp(total)[:, :, None, None] * h_prev + jnp.einsum(
            "bjh,bjn,bjhp->bhpn", decay_j, b_k.astype(f32), x_k.astype(f32))
        return h_new, y_intra + y_inter

    h_last, yc = jax.lax.scan(step, h0, (xc, dtc, bc, cc, cum))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)[:, :t]
    return y.astype(x.dtype), h_last


def ssd_decode_step(x, dt, a, b, c, h_prev):
    """Single-token SSD update. x: (B,1,H,P); b,c: (B,1,N); dt: (B,1,H)."""
    f32 = jnp.float32
    da = jnp.exp(dt[:, 0].astype(f32) * a.astype(f32)[None, :])      # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0].astype(f32),
                     b[:, 0].astype(f32), x[:, 0].astype(f32))
    h_new = da[:, :, None, None] * h_prev + upd
    y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(f32), h_new)
    return y[:, None].astype(x.dtype), h_new


def ssm_block(cfg, params: SSMParams, x_in: jax.Array, masks: dict | None = None,
              state: tuple | None = None, chunk: int = 256, decode: bool = False):
    """Full Mamba2 mixer. x_in: (B, T, d_model) (pre-normed by caller).

    state: (conv_x_state (B,w-1,d_inner), conv_bc_state (B,w-1,2N), h (B,H,P,N)).
    Returns (y (B, T, d_model), new_state).
    """
    m = masks or {}
    z = L.linear(x_in, params.in_z, m.get("in_z"))
    x = L.linear(x_in, params.in_x, m.get("in_x"))
    bc = L.linear(x_in, params.in_bc)
    dt = L.linear(x_in, params.in_dt)

    sx, sbc, h0 = state if state is not None else (None, None, None)
    x, new_sx = _causal_conv(x, params.conv_x, params.conv_b, sx)
    bc, new_sbc = _causal_conv(bc, params.conv_bc, params.conv_bc_b, sbc)
    n = cfg.ssm_state
    b, c = bc[..., :n], bc[..., n:]

    h = cfg.ssm_n_heads
    p = cfg.ssm_head_dim
    xh = x.reshape(*x.shape[:-1], h, p)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params.dt_bias)
    a = -jnp.exp(params.a_log)

    if decode:
        y, h_last = ssd_decode_step(xh, dtv, a, b, c, h0)
        y = y.reshape(*x.shape[:-1], h, p)
    else:
        y, h_last = ssd_chunked(xh, dtv, a, b, c, chunk=chunk, h0=h0)
    y = y + params.d_skip.astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], cfg.d_inner).astype(x.dtype)

    y = L.rms_norm(y * jax.nn.silu(z), params.norm_scale)
    out = L.linear(y, params.out_proj, m.get("out_proj"))
    return out, (new_sx, new_sbc, h_last)
