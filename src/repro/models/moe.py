"""Top-k MoE with GShard-style capacity routing (TPU-idiomatic one-hot dispatch).

Tokens are processed in groups of ``group_size``; each group dispatches to a
per-expert capacity buffer with one-hot einsums — the classic fully-SPMD-
partitionable formulation (experts sharded over the 'model' mesh axis, groups
over 'data'; XLA inserts the dispatch all-to-alls). Tokens over capacity are
dropped (capacity_factor 1.25 default), matching standard large-scale practice.

Experts are SwiGLU FFNs stored stacked ``(E, d, ff)`` so SRigL treats each
expert row block as its own constant fan-in matrix (vmapped update).

A load-balancing auxiliary loss (Switch/GShard) is returned for the trainer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


class MoEParams(NamedTuple):
    router: jax.Array   # (d_model, E)
    w_gate: jax.Array   # (E, d_model, ff)
    w_up: jax.Array     # (E, d_model, ff)
    w_down: jax.Array   # (E, ff, d_model)


def init_moe_params(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
                    k_fan_in: dict | None = None, dtype=jnp.float32) -> MoEParams:
    ks = jax.random.split(key, 4)
    def init(k, a, b, fan):
        w = jax.random.normal(k, (n_experts, a, b)) / jnp.sqrt(max(fan, 1))
        return w.astype(dtype)
    kf = k_fan_in or {}
    return MoEParams(
        router=L.dense_init(ks[0], d_model, n_experts, jnp.float32),
        w_gate=init(ks[1], d_model, d_ff, kf.get("w_gate", d_model)),
        w_up=init(ks[2], d_model, d_ff, kf.get("w_up", d_model)),
        w_down=init(ks[3], d_ff, d_model, kf.get("w_down", d_ff)),
    )


def route_topk(logits: jax.Array, top_k: int, capacity: int):
    """GShard top-k routing for one group.

    logits: (G, S, E). Returns (dispatch (G,S,E,C) bool, combine (G,S,E,C) f32,
    aux_loss scalar).
    """
    g, s, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (G, S, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # Sequential slot assignment across the k choices (classic GShard loop).
    counts = jnp.zeros((g, e), jnp.int32)
    dispatch = jnp.zeros((g, s, e, capacity), bool)
    combine = jnp.zeros((g, s, e, capacity), jnp.float32)
    for j in range(top_k):
        onehot = jax.nn.one_hot(gate_idx[:, :, j], e, dtype=jnp.int32)  # (G,S,E)
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]  # slot per token
        counts = counts + jnp.sum(onehot, axis=1)
        keep = (pos < capacity) & (onehot > 0)
        slot = jnp.clip(pos, 0, capacity - 1)
        slot_oh = jax.nn.one_hot(slot, capacity, dtype=jnp.float32) * keep[..., None]
        dispatch = dispatch | (slot_oh > 0)
        combine = combine + gate_vals[:, :, j, None, None] * slot_oh

    # load-balance aux loss: E * sum_e f_e * p_e   (Switch Transformer eq. 4)
    me = jnp.mean(probs, axis=(0, 1))                               # mean router prob
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, :, 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return dispatch, combine, aux


def moe_block(cfg, params: MoEParams, x: jax.Array, masks: dict | None = None,
              group_size: int = 2048):
    """x: (B, T, d) -> (y, aux_loss)."""
    m = masks or {}
    b, t, d = x.shape
    n_tok = b * t
    gs = min(group_size, n_tok)
    n_groups = n_tok // gs
    assert n_groups * gs == n_tok, f"tokens {n_tok} not divisible by group {gs}"
    e, k = cfg.n_experts, cfg.top_k_experts
    # ceil + floor-at-top_k so tiny decode groups are never starved; a token
    # occupies each chosen expert at most once, so capacity == gs => no drops.
    capacity = min(gs, max(-(-gs * k * int(100 * cfg.capacity_factor) // (100 * e)), k))

    xt = x.reshape(n_groups, gs, d)
    logits = xt @ params.router.astype(x.dtype)                     # (G, S, E)
    dispatch, combine, aux = route_topk(logits, k, capacity)

    # dispatch: (G,S,E,C) x (G,S,d) -> (E, G, C, d)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xt)

    def expert_ffn(w_gate, w_up, w_down, mg, mu, md, xin):
        gate = L.linear(xin, w_gate, mg)
        up = L.linear(xin, w_up, mu)
        return L.linear(L.swiglu(gate, up), w_down, md)

    mg, mu, md = m.get("w_gate"), m.get("w_up"), m.get("w_down")
    if mg is not None:
        ye = jax.vmap(expert_ffn)(params.w_gate, params.w_up, params.w_down, mg, mu, md, xe)
    else:
        ye = jax.vmap(
            lambda wg, wu, wd, xin: expert_ffn(wg, wu, wd, None, None, None, xin)
        )(params.w_gate, params.w_up, params.w_down, xe)

    # combine: (G,S,E,C) x (E,G,C,d) -> (G,S,d)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)
    return y.reshape(b, t, d), aux
