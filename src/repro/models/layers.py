"""Primitive layers: norms, embeddings, RoPE/M-RoPE, sparse-aware linear apply.

All modules are functional: ``init_*`` returns a params dict, ``apply`` is a
pure function. Sparse linears take an optional boolean mask; when given, the
weight is masked with a straight-through trick so the *gradient stays dense*
(required by the RigL/SRigL grow criterion — see core/srigl.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.srigl import apply_mask_for_forward
from repro.sparse import formats as F


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal-ish init, std = 1/sqrt(d_in)."""
    return (jax.random.normal(key, (d_in, d_out)) / jnp.sqrt(d_in)).astype(dtype)


def sparse_init(key: jax.Array, d_in: int, d_out: int, k: int, dtype=jnp.float32) -> jax.Array:
    """Fan-in-aware init for sparse layers (Evci et al. 2022): std = 1/sqrt(k).

    The dense tensor is initialized at the *sparse* fan-in scale; masked-out
    entries are dead until regrown (regrown weights start at 0 per RigL).
    """
    return (jax.random.normal(key, (d_in, d_out)) / jnp.sqrt(max(k, 1))).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# linear / norm applies
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w: jax.Array, mask=None) -> jax.Array:
    """y = x @ (w masked if sparse). Dense gradients via straight-through.

    Serving-representation dispatch (paper Sec. 4.4 "same weights, multiple
    representations"): the ``mask`` argument selects the execution path. The
    per-stack choice is made by repro.sparse.plan (each format's cost model
    over the request batch shape); this function only dispatches on the
    leaf's TYPE:

    * bool array — masked-dense MXU path (training / prefill default), with
      the straight-through trick so the gradient stays dense (the RigL/SRigL
      grow criterion needs it).
    * ``repro.sparse.formats.SparseFormat`` — the format executes itself
      (``fmt.apply(x, w)``): MaskedDense / Condensed / StructuredFanIn /
      CondensedOverActive, each one point of PAPER.md Fig. 4 (see the
      formats module docstring for the mapping; the structured and
      condensed-over-active points run the ablation-aware Pallas kernels of
      kernels.structured_matmul — gathered columns / fused scatter).
    * legacy dict leaf — auto-upgraded through the deprecation shim
      (``formats.from_legacy_leaf``); a dict with unrecognized keys raises a
      clear error instead of silently mis-dispatching.
    """
    if isinstance(mask, dict):
        # pre-formats serving trees: upgrade, then dispatch on type
        mask = F.from_legacy_leaf(mask, d_in=w.shape[-2], d_out=w.shape[-1])
    if isinstance(mask, F.SparseFormat):
        return mask.apply(x, w)
    if mask is not None:
        w = apply_mask_for_forward(w, mask)
    return x @ w.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., T, 1, D/2)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=(2, 1, 1)) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): 3 position streams (t, h, w) over D/2 bands.

    x: (B, T, H, D); positions: (3, B, T). Frequency bands are split into
    sections proportional to ``sections`` and each uses its own position ids.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    n = d // 2
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        nxt = acc + (n * s) // total
        bounds.append((acc, nxt))
        acc = nxt
    bounds[-1] = (bounds[-1][0], n)
    # Select per-band position stream.
    band_pos = []
    for axis, (lo, hi) in enumerate(bounds):
        p = positions[axis]  # (B, T)
        band_pos.append(p[..., None].astype(jnp.float32) * freqs[lo:hi])
    ang = jnp.concatenate(band_pos, axis=-1)  # (B, T, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :n], x[..., n:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up
