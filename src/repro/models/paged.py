"""Paged KV cache: a shared block pool + per-stream block tables.

The continuous-batching scheduler (repro.launch.engine) keeps ONE compiled
decode program per batch bucket and changes group membership between chunk
dispatches — streams are admitted and retired without copying anyone's KV
state. The enabling layout is paging (vLLM-style, adapted to the functional
JAX serving loop):

* **Pool** — per layer, ``num_blocks`` fixed-size pages of ``block_size``
  token slots: ``{"pk": (L, P, bs, Hkv, D), "pv": ...}`` (see
  ``model.init_paged_pool``). The pool is donated through every jitted
  dispatch, so serving memory stays at one pool regardless of how many
  requests flow through it.
* **Block table** — per stream, an int32 row of page ids in position order;
  token ``t`` of a stream lives at ``(table[t // bs], t % bs)``. Tables and
  per-stream lengths are small host-managed arrays passed into each
  dispatch; reshaping GROUP membership is a host-side table edit, never a
  device copy.
* **Block 0 is reserved** as a garbage page: idle rows of a bucket-padded
  dispatch point their whole table at it, so their writes land harmlessly
  and their reads are masked by ``lengths == 0``. Real streams never have
  page 0 in their table, which is what makes bucket-padding exact: a padded
  dispatch cannot touch a live stream's pages.

The device-side read/write primitives live in ``repro.models.attention``
(``paged_decode_attention`` / ``paged_cache_write``); this module owns the
host-side accounting.
"""
from __future__ import annotations


def pages_for(tokens: int, block_size: int) -> int:
    """Pages needed to hold ``tokens`` slots of one stream."""
    return -(-max(int(tokens), 0) // int(block_size))


def rewind_pages(table_row, allocator, committed_tokens: int,
                 block_size: int) -> int:
    """Roll one stream's table back to ``committed_tokens`` slots.

    Speculative decoding writes draft K/V past the committed length; when
    the verifier rejects a suffix, the pages covering ONLY overshoot slots
    must return to the pool and their table entries must zero (so later
    writes clamp into the garbage page, never a stale grant). ``table_row``
    is the stream's host int32 row, mutated in place. Pages holding at
    least one committed token stay — their overshoot tail is dead data
    masked by ``lengths`` at every read. Returns the number of pages freed.
    """
    keep = pages_for(committed_tokens, block_size)
    held = [int(p) for p in table_row if p != 0]
    overshoot = held[keep:]
    if overshoot:
        allocator.release(overshoot)
        table_row[keep:] = 0
    return len(overshoot)


class BlockAllocator:
    """Host-side free list over a pool's page ids (page 0 reserved).

    Allocation is LIFO (recently freed pages are reused first — they are the
    ones most likely still warm in cache) and all-or-nothing: ``alloc``
    either returns exactly ``n`` pages or raises without side effects.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1 (page 0 is reserved)")
        self.num_blocks = int(num_blocks)
        self._free = list(range(1, self.num_blocks))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"paged KV pool exhausted: requested {n} pages, "
                f"{len(self._free)}/{self.num_blocks - 1} free")
        out = [self._free.pop() for _ in range(n)]
        return out

    def release(self, pages) -> None:
        for p in pages:
            if p == 0:
                raise ValueError("page 0 is the reserved garbage page")
            if p in self._free or not (0 < p < self.num_blocks):
                raise ValueError(f"double free / bad page id {p}")
            self._free.append(p)

    def grow(self, new_num_blocks: int) -> None:
        """Extend the free list after the pool itself grew."""
        if new_num_blocks < self.num_blocks:
            raise ValueError("pool can only grow")
        self._free.extend(range(self.num_blocks, new_num_blocks))
        self.num_blocks = int(new_num_blocks)
