import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device count
on first init). For each cell this script:

  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. builds ShapeDtypeStruct stand-ins for the train/serve step inputs
     (weights, optimizer state, DST masks, batch, KV caches — no allocation),
  3. jit-lowers with explicit in/out shardings from launch/sharding.py,
  4. compiles, prints memory_analysis() (proves it fits) and cost_analysis()
     (FLOPs/bytes for §Roofline), and
  5. parses the partitioned HLO for collective traffic (hlo_analysis).

Results are appended as JSON lines for benchmarks/roofline.py to aggregate.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --arch all [--shapes train_4k,prefill_32k]
                                [--multi-pod] [--out results.jsonl]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat, configs
from repro.data.pipeline import make_batch_spec
from repro.launch import hlo_analysis as HLO
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.models import model as M
from repro.sparse import registry as REG
from repro.train.state import init_train_state
from repro.train.trainer import make_train_step


def _sds_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _abstract_train_state(cfg):
    return jax.eval_shape(lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0))


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def state_shardings(rules: ShardingRules, state_sds):
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(rules.mesh, P())
    return type(state_sds)(
        step=rep,
        params=rules.params(state_sds.params),
        opt_state=rules.opt_state(state_sds.opt_state, state_sds.params),
        masks=rules.masks(state_sds.masks),
        neuron_active=rules.neuron_active(state_sds.neuron_active),
        grad_accum=rules.params(state_sds.grad_accum),
        mask_versions=jax.tree.map(lambda _: rep, state_sds.mask_versions),
        rng=rep,
    )


def lower_train(cfg, shape, mesh):
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    state_sds = _abstract_train_state(cfg)
    batch_sds = make_batch_spec(cfg, shape)
    # targets/labels present for training
    st_sh = state_shardings(rules, state_sds)
    b_sh = rules.batch(batch_sds, shape=shape)
    step = make_train_step(cfg, registry, lambda s: jnp.float32(1e-3),
                           microbatches=cfg.microbatches)
    jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
    with compat.use_mesh(mesh):
        return jitted.lower(state_sds, batch_sds)


def lower_dst(cfg, shape, mesh):
    """The topology-update program (runs every delta_t steps)."""
    from repro.train.trainer import make_dst_step
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    if not registry:
        return None
    state_sds = _abstract_train_state(cfg)
    batch_sds = make_batch_spec(cfg, shape)
    st_sh = state_shardings(rules, state_sds)
    b_sh = rules.batch(batch_sds, shape=shape)
    # NOTE (§Perf iteration 7): per-slab sharding constraints inside the
    # lax.map get hoisted by GSPMD into whole-stack gathers (80 GB f32 for
    # kimi's expert stacks). Letting the partitioner reshard each slab
    # transiently is 4.6x cheaper — measured 318 -> 68 GB temp.
    step = make_dst_step(cfg, registry, compute_specs=None)
    jitted = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=st_sh,
                     donate_argnums=(0,))
    with compat.use_mesh(mesh):
        return jitted.lower(state_sds, batch_sds)


def lower_serve_planned(cfg, shape, mesh, reps: dict):
    """Decode under a per-stack representation assignment ``reps`` (stack
    name -> representation), the dry-run consumer of repro.sparse.plan:
    the serving pytree is built abstractly (ShapeDtypeStructs, no
    allocation) and the planned decode program is lowered against it."""
    from repro.sparse import plan as PLAN
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    k_fan = REG.k_fan_map(cfg, registry)
    params_sds = _abstract(lambda k: M.init_params(cfg, k, k_fan), jax.random.PRNGKey(0))
    cond_sds = PLAN.abstract_serving_tree(cfg, registry, reps)
    cache_sds = _abstract(lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    batch_sds = make_batch_spec(cfg, shape)

    p_sh = rules.params(params_sds)
    m_sh = rules.masks(cond_sds)
    c_sh = rules.cache(cache_sds, global_batch=shape.global_batch)
    b_sh = rules.batch(batch_sds, shape=shape)

    def serve_step(params, cond, batch, cache):
        return M.decode_step(cfg, params, cond, batch, cache)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, m_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(3,))
    with compat.use_mesh(mesh):
        return jitted.lower(params_sds, cond_sds, batch_sds, cache_sds)


def lower_serve_condensed(cfg, shape, mesh):
    """Decode with the condensed constant fan-in representation (the paper's
    Alg. 1 serving path): weight reads shrink to n_out*k entries."""
    registry = REG.build_registry(cfg)
    return lower_serve_planned(cfg, shape, mesh,
                               {s.name: "condensed" for s in registry})


def lower_serve_structured(cfg, shape, mesh):
    """Decode with the structured (ablation) representation: the
    column-gathered kernel over abstract ``active_index`` leaves — proves
    the gathered matmul + fused scatter epilogue lower and fit at the
    padded-d_out static bound before any mask is realized."""
    registry = REG.build_registry(cfg)
    return lower_serve_planned(cfg, shape, mesh,
                               {s.name: "structured" for s in registry})


def lower_serve_plan(cfg, shape, mesh):
    """Decode under the cost-model's per-stack choice for this shape's batch
    (the ``--path auto`` program, compiled without allocation)."""
    from repro.sparse import plan as PLAN
    registry = REG.build_registry(cfg)
    reps = PLAN.plan_for_shape(cfg, registry, batch_size=shape.global_batch)
    return lower_serve_planned(cfg, shape, mesh, reps)


def lower_serve_engine(cfg, shape, mesh):
    """Decode for one ServingEngine GROUP, lowered abstractly: the plan key
    a request of this shape's batch would group under (batch bucket x
    format signature — repro.launch.engine.abstract_plan_key, no
    allocation), and the planned decode program for that group's serving
    tree. Proves every group program the engine would dispatch compiles and
    fits before a single weight is exported."""
    from repro.launch import engine as ENG
    registry = REG.build_registry(cfg)
    key, reps = ENG.abstract_plan_key(cfg, registry, shape.global_batch)
    print(f"[dryrun] engine group {key.describe()} for batch "
          f"{shape.global_batch}")
    return lower_serve_planned(cfg, shape, mesh, reps)


def lower_serve_paged(cfg, shape, mesh):
    """The continuous-batching decode program: one step against the paged
    KV pool (block tables + per-stream lengths), lowered abstractly at this
    shape's batch with the pool sharded page-wise over the batch axes.
    Proves the scheduler's decode program compiles and the pool fits at
    production scale. The dry-run pool holds exactly batch x pages-per-
    stream pages (batch-axis divisible); the engine's extra reserved
    garbage page rounds up to the next multiple in production."""
    from repro.compat import NamedSharding
    from repro.compat import PartitionSpec as P
    from repro.models import paged as PG
    if not M.supports_paged(cfg):
        raise ValueError(
            f"{cfg.name}: architecture outside the paged serving path "
            "(windowed/ring caches, M-RoPE, audio or SSM state) — use "
            "program=serve")
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    k_fan = REG.k_fan_map(cfg, registry)
    params_sds = _abstract(lambda k: M.init_params(cfg, k, k_fan),
                           jax.random.PRNGKey(0))
    if registry:
        masks_sds = _abstract(
            lambda k: REG.init_sparsity_state(cfg, k, registry)["masks"],
            jax.random.PRNGKey(0))
    else:
        masks_sds = {}
    bsz = shape.global_batch
    bs_blk = 16
    nb = PG.pages_for(shape.seq_len + bs_blk, bs_blk)
    pool_sds = _abstract(lambda: M.init_paged_pool(cfg, bsz * nb, bs_blk))
    table_sds = jax.ShapeDtypeStruct((bsz, nb), jnp.int32)
    len_sds = jax.ShapeDtypeStruct((bsz,), jnp.int32)
    batch_sds = make_batch_spec(cfg, shape)

    p_sh = rules.params(params_sds)
    m_sh = rules.masks(masks_sds)
    c_sh = rules.cache(pool_sds, global_batch=bsz)
    b_sh = rules.batch(batch_sds, shape=shape)
    bax = rules.batch_axes(bsz)
    t_sh = NamedSharding(mesh, P(bax or None, None))
    l_sh = NamedSharding(mesh, P(bax or None))

    def serve_step(params, masks, batch, pool, table, lengths):
        return M.paged_decode_step(cfg, params, masks, batch, pool, table,
                                   lengths)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, m_sh, b_sh, c_sh, t_sh, l_sh),
                     out_shardings=(None, c_sh), donate_argnums=(3,))
    with compat.use_mesh(mesh):
        return jitted.lower(params_sds, masks_sds, batch_sds, pool_sds,
                            table_sds, len_sds)


def lower_zoo_engine(cfg, shape, mesh, reps: dict):
    """The exact decode program a ``ServingEngine`` group dispatches for
    this arch: paged decode where the arch supports it, legacy
    contiguous-cache decode otherwise — in both cases with the PLANNED
    abstract serving tree (format-object ShapeDtypeStruct leaves) in the
    masks slot, exactly what the engine's runners execute."""
    if not M.supports_paged(cfg):
        return lower_serve_planned(cfg, shape, mesh, reps)
    from repro.compat import NamedSharding
    from repro.compat import PartitionSpec as P
    from repro.models import paged as PG
    from repro.sparse import plan as PLAN
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    k_fan = REG.k_fan_map(cfg, registry)
    params_sds = _abstract(lambda k: M.init_params(cfg, k, k_fan),
                           jax.random.PRNGKey(0))
    cond_sds = PLAN.abstract_serving_tree(cfg, registry, reps)
    bsz = shape.global_batch
    bs_blk = 16
    nb = PG.pages_for(shape.seq_len + bs_blk, bs_blk)
    pool_sds = _abstract(lambda: M.init_paged_pool(cfg, bsz * nb, bs_blk))
    table_sds = jax.ShapeDtypeStruct((bsz, nb), jnp.int32)
    len_sds = jax.ShapeDtypeStruct((bsz,), jnp.int32)
    batch_sds = make_batch_spec(cfg, shape)
    p_sh = rules.params(params_sds)
    m_sh = rules.masks(cond_sds)
    c_sh = rules.cache(pool_sds, global_batch=bsz)
    b_sh = rules.batch(batch_sds, shape=shape)
    bax = rules.batch_axes(bsz)
    t_sh = NamedSharding(mesh, P(bax or None, None))
    l_sh = NamedSharding(mesh, P(bax or None))

    def serve_step(params, cond, batch, pool, table, lengths):
        return M.paged_decode_step(cfg, params, cond, batch, pool, table,
                                   lengths)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, m_sh, b_sh, c_sh, t_sh, l_sh),
                     out_shardings=(None, c_sh), donate_argnums=(3,))
    with compat.use_mesh(mesh):
        return jitted.lower(params_sds, cond_sds, batch_sds, pool_sds,
                            table_sds, len_sds)


def run_zoo_cell(arch: str, smoke: bool = False, quiet: bool = False) -> dict:
    """Config-zoo serving smoke (one arch): group a decode request under
    the engine's abstract plan key, build the abstract serving tree, and
    compile the group's decode program (paged where supported). Proves the
    ``ServingEngine`` plan machinery lowers for EVERY ``configs/`` model —
    MoE expert stacks, SSM/hybrid (legacy path), multimodal, musicgen —
    before any of them is served for real. Encoder-only archs (ViT) stop
    after key + abstract tree: there is no decode program to lower."""
    import dataclasses as DC

    from repro.launch import engine as ENG
    from repro.sparse import plan as PLAN

    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    registry = REG.build_registry(cfg)
    shapes = configs.shapes_for(arch, cfg.family, cfg.causal)
    decode = next((s for s in shapes if s.kind == "decode"), None)
    batch = decode.global_batch if decode is not None else 8
    key, reps = ENG.abstract_plan_key(cfg, registry, batch)
    tree_sds = PLAN.abstract_serving_tree(cfg, registry, reps)
    result = {
        "arch": arch, "program": "serve_zoo", "smoke": smoke,
        "family": cfg.family, "plan_key": key.describe(), "formats": reps,
        "supports_paged": M.supports_paged(cfg),
        "abstract_leaves": len(jax.tree.leaves(tree_sds)),
        "decode_shape": decode.name if decode is not None else None,
    }
    if decode is None:
        if not quiet:
            print(f"[serve_zoo] {arch}: encoder-only — plan key "
                  f"{key.describe()}, no decode program")
        return result
    shape = decode
    if smoke:
        shape = DC.replace(shape, seq_len=min(shape.seq_len, 256),
                           global_batch=min(shape.global_batch, 8))
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    compiled = lower_zoo_engine(cfg, shape, mesh, reps).compile()
    result["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    result["peak_bytes"] = (getattr(mem, "argument_size_in_bytes", 0)
                            + getattr(mem, "temp_size_in_bytes", 0))
    if not quiet:
        paged = "paged" if result["supports_paged"] else "legacy"
        print(f"[serve_zoo] {arch}: group {key.describe()} ({paged}) "
              f"compiled in {result['compile_s']}s, peak "
              f"{result['peak_bytes'] / 2**30:.2f} GB/device")
    return result


def lower_serve(cfg, shape, mesh):
    if shape.kind == "prefill":
        # larger attention chunks for long-prompt prefill: fewer unrolled
        # q-chunks keeps HLO size and compile time bounded
        cfg = cfg.replace(attn_q_chunk=4096, attn_kv_chunk=2048)
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    k_fan = REG.k_fan_map(cfg, registry)

    params_sds = _abstract(lambda k: M.init_params(cfg, k, k_fan), jax.random.PRNGKey(0))
    if registry:
        masks_sds = _abstract(
            lambda k: REG.init_sparsity_state(cfg, k, registry)["masks"],
            jax.random.PRNGKey(0))
    else:
        masks_sds = {}
    cache_sds = _abstract(lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    batch_sds = make_batch_spec(cfg, shape)

    p_sh = rules.params(params_sds)
    m_sh = rules.masks(masks_sds)
    c_sh = rules.cache(cache_sds, global_batch=shape.global_batch)
    b_sh = rules.batch(batch_sds, shape=shape)

    step_fn = M.prefill_step if shape.kind == "prefill" else M.decode_step

    def serve_step(params, masks, batch, cache):
        return step_fn(cfg, params, masks, batch, cache)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, m_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(3,))
    with compat.use_mesh(mesh):
        return jitted.lower(params_sds, masks_sds, batch_sds, cache_sds)


def tp_mesh(tp: int = 4):
    """Simulated (data=1, model=tp) mesh over the forced host devices — the
    smallest mesh that exercises the tensor-parallel serving path."""
    return compat.make_mesh((1, int(tp)), ("data", "model"))


def _gather_ok(shapes, nloc: int, k: int, d_out: int) -> bool:
    """True iff some gather is shard-local ``(..., nloc, k)`` and none is the
    replicated global ``(..., d_out, k)`` sparse gather."""
    local = any(g[-2:] == (nloc, k) for g in shapes if len(g) >= 2)
    global_ = any(g[-2:] == (d_out, k) for g in shapes if len(g) >= 2)
    return local and (nloc == d_out or not global_)


def run_tp_cell(arch: str, shape_name: str, tp: int = 4, quiet: bool = False,
                cfg=None, smoke: bool = False) -> dict:
    """Tensor-parallel serving cell: lower the sharded PREFILL and the paged
    DECODE abstractly on a simulated (data=1, model=tp) mesh, and assert the
    SPMD invariants from the partitioned HLO:

      1. per sparse stack (isolated apply program, condensed leaves in their
         tp-block layout): EXACTLY ONE all-gather — the output-partial
         collective the cost model prices — and no other collective;
      2. every condensed gather in that program is shard-local: trailing
         dims ``(d_out/tp, k)``, never the replicated ``(d_out, k)``;
      3. the full prefill + paged-decode programs compile with the sharded
         serving tree, their gathers are shard-local for every divisible
         stack, and no global-shape sparse gather survives partitioning.

    These are BLOCKING checks (AssertionError fails the cell); the recorded
    timings/byte counts are trend data only. ``smoke`` swaps in the arch's
    smoke config and a small decode shape so CI can run the cell in seconds.
    """
    import dataclasses as DC

    from repro.compat import NamedSharding
    from repro.compat import PartitionSpec as P
    from repro.core import distributions as D
    from repro.models import paged as PG
    from repro.sparse import formats as F
    from repro.sparse import plan as PLAN

    cfg = cfg or (configs.get_smoke_config(arch) if smoke
                  else configs.get_config(arch))
    shape = configs.SHAPES[shape_name]
    if smoke:
        shape = DC.replace(shape, seq_len=min(shape.seq_len, 256),
                           global_batch=min(shape.global_batch, 8))
    if shape.kind != "decode":
        raise ValueError(f"serve_tp runs decode shapes; got {shape_name!r} "
                         f"({shape.kind})")
    mesh = tp_mesh(tp)
    rules = ShardingRules(cfg, mesh)
    registry = REG.build_registry(cfg)
    if not registry:
        raise ValueError(f"{cfg.name}: no sparse stacks to shard")
    dt = jnp.dtype(cfg.param_dtype)
    bsz = shape.global_batch

    # -- invariant 1+2: isolated per-stack apply programs -------------------
    per_stack = {}
    tp_stacks = [s for s in registry if s.d_out % tp == 0]
    for s in tp_stacks:
        k = D.fan_in_from_density(s.d_in, s.density)
        leaf = F.Condensed.abstract((), s.d_in, s.d_out, k, dt, tp=tp)
        tree: dict = {}
        REG.set_path(tree, s.path, leaf)
        x_sds = jax.ShapeDtypeStruct((bsz, s.d_in), dt)

        def apply_fn(tree, x, _path=s.path):
            return REG.get_path(tree, _path).apply(x)

        jitted = jax.jit(apply_fn,
                         in_shardings=(rules.masks(tree),
                                       NamedSharding(mesh, P())),
                         out_shardings=NamedSharding(mesh, P()))
        with compat.use_mesh(mesh):
            hlo = jitted.lower(tree, x_sds).compile().as_text()
        pc = HLO.analyze(hlo)
        others = {c: n for c, n in pc.count_by_type.items()
                  if n and c != "all-gather"}
        gshapes = HLO.instruction_shapes(hlo, "gather")
        nloc = s.d_out // tp
        assert pc.count_by_type["all-gather"] == 1, (
            f"{s.name}: expected exactly ONE all-gather for the sharded "
            f"apply, got {pc.count_by_type}")
        assert not others, f"{s.name}: unexpected collectives {others}"
        assert _gather_ok(gshapes, nloc, k, s.d_out), (
            f"{s.name}: gathers {gshapes} are not shard-local "
            f"(want trailing ({nloc}, {k}), forbid ({s.d_out}, {k}))")
        per_stack[s.name] = {
            "all_gather": 1, "gathers": [list(g) for g in gshapes],
            "nloc": nloc, "k": k,
            "allgather_bytes": pc.bytes_by_type["all-gather"]}
    skipped = [s.name for s in registry if s.d_out % tp != 0]

    # -- invariant 3: full sharded prefill + paged decode -------------------
    reps = {s.name: "condensed" for s in registry}
    k_fan = REG.k_fan_map(cfg, registry)
    params_sds = _abstract(lambda key: M.init_params(cfg, key, k_fan),
                           jax.random.PRNGKey(0))
    cond_sds = PLAN.abstract_serving_tree(cfg, registry, reps, tp=tp)
    p_sh = rules.params(params_sds)
    m_sh = rules.masks(cond_sds)

    def check_full(name, hlo):
        gshapes = HLO.instruction_shapes(hlo, "gather")
        for s in tp_stacks:
            k = D.fan_in_from_density(s.d_in, s.density)
            assert _gather_ok(gshapes, s.d_out // tp, k, s.d_out), (
                f"{name}/{s.name}: sparse gathers not shard-local in the "
                f"full program: {sorted(set(gshapes))}")
        return HLO.analyze(hlo)

    timings = {}
    # prefill at the full prompt length
    pre_shape = DC.replace(shape, kind="prefill")
    pre_batch_sds = make_batch_spec(cfg, pre_shape)
    cache_sds = _abstract(lambda: M.init_cache(cfg, bsz, shape.seq_len))
    c_sh = rules.cache(cache_sds, global_batch=bsz)
    b_sh = rules.batch(pre_batch_sds, shape=pre_shape)
    t0 = time.time()
    jitted = jax.jit(lambda p, c, b, kv: M.prefill_step(cfg, p, c, b, kv),
                     in_shardings=(p_sh, m_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(3,))
    with compat.use_mesh(mesh):
        pre_hlo = jitted.lower(params_sds, cond_sds, pre_batch_sds,
                               cache_sds).compile().as_text()
    timings["prefill_s"] = round(time.time() - t0, 1)
    pre_pc = check_full("prefill", pre_hlo)

    # paged decode step (the continuous-batching program)
    if M.supports_paged(cfg):
        bs_blk = 16
        nb = PG.pages_for(shape.seq_len + bs_blk, bs_blk)
        pool_sds = _abstract(lambda: M.init_paged_pool(cfg, bsz * nb, bs_blk))
        table_sds = jax.ShapeDtypeStruct((bsz, nb), jnp.int32)
        len_sds = jax.ShapeDtypeStruct((bsz,), jnp.int32)
        dec_batch_sds = make_batch_spec(cfg, shape)
        pc_sh = rules.cache(pool_sds, global_batch=bsz)
        db_sh = rules.batch(dec_batch_sds, shape=shape)
        bax = rules.batch_axes(bsz)
        t_sh = NamedSharding(mesh, P(bax or None, None))
        l_sh = NamedSharding(mesh, P(bax or None))
        t0 = time.time()
        jitted = jax.jit(
            lambda p, c, b, pool, tb, ln: M.paged_decode_step(
                cfg, p, c, b, pool, tb, ln),
            in_shardings=(p_sh, m_sh, db_sh, pc_sh, t_sh, l_sh),
            out_shardings=(None, pc_sh), donate_argnums=(3,))
        with compat.use_mesh(mesh):
            dec_hlo = jitted.lower(params_sds, cond_sds, dec_batch_sds,
                                   pool_sds, table_sds,
                                   len_sds).compile().as_text()
        timings["decode_s"] = round(time.time() - t0, 1)
        dec_pc = check_full("paged_decode", dec_hlo)
    else:
        dec_pc = None

    # per-shard serving bytes: each device streams 1/tp of the values+indices
    itemsize = dt.itemsize
    shard_bytes = sum(
        F.Condensed.estimate_weight_bytes(F.SparseFormat.shard_spec(
            F.FormatSpec(d_in=s.d_in, d_out=s.d_out, n_replicas=s.n_replicas,
                         itemsize=itemsize,
                         k=D.fan_in_from_density(s.d_in, s.density),
                         max_active=s.d_out, active_fraction=1.0), tp))
        for s in tp_stacks)

    result = {
        "arch": arch, "shape": shape_name, "program": "serve_tp", "tp": tp,
        "mesh": f"1x{tp}", "smoke": smoke,
        "per_stack": per_stack, "skipped_stacks": skipped,
        "per_shard_values_bytes": shard_bytes,
        "prefill_collectives": pre_pc.count_by_type,
        "decode_collectives": dec_pc.count_by_type if dec_pc else None,
        **timings,
    }
    if not quiet:
        print(f"--- {arch} x {shape_name} x serve_tp (model={tp}) ---")
        for name, row in per_stack.items():
            print(f"[serve_tp] {name:24s} all-gather x1, gathers "
                  f"{row['gathers']} (nloc={row['nloc']}, k={row['k']})")
        if skipped:
            print(f"[serve_tp] replicated (d_out % {tp} != 0): {skipped}")
        print(f"[serve_tp] per-shard condensed bytes: {shard_bytes} "
              f"({shard_bytes / 2**10:.1f} KiB/device)")
        print("[serve_tp] prefill collectives:",
              {c: n for c, n in pre_pc.count_by_type.items() if n})
        if dec_pc:
            print("[serve_tp] paged-decode collectives:",
                  {c: n for c, n in dec_pc.count_by_type.items() if n})
        print(f"[serve_tp] SPMD invariants OK for {len(per_stack)} stacks")
    return result


def run_cell(arch: str, shape_name: str, multi_pod: bool, quiet: bool = False,
             program: str = "auto", cfg=None) -> dict:
    if program == "serve_tp":
        return run_tp_cell(arch, shape_name, quiet=quiet, cfg=cfg)
    cfg = cfg or configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    lower_fn = {"train": lower_train, "serve": lower_serve, "dst": lower_dst,
                "serve_cond": lower_serve_condensed,
                "serve_struct": lower_serve_structured,
                "serve_plan": lower_serve_plan,
                "serve_engine": lower_serve_engine,
                "serve_paged": lower_serve_paged}[
        (("train" if shape.kind == "train" else "serve") if program == "auto"
         else program)]
    t0 = time.time()
    lowered = lower_fn(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    # trip-count-aware static cost model (xla's cost_analysis counts scan
    # bodies once — see hlo_analysis module docstring); bf16_equiv corrects
    # the CPU backend's f32-upcast of bf16 dots/collectives for the TPU target
    pc = HLO.analyze(hlo, bf16_equiv=(cfg.dtype == "bfloat16"))

    flops = pc.flops
    bytes_acc = pc.hbm_bytes
    terms = HLO.roofline_terms(flops, bytes_acc, pc.total_collective_bytes, n_chips)

    result = {
        "arch": arch, "shape": shape_name, "program": program,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "collective_bytes": pc.total_collective_bytes,
        "collective_by_type": pc.bytes_by_type,
        "collective_counts": pc.count_by_type,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                       + getattr(mem, "temp_size_in_bytes", 0)),
        "roofline": terms,
        "dominant": HLO.dominant_term(terms),
    }
    if not quiet:
        print(f"--- {arch} x {shape_name} x {result['mesh']} ---")
        print("memory_analysis:", mem)
        print("flops/device={:.3e} hbm_bytes/device={:.3e} peak_mem={:.2f}GB".format(
            flops, bytes_acc, result["peak_bytes"] / 2**30))
        print("collectives:", {k: f"{v/1e6:.1f}MB" for k, v in pc.bytes_by_type.items() if v})
        print("roofline:", {k: f"{v*1e3:.2f}ms" for k, v in terms.items()},
              "dominant:", result["dominant"])
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--dst", action="store_true", help="also compile the topology-update program for train cells")
    ap.add_argument("--program", default="auto",
                    help="program to lower (auto/train/serve/serve_cond/"
                         "serve_struct/serve_plan/serve_engine/serve_paged/"
                         "serve_tp/serve_zoo)")
    ap.add_argument("--tp", type=int, default=4,
                    help="model-axis size for --program serve_tp")
    ap.add_argument("--smoke", action="store_true",
                    help="serve_tp/serve_zoo: smoke config + tiny decode "
                         "shape (CI-sized; invariants still blocking)")
    args = ap.parse_args(argv)

    archs = list(configs.ALL_ARCHS) if args.arch == "all" else [args.arch]
    results, failures = [], []
    if args.program == "serve_zoo":
        # one cell per ARCH (the zoo picks its own decode shape); sweeps the
        # whole configs/ zoo through the engine's plan machinery
        for arch in archs:
            try:
                results.append(run_zoo_cell(arch, smoke=args.smoke))
            except Exception as e:  # noqa: BLE001 — report, continue sweep
                traceback.print_exc()
                failures.append((arch, "serve_zoo", str(e)[:200]))
        if args.out:
            with open(args.out, "w") as f:
                for r in results:
                    f.write(json.dumps(r) + "\n")
        print(f"\n{len(results)} zoo cells OK, {len(failures)} failed")
        for f in failures:
            print("FAILED:", f)
        return 1 if failures else 0
    for arch in archs:
        cfg = configs.get_config(arch)
        cells = configs.shapes_for(arch, cfg.family, cfg.causal)
        if args.shapes:
            cells = [s for s in cells if s.name in args.shapes.split(",")]
        if args.program == "serve_tp":
            cells = [s for s in cells if s.kind == "decode"]
        for shape in cells:
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            programs = ([args.program] if args.program != "auto" else
                        ["auto"] + (["dst"] if shape.kind == "train"
                                    and args.dst else []))
            for mp in meshes:
                for prog in programs:
                    try:
                        if prog == "serve_tp":
                            r = run_tp_cell(arch, shape.name, tp=args.tp,
                                            smoke=args.smoke)
                        else:
                            r = run_cell(arch, shape.name, mp, program=prog)
                        results.append(r)
                    except Exception as e:  # noqa: BLE001 — report, continue sweep
                        traceback.print_exc()
                        failures.append((arch, shape.name, mp, prog, str(e)[:200]))
                    if args.out:
                        with open(args.out, "w") as f:
                            for r in results:
                                f.write(json.dumps(r) + "\n")
    print(f"\n{len(results)} cells compiled OK, {len(failures)} failed")
    for f in failures:
        print("FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
