"""Serving CLI: a thin wrapper over ``repro.launch.engine.ServingEngine``.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --path condensed

Demonstrates the production serving paths (paper Sec. 4.4 — same trained
weights, multiple execution representations). Representation selection lives
in repro.sparse.plan over the typed formats of repro.sparse.formats; request
admission/grouping/execution live in repro.launch.engine. This module only
parses flags, builds the engine, submits ONE request and prints the result:

  --path auto        per-stack cost model over the request's batch BUCKET
                     (shared with the autotune cache keys): condensed gather
                     wins the bandwidth-bound decode shapes (B=1),
                     masked-dense wins the MXU back at large batch (B=256),
                     matching the paper's Sec. 4.4 crossover; ablation-ONLY
                     stacks additionally admit the column-gathered
                     structured kernel, which wins their decode shapes
  --path masked      masked-dense MXU path (bool masks; training layout)
  --path condensed   constant fan-in condensed path: sparse linears run the
                     Pallas gather kernel over Condensed formats, touching
                     only n_out*k weight entries (Alg. 1; bandwidth-bound
                     decode is where the paper's 3.4x/1.7x CPU/GPU wins live)
  --path structured  ablated neurons dropped, surviving columns gathered
                     through the structured Pallas kernel — weight bytes and
                     MXU FLOPs scale with the active fraction (Fig. 4
                     "structured" ablation — NOT output-equivalent unless the
                     sparsity is ablation-only)
  --path condensed_over_active
                     the paper's combined Fig. 4 point: ablated neurons are
                     dropped, THEN the condensed gather runs over the
                     surviving rows only. Token-identical to masked for any
                     mask (ablated outputs are exact zeros either way).

Greedy decode for masked / condensed / condensed_over_active / auto is
token-identical: all evaluate the same masked weights, only the
storage/compute representation differs.

Execution is the engine's continuous-batching scheduler where the arch
supports it: dispatches are padded to the batch bucket, KV state lives in a
paged pool (block tables over shared pages), and decode runs in chunked
jitted ``lax.scan`` programs with the pool donated — so one request here
compiles the exact programs a full request mix would reuse. ``--no-paged``
(or an arch outside ``model.supports_paged``) falls back to the legacy
exact-shape slab path: one ``lax.scan`` over the whole generation against a
contiguous donated cache.

Calibration knobs (this machine, not a spec sheet):

  --profile measured  price the --path auto cost model with rates micro-
                      benchmarked on the live backend (HardwareProfile
                      .measure(); two-point gather calibration; cached per
                      backend in the autotune cache)
  --autotune          run the timed (block_b, block_n) search for every
                      condensed stack shape at this batch bucket; winners
                      persist in the autotune cache
                      ($REPRO_AUTOTUNE_CACHE or ~/.cache/repro/autotune.json)
                      under the formats' tuning keys and are picked up by
                      the Pallas kernel wrappers at trace time
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.launch.engine import (  # noqa: F401  (re-exported API surface)
    ServingEngine, _decode_loop, _prefill, generate, serve_once)
from repro.models import model as M
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG

PATHS = PLAN.PATHS


def build_plan(cfg, registry, params, masks, path: str, *,
               batch_size: int = 1, mask_versions=None,
               profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
               values_dtype: str | None = None) -> PLAN.Plan:
    """Per-stack execution plan for ``path`` at the request batch shape."""
    return PLAN.build_plan(cfg, registry, params, masks, path=path,
                           batch_size=batch_size, mask_versions=mask_versions,
                           profile=profile, values_dtype=values_dtype)


def build_serving_masks(cfg, registry, params, masks, path: str,
                        batch_size: int = 1):
    """Convert the trained (params, masks) pair into the serving pytree for
    ``path`` (leaves are repro.sparse.formats objects). Thin wrapper over
    repro.sparse.plan — the result plugs into the masks slot of
    prefill/decode_step; repro.models.layers.linear dispatches per leaf on
    its type. ``path="masked"`` returns ``masks`` unchanged (identity, no
    export) to keep the training-layout fast path allocation-free."""
    if path == "masked":
        return masks
    return build_plan(cfg, registry, params, masks, path,
                      batch_size=batch_size).serving_tree


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--path", choices=PATHS, default="masked",
                    help="serving representation for sparse linears")
    ap.add_argument("--values-dtype", choices=("f32", "bf16", "int8", "fp8"),
                    default="f32",
                    help="stored width of the exported sparse values: int8/"
                         "fp8 quantize per output neuron (symmetric absmax "
                         "scale, dequant fused into the Pallas kernels — "
                         "~1 byte/weight streamed at decode), bf16 is a "
                         "plain storage cast, f32 keeps the param dtype. "
                         "Engine-wide setting; masked stacks read the live "
                         "params and are unaffected")
    ap.add_argument("--profile", choices=("default", "measured"),
                    default="default",
                    help="cost-model hardware profile for --path auto: "
                         "'measured' microbenchmarks HBM/matmul/gather rates "
                         "on this machine (two gather batch points; cached "
                         "per backend in the autotune cache file) instead "
                         "of the built-in v5e-like constants")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shard count: builds a (1, tp) "
                         "data x model mesh and serves sparse stacks with "
                         "shard-local condensed gathers (one all-gather per "
                         "sparse layer). Requires tp visible devices; the "
                         "--path auto cost model prices the collective and "
                         "may still keep individual stacks replicated")
    ap.add_argument("--no-paged", action="store_true",
                    help="force the legacy exact-shape slab path instead of "
                         "the paged continuous-batching scheduler")
    ap.add_argument("--speculative", action="store_true",
                    help="self-draft speculative decoding: the SAME weights "
                         "at --draft-ablation extra neuron ablation draft "
                         "--gamma tokens per round, one batched full-network "
                         "dispatch verifies them (greedy output stays "
                         "bitwise identical; Result reports the measured "
                         "acceptance rate). Needs the paged scheduler and a "
                         "format-typed path (anything but masked); with a "
                         "fixed path speculation always runs, with --path "
                         "auto the cost model may decline it")
    ap.add_argument("--draft-ablation", type=float, default=0.5,
                    help="extra neuron ablation fraction of the draft "
                         "subnetwork (0.5 = draft keeps the most salient "
                         "half of each stack's active neurons)")
    ap.add_argument("--gamma", type=int, default=3,
                    help="drafted tokens per speculative round (the verify "
                         "dispatch scores gamma+1 positions)")
    ap.add_argument("--sync-dir", default=None,
                    help="subscribe to a live trainer's sync directory "
                         "(repro.sync DirChannel): bootstrap the engine "
                         "from the publisher's snapshot instead of local "
                         "init, then drain topology/values deltas at "
                         "paged-chunk boundaries while serving. Requires a "
                         "condensed-family --path matching the publisher")
    ap.add_argument("--sync-wait", type=float, default=10.0,
                    help="seconds to wait for the publisher's bootstrap "
                         "snapshot in --sync-dir before giving up")
    ap.add_argument("--autotune", action="store_true",
                    help="run the timed kernel block-shape search for every "
                         "condensed stack shape at this batch bucket before "
                         "serving (results persist in the autotune cache "
                         "and are picked up by the Pallas kernel wrappers)")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config if args.smoke else configs.get_config)(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    key = jax.random.PRNGKey(args.seed)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"] if reg else {}
    if args.path not in ("masked", "auto") and not reg:
        raise SystemExit(f"{args.arch} has no sparse stacks — only "
                         f"--path masked/auto")
    profile = PLAN.DEFAULT_PROFILE
    if args.profile == "measured":
        profile = PLAN.HardwareProfile.measure()
        print(f"[serve] calibrated profile {profile.name}: "
              f"hbm {profile.hbm_bytes_per_s / 1e9:.1f} GB/s, "
              f"matmul {profile.mxu_flops_per_s / 1e9:.1f} GFLOP/s, "
              f"gather {profile.gather_flops_per_s / 1e9:.1f}"
              + (f"->{profile.gather_flops_per_s_large / 1e9:.1f}"
                 if profile.gather_flops_per_s_large else "")
              + " GFLOP/s")

    if (args.values_dtype != "f32" and args.path == "masked"):
        print("[serve] note: --path masked serves the live dense params; "
              f"--values-dtype {args.values_dtype} only affects exported "
              "value-storing formats (condensed/structured paths or auto)")
    mesh = None
    if args.tp > 1:
        if jax.device_count() < args.tp:
            raise SystemExit(
                f"--tp {args.tp} needs {args.tp} devices, found "
                f"{jax.device_count()} (simulated meshes live in "
                "repro.launch.dryrun --program serve_tp)")
        from repro import compat
        mesh = compat.make_mesh((1, args.tp), ("data", "model"))
        print(f"[serve] mesh data=1 model={args.tp}: sparse stacks shard "
              "the neuron axis where the cost model prices it a win")
    speculative = None
    if args.speculative:
        from repro.launch.speculative import SpecConfig
        # a fixed path means the operator chose the representation — run
        # speculation as asked; --path auto keeps the cost model in charge
        speculative = SpecConfig(gamma=args.gamma,
                                 draft_ablation=args.draft_ablation,
                                 force=args.path != "auto")
    subscriber = None
    if args.sync_dir is not None:
        from repro.sync import DirChannel, Subscriber, engine_from_snapshot
        subscriber = Subscriber(DirChannel(args.sync_dir).subscribe("serve"),
                                name="serve")
        print(f"[serve] syncing from {args.sync_dir}: waiting up to "
              f"{args.sync_wait:.0f}s for a bootstrap snapshot")
        if not subscriber.wait_for_bootstrap(timeout=args.sync_wait):
            raise SystemExit(f"no snapshot appeared in {args.sync_dir} "
                             f"within {args.sync_wait:.0f}s — is the "
                             "trainer publishing?")
        # the published stream fixes path/values_dtype/tp; CLI flags for
        # those describe the LOCAL engine and must agree
        meta = subscriber.meta
        if args.path != meta.get("path"):
            print(f"[serve] note: stream publishes path={meta.get('path')!r}"
                  f"; serving that (not --path {args.path})")
        engine = engine_from_snapshot(
            cfg, subscriber, registry=reg, profile=profile,
            paged=False if args.no_paged else None, mesh=mesh,
            speculative=speculative)
        print(f"[serve] bootstrapped at generation {subscriber.generation} "
              f"(path={engine.path}, values_dtype={engine.values_dtype})")
    else:
        engine = ServingEngine(cfg, params, masks, reg, path=args.path,
                               profile=profile,
                               paged=False if args.no_paged else None,
                               values_dtype=args.values_dtype, mesh=mesh,
                               speculative=speculative)

    if args.autotune and args.path == "masked":
        print("[serve] --autotune skipped: --path masked never dispatches "
              "to the condensed kernels (use a condensed-family path or "
              "auto)")
    elif args.autotune and reg:
        tuned = engine.autotune(args.batch)
        for name, res in tuned.items():
            print(f"[serve] autotuned {name}: best "
                  f"{res.block_b or 'decode'}x{res.block_n} "
                  f"({res.us:.1f} us vs default {res.default_us:.1f} us)")

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    rid = engine.submit(prompts, args.gen)
    if args.path == "auto" and reg:
        # describe() shows BOTH the requested batch and the planned bucket —
        # the plan is keyed on the bucket (shared with autotune cache keys),
        # so --batch 2 legitimately plans at bucket 8; say so explicitly.
        print(engine.plan_for(engine.plan_key(args.batch))
              .describe(requested_batch=args.batch))
    if args.values_dtype != "f32" and reg and args.path != "masked":
        plan = engine.plan_for(engine.plan_key(args.batch))
        serving, masked_ref = plan.weight_bytes()
        print(f"[serve] values_dtype={args.values_dtype}: serving weight "
              f"bytes {serving} ({serving / max(masked_ref, 1):.3f}x of the "
              f"masked-dense reference)")
    engine.step()
    [res] = engine.retire(rid)
    b, t = prompts.shape
    print(f"[serve:{args.path}] prefill {b}x{t} in {res.prefill_s:.3f}s | "
          f"decode {b}x{args.gen} in {res.decode_s:.3f}s "
          f"({res.tok_s:.1f} tok/s)")
    print("[serve] first stream:", res.tokens[0, -args.gen:].tolist())
    if speculative is not None:
        if res.spec is not None:
            s = res.spec
            print(f"[serve:spec] gamma={s['gamma']} draft_ablation="
                  f"{s['draft_ablation']} | acceptance "
                  f"{s['acceptance_rate']:.3f} ({s['matched']}/{s['drafted']}"
                  f" drafts) | {s['full_dispatches_per_token']:.3f} "
                  f"full-network dispatches/token | draft {s['draft_s']:.3f}s"
                  f" + verify {s['verify_s']:.3f}s")
        else:
            est = engine.spec_estimate_for(res.plan_key)
            why = (f"priced {est.spec_s_per_token * 1e6:.1f} vs plain "
                   f"{est.base_s_per_token * 1e6:.1f} us/tok at assumed "
                   f"acceptance {est.acceptance}" if est else "no estimate")
            print(f"[serve:spec] declined by --path auto pricing ({why}); "
                  f"pass a fixed path to force speculation")
    if subscriber is not None:
        c = subscriber.counters
        print(f"[serve:sync] generation {subscriber.generation} | applied "
              f"{c['applied_deltas']} delta(s) + {c['applied_snapshots']} "
              f"snapshot(s) | delta bytes {c['bytes_deltas']} vs snapshot "
              f"bytes {c['bytes_snapshots']} | stale {c['stale']} dup "
              f"{c['duplicate']} gaps {c['gaps']} resyncs {c['resyncs']}")
    return res.tokens


if __name__ == "__main__":
    main()
