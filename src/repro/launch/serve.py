"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

Demonstrates the production serving path: prefill_step fills the KV/SSM
caches (ring buffers for sliding-window layers), decode_step generates
token-by-token. On real hardware the same functions are jit-ted with the
launch.sharding cache/params shardings (see launch/dryrun.py lower_serve).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.sparse import registry as REG


def generate(cfg, params, masks, prompts: jax.Array, gen_len: int):
    """prompts: (B, T) int32. Greedy decode. Returns (B, T+gen_len)."""
    b, t = prompts.shape
    cache = M.init_cache(cfg, b, max_len=t + gen_len)
    logits, cache = jax.jit(
        lambda p, m, bt, c: M.prefill_step(cfg, p, m, bt, c)
    )(params, masks, {"tokens": prompts}, cache)
    step = jax.jit(lambda p, m, bt, c: M.decode_step(cfg, p, m, bt, c))
    out = [prompts]
    cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for _ in range(gen_len):
        out.append(cur)
        logits, cache = step(params, masks, {"tokens": cur}, cache)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config if args.smoke else configs.get_config)(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    key = jax.random.PRNGKey(args.seed)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"] if reg else {}

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(cfg, params, masks, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("[serve] first stream:", out[0, -args.gen:].tolist())
    return out


if __name__ == "__main__":
    main()
