"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --path condensed

Demonstrates the production serving paths (paper Sec. 4.4 — same trained
weights, multiple execution representations). Representation selection lives
in repro.sparse.plan; this driver builds a per-stack execution Plan:

  --path auto        per-stack bytes/FLOPs cost model over the request batch
                     shape: condensed gather wins the bandwidth-bound decode
                     shapes (B=1), masked-dense wins the MXU back at large
                     batch (B=256), matching the paper's Sec. 4.4 crossover
  --path masked      masked-dense MXU path (bool masks; training layout)
  --path condensed   constant fan-in condensed path: sparse linears run the
                     Pallas gather kernel over {values, indices}, touching
                     only n_out*k weight entries (Alg. 1; bandwidth-bound
                     decode is where the paper's 3.4x/1.7x CPU/GPU wins live)
  --path structured  ablated neurons dropped, active columns dense (Fig. 4
                     "structured" ablation — NOT output-equivalent unless the
                     sparsity is ablation-only)
  --path condensed_over_active
                     the paper's combined Fig. 4 point: ablated neurons are
                     dropped, THEN the condensed gather runs over the
                     surviving rows only. Token-identical to masked for any
                     mask (ablated outputs are exact zeros either way).

Greedy decode for masked / condensed / condensed_over_active / auto is
token-identical: all evaluate the same masked weights, only the
storage/compute representation differs.

The generation loop is a single jitted ``lax.scan`` over decode steps with the
KV/SSM cache donated (no per-token Python dispatch, no cache copies) — the
serving analogue of the scanned layer stacks in repro.models.model.

Calibration knobs (this machine, not a spec sheet):

  --profile measured  price the --path auto cost model with rates micro-
                      benchmarked on the live backend (HardwareProfile
                      .measure(); cached per backend in the autotune cache)
  --autotune          run the timed (block_b, block_n) search for every
                      condensed stack shape at this batch bucket; winners
                      persist in the autotune cache
                      ($REPRO_AUTOTUNE_CACHE or ~/.cache/repro/autotune.json)
                      and are picked up by the Pallas kernel wrappers at
                      trace time
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG

PATHS = PLAN.PATHS


def build_plan(cfg, registry, params, masks, path: str, *,
               batch_size: int = 1, mask_versions=None,
               profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE) -> PLAN.Plan:
    """Per-stack execution plan for ``path`` at the request batch shape."""
    return PLAN.build_plan(cfg, registry, params, masks, path=path,
                           batch_size=batch_size, mask_versions=mask_versions,
                           profile=profile)


def build_serving_masks(cfg, registry, params, masks, path: str,
                        batch_size: int = 1):
    """Convert the trained (params, masks) pair into the serving pytree for
    ``path``. Thin wrapper over repro.sparse.plan — the result plugs into the
    masks slot of prefill/decode_step; repro.models.layers.linear dispatches
    per-leaf on its structure. ``path="masked"`` returns ``masks`` unchanged
    (identity, no export) to keep the training-layout fast path allocation-
    free."""
    if path == "masked":
        return masks
    return build_plan(cfg, registry, params, masks, path,
                      batch_size=batch_size).serving_tree


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill(cfg, params, masks, batch, cache):
    # module-level jit (not a per-call lambda) so repeated serve calls on the
    # same cfg/shapes hit the compile cache — the benchmark warm-up relies on it
    return M.prefill_step(cfg, params, masks, batch, cache)


@functools.partial(jax.jit, static_argnames=("cfg", "gen_len"),
                   donate_argnums=(3,))
def _decode_loop(cfg, params, masks, cache, first_tok, gen_len: int):
    """Greedy decode of ``gen_len`` tokens as one scanned program.

    first_tok: (B, 1) int32 — argmax of the prefill logits. The cache is
    donated: each scan step's cache update aliases the input buffers, so
    serving memory stays at one cache regardless of generation length.
    Returns (B, gen_len) generated tokens (first_tok first).
    """
    def body(carry, _):
        cur, cache = carry
        logits, cache = M.decode_step(cfg, params, masks, {"tokens": cur}, cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, cache), cur[:, 0]

    (_, cache), toks = jax.lax.scan(body, (first_tok, cache), None,
                                    length=gen_len)
    return toks.T, cache


def generate(cfg, params, masks, prompts: jax.Array, gen_len: int):
    """prompts: (B, T) int32. Greedy decode. Returns (B, T+gen_len)."""
    out, _ = serve_once(cfg, params, masks, prompts, gen_len, "generate",
                        quiet=True)
    return out


def serve_once(cfg, params, masks, prompts, gen_len: int, path_name: str,
               quiet: bool = False):
    """One timed prefill+decode pass. Returns (tokens, decode_tok_per_s)."""
    b, t = prompts.shape
    cache = M.init_cache(cfg, b, max_len=t + gen_len)

    t0 = time.perf_counter()
    logits, cache = _prefill(cfg, params, masks, {"tokens": prompts}, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    toks, _ = _decode_loop(cfg, params, masks, cache, first, gen_len)
    toks.block_until_ready()
    t_decode = time.perf_counter() - t0

    tok_s = b * gen_len / max(t_decode, 1e-9)
    if not quiet:
        print(f"[serve:{path_name}] prefill {b}x{t} in {t_prefill:.3f}s | "
              f"decode {b}x{gen_len} in {t_decode:.3f}s ({tok_s:.1f} tok/s)")
    return jnp.concatenate([prompts, toks], axis=1), tok_s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--path", choices=PATHS, default="masked",
                    help="serving representation for sparse linears")
    ap.add_argument("--profile", choices=("default", "measured"),
                    default="default",
                    help="cost-model hardware profile for --path auto: "
                         "'measured' microbenchmarks HBM/matmul/gather rates "
                         "on this machine (cached per backend in the "
                         "autotune cache file) instead of the built-in "
                         "v5e-like constants")
    ap.add_argument("--autotune", action="store_true",
                    help="run the timed kernel block-shape search for every "
                         "condensed stack shape at this batch bucket before "
                         "serving (results persist in the autotune cache "
                         "and are picked up by the Pallas kernel wrappers)")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config if args.smoke else configs.get_config)(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    key = jax.random.PRNGKey(args.seed)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"] if reg else {}
    if args.path not in ("masked", "auto") and not reg:
        raise SystemExit(f"{args.arch} has no sparse stacks — only "
                         f"--path masked/auto")
    profile = PLAN.DEFAULT_PROFILE
    if args.profile == "measured":
        profile = PLAN.HardwareProfile.measure()
        print(f"[serve] calibrated profile {profile.name}: "
              f"hbm {profile.hbm_bytes_per_s / 1e9:.1f} GB/s, "
              f"matmul {profile.mxu_flops_per_s / 1e9:.1f} GFLOP/s, "
              f"gather {profile.gather_flops_per_s / 1e9:.1f} GFLOP/s")
    if args.autotune and args.path == "masked":
        print("[serve] --autotune skipped: --path masked never dispatches "
              "to the condensed kernels (use a condensed-family path or "
              "auto)")
    elif args.autotune and reg:
        from repro.sparse import autotune as AT
        from repro.sparse import condensed as COND
        # tune at the SERVING dtype: layers cast condensed values to the
        # activation dtype, and the cache key includes the itemsize — an f32
        # tuning pass would never be looked up by a bf16 serving run
        tuned = AT.tune_registry(reg, COND.export_stats(reg, masks),
                                 batch=args.batch, dtype=jnp.dtype(cfg.dtype))
        for name, res in tuned.items():
            print(f"[serve] autotuned {name}: best "
                  f"{res.block_b or 'decode'}x{res.block_n} "
                  f"({res.us:.1f} us vs default {res.default_us:.1f} us)")
    if args.path == "masked" or not reg:
        serving_masks = masks
    else:
        plan = build_plan(cfg, reg, params, masks, args.path,
                          batch_size=args.batch, profile=profile)
        if args.path == "auto":
            print(plan.describe())
        serving_masks = plan.serving_tree

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    out, _ = serve_once(cfg, params, serving_masks, prompts, args.gen, args.path)
    print("[serve] first stream:", out[0, -args.gen:].tolist())
    return out


if __name__ == "__main__":
    main()
