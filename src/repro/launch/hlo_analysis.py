"""HLO static cost model: trip-count-aware FLOPs / bytes / collective traffic.

``compiled.cost_analysis()`` counts every while-loop body ONCE — useless for
scan-over-layers programs (an 88-layer model reports 1/88th of its FLOPs). We
therefore walk the optimized, partitioned HLO text ourselves:

  * computations are parsed into instruction lists with a result-size symbol
    table;
  * ``while`` instructions get their trip count recovered from the loop
    condition's compare-against-constant, and their body/cond costs are
    multiplied through (nested loops compose);
  * FLOPs come from ``dot`` ops (2 x prod(result) x prod(contracting dims)),
    wherever they sit (fusion bodies included);
  * HBM bytes are counted at fusion granularity (operands + results of
    top-level instructions; fusion internals stay in registers/VMEM);
  * collective traffic sums operand bytes per collective type, multiplied by
    the enclosing loops' trip counts.

Roofline terms then follow from the hardware constants. All numbers are
PER-DEVICE (the HLO is the partitioned per-device module).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~per-chip usable collective bw)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (possibly a tuple type)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    result_bytes: int
    operand_names: list
    attrs: str
    type_str: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    is_entry: bool = False


_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INST_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}\s]+?)\s+"
                      r"([\w\-]+)\((.*)$")


def parse_hlo(hlo_text: str) -> dict:
    """Parse HLO text into {computation_name: Computation}."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        # computation headers sit at column 0 (instructions are indented)
        if line and not line[0].isspace() and "->" in line and line.rstrip().endswith("{"):
            hm = _COMP_HEAD_RE.match(line.strip())
            if hm:
                cur = Computation(hm.group(2), [], is_entry=bool(hm.group(1)))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # split rest into "(operands), attrs"
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:idx], rest[idx + 1:]
        opnames = re.findall(r"%([\w.\-]+)", operand_str)
        if not opnames:  # operands may be bare names (no % in new dumps)
            opnames = [t.strip().split(" ")[-1] for t in operand_str.split(",")
                       if t.strip() and not t.strip()[0].isdigit()]
            opnames = [re.sub(r"[^\w.\-]", "", t) for t in opnames if t]
        comps[cur.name].instructions.append(
            Instruction(name, op, _shape_bytes(type_str), opnames, attrs, type_str))
    return comps


_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_DOT_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


@dataclasses.dataclass
class ProgramCost:
    flops: float
    hbm_bytes: float
    bytes_by_type: dict
    count_by_type: dict

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.bytes_by_type.values())


def analyze(hlo_text: str, bf16_equiv: bool = False) -> ProgramCost:
    """bf16_equiv: the CPU backend's float-normalization pass upcasts bf16
    dots (and the collectives scheduled on their outputs) to f32 — a TPU
    lowering of the same program keeps bf16. When the program's compute dtype
    is bf16, this flag counts f32 dot/collective payloads at 2 bytes/elem so
    the roofline reflects the TPU target, not the CPU host. Fusion bytes are
    left raw (documented upper bound)."""
    comps = parse_hlo(hlo_text)
    # symbol table per computation: name -> (result_bytes, type_str)
    tables = {cn: {i.name: i for i in c.instructions} for cn, c in comps.items()}
    memo: dict[str, ProgramCost] = {}

    def dot_flops(inst: Instruction, table: dict) -> float:
        res_elems = 0
        for dt, dims in _SHAPE_RE.findall(inst.type_str):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            res_elems += n
        k = 1
        m = _DOT_CDIMS_RE.search(inst.attrs)
        if m and inst.operand_names:
            lhs = table.get(inst.operand_names[0])
            if lhs is not None:
                lhs_dims = _SHAPE_RE.search(lhs.type_str)
                if lhs_dims:
                    dims = [int(d) for d in lhs_dims.group(2).split(",") if d]
                    for ci in m.group(1).split(","):
                        if ci != "" and int(ci) < len(dims):
                            k *= dims[int(ci)]
        return 2.0 * res_elems * k

    def trip_of(while_inst: Instruction) -> int:
        # XLA annotates scans: backend_config={"known_trip_count":{"n":"10"}}
        m = re.search(r'known_trip_count[^0-9]*(\d+)', while_inst.attrs)
        if m:
            return int(m.group(1))
        m = re.search(r"condition=%?([\w.\-]+)", while_inst.attrs)
        if not m or m.group(1) not in comps:
            return 1
        cond = comps[m.group(1)]
        best = 1
        for i in cond.instructions:
            for mm in re.finditer(r"constant\((\d+)\)", i.type_str + " " + i.attrs):
                best = max(best, int(mm.group(1)))
        return best

    def cost_of(comp_name: str, top_level: bool) -> ProgramCost:
        key = comp_name
        if key in memo:
            return memo[key]
        comp = comps[comp_name]
        table = tables[comp_name]
        flops = 0.0
        hbm = 0.0
        bby = {c: 0.0 for c in COLLECTIVES}
        cby = {c: 0 for c in COLLECTIVES}

        for inst in comp.instructions:
            op = inst.op
            if op == "dot":
                flops += dot_flops(inst, table)
                db = inst.result_bytes + sum(
                    table[o].result_bytes for o in inst.operand_names if o in table)
                if bf16_equiv and inst.type_str.startswith("f32"):
                    db *= 0.5
                hbm += db
            elif op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                trip = trip_of(inst)
                if mb and mb.group(1) in comps:
                    sub = cost_of(mb.group(1), True)
                    flops += trip * sub.flops
                    hbm += trip * sub.hbm_bytes
                    for c in COLLECTIVES:
                        bby[c] += trip * sub.bytes_by_type[c]
                        cby[c] += trip * sub.count_by_type[c]
            elif op == "fusion":
                mc = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                called = mc.group(1) if mc and mc.group(1) in comps else None
                if called:
                    sub = cost_of(called, False)
                    flops += sub.flops  # dots inside fusions still count
                    for c in COLLECTIVES:
                        bby[c] += sub.bytes_by_type[c]
                        cby[c] += sub.count_by_type[c]
                # HBM at fusion granularity. kLoop fusions touch each operand
                # at output cardinality (a dynamic-slice of a stacked scan
                # operand reads one slice, not the stack) -> cap per-operand
                # bytes at the result size; kInput (reduction) fusions read
                # operands fully. Fusions ROOTED at a dynamic-update-slice
                # write in place: traffic is the update slice (2x), not the
                # full aliased buffer (a scan writing per-layer KV caches
                # into a stacked ys buffer would otherwise be charged the
                # whole stack every iteration — 28x overcount observed).
                root_op = None
                if called and comps[called].instructions:
                    root_op = comps[called].instructions[-1].op
                if root_op == "dynamic-update-slice":
                    opbs = sorted(table[o].result_bytes
                                  for o in inst.operand_names if o in table)
                    hbm += 2 * sum(opbs[:-1])  # everything but the aliased buffer
                else:
                    kloop = "kind=kLoop" in inst.attrs or "kind=kOutput" in inst.attrs
                    for o in inst.operand_names:
                        if o in table:
                            ob = table[o].result_bytes
                            hbm += min(ob, inst.result_bytes) if kloop else ob
                    hbm += inst.result_bytes
            elif op in ("call", "conditional", "async-start"):
                for mc in re.finditer(
                        r"(?:to_apply|branch_computations=\{|called_computations=\{|calls=)"
                        r"%?([\w.\-]+)", inst.attrs):
                    if mc.group(1) in comps:
                        sub = cost_of(mc.group(1), True)
                        flops += sub.flops
                        hbm += sub.hbm_bytes
                        for c in COLLECTIVES:
                            bby[c] += sub.bytes_by_type[c]
                            cby[c] += sub.count_by_type[c]
            else:
                base = op[:-6] if op.endswith("-start") else op
                if base in COLLECTIVES:
                    opb = sum(table[o].result_bytes for o in inst.operand_names
                              if o in table)
                    if opb == 0:
                        opb = inst.result_bytes
                    if bf16_equiv and "f32" in inst.type_str:
                        opb *= 0.5
                    bby[base] += opb
                    cby[base] += 1
                    hbm += opb + inst.result_bytes
                elif op in ("dynamic-slice", "gather"):
                    # reads only the sliced/gathered extent
                    hbm += 2 * inst.result_bytes
                elif op == "dynamic-update-slice":
                    # in-place: traffic ~ 2x update bytes (operand 1)
                    upd = (table[inst.operand_names[1]].result_bytes
                           if len(inst.operand_names) > 1
                           and inst.operand_names[1] in table else inst.result_bytes)
                    hbm += 2 * upd
                elif top_level and op not in ("parameter", "constant", "tuple",
                                              "get-tuple-element", "bitcast",
                                              "after-all", "partition-id"):
                    hbm += inst.result_bytes + sum(
                        table[o].result_bytes for o in inst.operand_names if o in table)
        res = ProgramCost(flops, hbm, bby, cby)
        memo[key] = res
        return res

    entry = next((cn for cn, c in comps.items() if c.is_entry), None)
    if entry is None:
        return ProgramCost(0.0, 0.0, {c: 0.0 for c in COLLECTIVES},
                           {c: 0 for c in COLLECTIVES})
    return cost_of(entry, True)


def instruction_shapes(hlo_text: str, op: str = "gather") -> list[tuple[int, ...]]:
    """Result shapes (dim tuples) of every ``op`` instruction in the module,
    fusion bodies included. The tensor-parallel dry-run reads SPMD
    invariants straight off the partitioned per-device HLO with this: a
    shard-local condensed gather shows up as a ``gather`` whose trailing
    dims are ``(n/tp, k)``, and a replicated one as ``(n, k)`` — the shapes
    are the proof of where the partitioner actually split the work.
    ``op`` matches the base opcode (async ``-start`` variants included)."""
    comps = parse_hlo(hlo_text)
    out: list[tuple[int, ...]] = []
    for c in comps.values():
        for i in c.instructions:
            if i.op != op and i.op != op + "-start":
                continue
            m = _SHAPE_RE.search(i.type_str)
            if m:
                out.append(tuple(int(d) for d in m.group(2).split(",") if d))
    return out


# backwards-compatible wrapper used by dryrun.py
@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: dict
    count_by_type: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_type.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    cost = analyze(hlo_text)
    return CollectiveStats(cost.bytes_by_type, cost.count_by_type)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    """The three §Roofline terms, in seconds. Inputs are PER-DEVICE numbers
    (cost_analysis of the partitioned module is per-device)."""
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
