"""Self-draft speculative decoding: the ablated subnetwork drafts, the
full network verifies.

SRigL's neuron ablation means a served model already CONTAINS its own draft
model: the same trained weights at a higher ablation fraction (see
``plan.derive_draft_tree`` — per-stack, sharing every value buffer with the
target plan, zero extra weight residency). The paged scheduler's decode
chunk is replaced by speculative ROUNDS:

1. ``gamma`` greedy decode steps through the DRAFT tree (one scanned
   program — cheap steps, the draft's column subset is a fraction of the
   weight stream),
2. ONE batched full-network verification dispatch over the ``gamma + 1``
   positions (``model.paged_verify_step`` — each position attends exactly
   its own causal prefix, so position ``i``'s argmax is bitwise what a
   sequential greedy decode would emit there),
3. host-side acceptance: the longest drafted prefix the target agrees with
   commits (plus the target's own next token); the first mismatch rolls
   the paged KV state back (``paged.rewind_pages`` — overshoot pages
   return to the pool, table entries zero).

Greedy acceptance makes the emitted stream bitwise identical to
non-speculative greedy decode while the FULL network runs once per
committed prefix instead of once per token. Whether that is a win is
priced, not assumed: ``plan.price_speculation`` folds the draft's real
cost (sentinel drafts save nothing under the current kernels; column
subsets do) and an assumed acceptance rate into expected seconds/token, so
``--path auto`` can decline speculation.

KV protocol per round (stream at committed length L0, next un-emitted
token ``cur``): draft steps write draft-weight K/V at slots
``L0 .. L0+gamma-1`` and emit guesses d_1..d_gamma; the verify dispatch
feeds ``[cur, d_1..d_gamma]`` and REWRITES slots ``L0 .. L0+gamma`` with
target-weight K/V before any position attends them — draft residue is
never read by verification, and committed slots end the round holding
exactly the bytes a sequential decode would have written.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Engine-level speculative decoding settings.

    ``gamma`` — drafted tokens per round (the verify dispatch scores
    ``gamma + 1`` positions). ``draft_ablation`` — the extra neuron
    ablation fraction the draft tree applies on top of the target plan
    (0.5 = draft keeps the most salient half of each stack's active
    neurons). ``acceptance`` — the per-token acceptance probability the
    cost model assumes BEFORE measurement (``Result.spec`` reports the
    measured rate). ``force`` — run speculation even when the pricing
    declines it (fixed paths always run; ``--path auto`` declines unless
    forced).
    """
    gamma: int = 3
    draft_ablation: float = 0.5
    acceptance: float = 0.7
    force: bool = False

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError("gamma must be >= 1")
        if not 0.0 <= self.draft_ablation < 1.0:
            raise ValueError("draft_ablation must be in [0, 1)")
        if not 0.0 <= self.acceptance <= 1.0:
            raise ValueError("acceptance must be in [0, 1]")


@dataclasses.dataclass
class SpecStats:
    """Per-request speculative counters (accumulated across rounds).

    ``drafted``/``matched`` measure the draft's raw agreement with the
    target (acceptance rate = matched / drafted — the quantity the
    ablation-fraction sweep calibrates); ``committed`` counts tokens
    actually emitted (lockstep/capacity caps can commit fewer than
    matched); ``rounds`` counts full-network verify dispatches, so
    rounds / tokens-per-stream is the full-network-dispatches-per-token
    headline (1.0 for plain decode, < 1.0 whenever anything is accepted).
    """
    rounds: int = 0
    drafted: int = 0
    matched: int = 0
    committed: int = 0
    draft_s: float = 0.0
    verify_s: float = 0.0

    def summary(self, cfg: SpecConfig, streams: int) -> dict:
        tokens_per_stream = self.committed / max(streams, 1)
        return {
            "gamma": cfg.gamma,
            "draft_ablation": cfg.draft_ablation,
            "rounds": self.rounds,
            "drafted": self.drafted,
            "matched": self.matched,
            "committed": self.committed,
            "acceptance_rate": self.matched / max(self.drafted, 1),
            "full_dispatches_per_token":
                self.rounds / max(tokens_per_stream, 1e-9),
            "draft_s": self.draft_s,
            "verify_s": self.verify_s,
        }


# ---------------------------------------------------------------------------
# jitted round primitives
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "gamma"),
                   donate_argnums=(3,))
def _draft_chunk(cfg, params, draft_tree, pool, table, lengths, cur,
                 gamma: int):
    """``gamma`` greedy decode steps through the draft tree as one scanned
    program (pool donated). ``cur`` (B, 1) is each stream's next un-emitted
    token, sitting at slot ``lengths[b]``. Returns (drafted (B, gamma),
    pool): ``drafted[:, i]`` is the draft's guess for the token the target
    would emit ``i + 1`` steps from now. Draft K/V lands at slots
    ``lengths .. lengths+gamma-1`` — transient bytes the verify dispatch
    overwrites before reading."""
    def body(carry, _):
        cur, pool, lens = carry
        logits, pool = M.paged_decode_step(cfg, params, draft_tree,
                                           {"tokens": cur}, pool, table,
                                           lens)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, pool, lens + 1), nxt[:, 0]

    (_, pool, _), drafted = jax.lax.scan(body, (cur, pool, lengths), None,
                                         length=gamma)
    return drafted.T, pool


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(3,))
def _verify_chunk(cfg, params, tree, pool, table, lengths, feed):
    """ONE batched full-network dispatch over ``feed`` (B, gamma+1) — the
    current token followed by the gamma draft guesses. Returns
    (targ (B, gamma+1) int32, pool): ``targ[:, i]`` is the target's greedy
    next token after consuming ``feed[:, :i+1]`` — bitwise what sequential
    decode would emit at that position (``model.paged_verify_step``)."""
    logits, pool = M.paged_verify_step(cfg, params, tree, {"tokens": feed},
                                       pool, table, lengths)
    return jnp.argmax(logits, -1).astype(jnp.int32), pool


def _jit_entries(fn) -> int:
    try:
        return fn._cache_size()
    except Exception:  # noqa: BLE001 — optional introspection only
        return -1


def draft_dispatch(cfg, params, draft_tree, pool, table, lengths, cur,
                   gamma: int):
    """Timed draft dispatch. Returns (drafted, pool, seconds, cold)."""
    n0 = _jit_entries(_draft_chunk)
    t0 = time.perf_counter()
    drafted, pool = _draft_chunk(cfg, params, draft_tree, pool, table,
                                 lengths, cur, gamma)
    drafted.block_until_ready()
    return (drafted, pool, time.perf_counter() - t0,
            _jit_entries(_draft_chunk) != n0)


def verify_dispatch(cfg, params, tree, pool, table, lengths, feed):
    """Timed verify dispatch. Returns (targ, pool, seconds, cold)."""
    n0 = _jit_entries(_verify_chunk)
    t0 = time.perf_counter()
    targ, pool = _verify_chunk(cfg, params, tree, pool, table, lengths,
                               feed)
    targ.block_until_ready()
    return (targ, pool, time.perf_counter() - t0,
            _jit_entries(_verify_chunk) != n0)
