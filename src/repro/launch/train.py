"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Full-size configs on the production mesh are exercised via dryrun.py in this
CPU container; on a real pod this same entry point runs them (the Trainer is
mesh-agnostic: pass --mesh to place the state with launch.sharding rules).
On a multi-host pod, initialize jax.distributed before calling main() — the
per-host data pipeline shards by process_index and the checkpoint manager
writes per-host shards (see train/checkpoint.py).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.optim.schedules import warmup_cosine
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sparsity", type=float, default=None)
    ap.add_argument("--method", default=None,
                    choices=[None, "srigl", "rigl", "set", "dense"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config if args.smoke else configs.get_config)(args.arch)
    sp = cfg.sparsity
    if args.sparsity is not None:
        sp = dataclasses.replace(sp, sparsity=args.sparsity)
    if args.method is not None:
        sp = dataclasses.replace(sp, method=args.method)
    cfg = cfg.replace(sparsity=sp)

    data = SyntheticLM(
        vocab_size=max(cfg.vocab_size, 2), seq_len=args.seq, batch_size=args.batch,
        seed=args.seed, family=cfg.family, n_codebooks=cfg.n_codebooks,
        d_model=cfg.d_model)
    batches = Prefetcher(
        (jax.tree.map(jnp.asarray, b) for b in data.iterate()), depth=2)

    trainer = Trainer(
        cfg=cfg,
        lr_fn=warmup_cosine(args.lr, warmup_steps=max(args.steps // 20, 1),
                            total_steps=args.steps),
        ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every, log_every=10)
    state = trainer.init_or_restore(jax.random.PRNGKey(args.seed))
    if int(state.step) > 0:
        print(f"[train] resumed from step {int(state.step)}")
    state = trainer.fit(state, batches, args.steps)
    batches.close()
    if trainer.straggler_events:
        print(f"[train] {len(trainer.straggler_events)} straggler events flagged")
    print(f"[train] done at step {int(state.step)}")
    return state


if __name__ == "__main__":
    main()
